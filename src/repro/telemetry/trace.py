"""Span tracer with Chrome-trace / Perfetto JSON export.

A ``Tracer`` records the request lifecycle (queued → admitted → prefill →
decode megasteps → retired) and per-megastep stages (draft, verify,
accept, commit, host) as *complete* spans on named tracks. Tracks map to
Chrome-trace threads: one per request (``req:<uid>``), one for the engine
megasteps (``engine``), one for instant events. Time comes from the
injected :class:`~repro.telemetry.clock.Clock` — emulated-testbed seconds
on the testbed (where spans between driver advances collapse to zero
duration but keep their causal order), wall ``perf_counter`` live.

Spans are bounded (``maxlen``): the tracer is a flight recorder, not a
log — old events fall off rather than leaking. Export follows the Trace
Event Format: ``ph:"X"`` complete events with ``ts``/``dur`` in
microseconds relative to tracer start, ``ph:"i"`` instants, and ``ph:"M"``
``thread_name`` metadata so Perfetto labels the tracks. ``ts`` within a
track is monotonic by construction (single clock, sorted export);
``validate_chrome_trace`` asserts that plus JSON-loadability and proper
nesting, and is what CI runs against the uploaded artifact.
"""
from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, List, Optional, Tuple

from .clock import Clock, WallClock
from .metrics import SelfTime

PID = 1


class _Span:
    __slots__ = ("name", "track", "t0", "args")

    def __init__(self, name: str, track: str, t0: float,
                 args: Dict[str, Any]):
        self.name = name
        self.track = track
        self.t0 = t0
        self.args = args


class Tracer:
    def __init__(self, clock: Optional[Clock] = None,
                 self_time: Optional[SelfTime] = None,
                 maxlen: int = 200_000):
        self.clock = clock or WallClock()
        self._st = self_time
        self._t0 = self.clock.now()
        # finished events: (kind, name, track, ts, dur, args); kind X or i
        self._events: deque = deque(maxlen=maxlen)
        self._stacks: Dict[str, List[_Span]] = {}
        self._tids: Dict[str, int] = {}
        self.dropped = 0

    # -- recording ---------------------------------------------------------
    def begin(self, name: str, track: str = "main", **args):
        t0 = time.perf_counter() if self._st is not None else 0.0
        self._stacks.setdefault(track, []).append(
            _Span(name, track, self.clock.now(), args))
        if self._st is not None:
            self._st.add(time.perf_counter() - t0)

    def end(self, track: str = "main", **args):
        t0 = time.perf_counter() if self._st is not None else 0.0
        stack = self._stacks.get(track)
        if not stack:
            raise RuntimeError(f"end() with no open span on track {track!r}")
        sp = stack.pop()
        if args:
            sp.args.update(args)
        self._push(("X", sp.name, track, sp.t0, self.clock.now() - sp.t0,
                    sp.args))
        if self._st is not None:
            self._st.add(time.perf_counter() - t0)

    @contextmanager
    def span(self, name: str, track: str = "main", **args):
        self.begin(name, track, **args)
        try:
            yield self
        finally:
            self.end(track)

    def instant(self, name: str, track: str = "main", **args):
        """Point event; records the enclosing open span's name (so e.g. a
        compile instant is attributable to the megastep it happened in)."""
        t0 = time.perf_counter() if self._st is not None else 0.0
        stack = self._stacks.get(track)
        if stack:
            args = dict(args, enclosing=stack[-1].name)
        self._push(("i", name, track, self.clock.now(), 0.0, args))
        if self._st is not None:
            self._st.add(time.perf_counter() - t0)

    def current(self, track: str = "main") -> Optional[str]:
        stack = self._stacks.get(track)
        return stack[-1].name if stack else None

    def _push(self, ev: Tuple):
        if len(self._events) == self._events.maxlen:
            self.dropped += 1
        self._events.append(ev)

    # -- export ------------------------------------------------------------
    def _tid(self, track: str) -> int:
        if track not in self._tids:
            self._tids[track] = len(self._tids) + 1
        return self._tids[track]

    def to_chrome_trace(self) -> Dict[str, Any]:
        rows = []
        for kind, name, track, ts, dur, args in self._events:
            tid = self._tid(track)
            us = (ts - self._t0) * 1e6
            ev: Dict[str, Any] = {"name": name, "ph": kind, "pid": PID,
                                  "tid": tid, "ts": us}
            if kind == "X":
                ev["dur"] = dur * 1e6
            else:
                ev["s"] = "t"
            if args:
                ev["args"] = dict(args)
            rows.append(ev)
        # stable within-track ordering: by ts, outer (longer) spans first so
        # Perfetto nests them; instants after spans at equal ts
        rows.sort(key=lambda e: (e["tid"], e["ts"], -e.get("dur", -1.0)))
        meta = [{"name": "thread_name", "ph": "M", "pid": PID, "tid": tid,
                 "args": {"name": track}}
                for track, tid in sorted(self._tids.items(),
                                         key=lambda kv: kv[1])]
        return {"traceEvents": meta + rows, "displayTimeUnit": "ms"}

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)


def validate_chrome_trace(blob: Any) -> List[str]:
    """Return a list of problems (empty ⇒ valid Chrome/Perfetto trace).

    Checks: JSON round-trip, required event fields, per-track monotonic
    ``ts``, and well-nested ``X`` spans (a child must end no later than its
    parent). This is the validator CI runs on the uploaded artifact.
    """
    errs: List[str] = []
    try:
        blob = json.loads(json.dumps(blob))
    except (TypeError, ValueError) as e:
        return [f"not JSON-serialisable: {e}"]
    evs = blob.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return ["traceEvents missing or empty"]
    last_ts: Dict[int, float] = {}
    open_spans: Dict[int, List[float]] = {}
    for i, ev in enumerate(evs):
        ph = ev.get("ph")
        if ph not in ("X", "i", "M", "B", "E"):
            errs.append(f"event {i}: unknown ph {ph!r}")
            continue
        if "name" not in ev or "pid" not in ev or "tid" not in ev:
            errs.append(f"event {i}: missing name/pid/tid")
            continue
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            errs.append(f"event {i}: non-numeric ts {ts!r}")
            continue
        tid = ev["tid"]
        if ts < last_ts.get(tid, float("-inf")):
            errs.append(f"event {i}: ts {ts} < previous {last_ts[tid]} "
                        f"on tid {tid}")
        last_ts[tid] = ts
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"event {i}: bad dur {dur!r}")
                continue
            ends = open_spans.setdefault(tid, [])
            # epsilon absorbs float rounding of (ts - t0) * 1e6: adjacent
            # spans sharing a boundary are siblings, not parent/child
            while ends and ts >= ends[-1] - 1e-6:
                ends.pop()          # previous span closed before we start
            if ends and ts + dur > ends[-1] + 1e-6:
                errs.append(f"event {i}: span [{ts}, {ts + dur}] overflows "
                            f"enclosing span ending {ends[-1]} on tid {tid}")
            ends.append(ts + dur)
    return errs
