"""End-to-end telemetry: span tracing, metrics registry, event log.

``Telemetry`` is the bundle the engine and server consume: one clock
(wall or emulated), one tracer, one registry, one event log, and one
shared :class:`SelfTime` accumulator that sums the host seconds spent
inside every telemetry call. ``overhead_seconds()`` is that sum — the
<2% of iter-time contract is asserted against it in
``benchmarks/check_regression.py``.
"""
from __future__ import annotations

from typing import Optional

from .clock import Clock, EmulatedClock, WallClock
from .events import EventLog, configure_logging
from .metrics import (BoundedSeries, Counter, Gauge, Histogram, Registry,
                      RunningMean, SelfTime, exponential_buckets,
                      linear_buckets)
from .trace import Tracer, validate_chrome_trace

__all__ = [
    "BoundedSeries", "Clock", "Counter", "EmulatedClock", "EventLog",
    "Gauge", "Histogram", "Registry", "RunningMean", "SelfTime",
    "Telemetry", "Tracer", "WallClock", "configure_logging",
    "exponential_buckets", "linear_buckets", "validate_chrome_trace",
]


class Telemetry:
    """One per server/engine pairing. Construct with an ``EmulatedClock``
    for deterministic testbed runs; default is live wall time."""

    def __init__(self, clock: Optional[Clock] = None, trace: bool = True,
                 trace_maxlen: int = 200_000):
        self.clock = clock or WallClock()
        self.self_time = SelfTime()
        self.registry = Registry(self_time=self.self_time)
        self.tracer = (Tracer(self.clock, self_time=self.self_time,
                              maxlen=trace_maxlen) if trace else None)
        self.log = EventLog(clock=self.clock, tracer=self.tracer)

    def overhead_seconds(self) -> float:
        return self.self_time.seconds
