"""Metrics registry: counters, gauges and fixed-bucket histograms.

Design constraints, in order:

  1. **Bounded memory.** Nothing in here grows with the number of requests
     or steps served. Histograms are fixed bucket arrays; ``BoundedSeries``
     keeps running aggregates over the full history plus a bounded window
     of recent raw values (exact quantiles while the window still holds
     everything, histogram-estimated after it wraps). This is what fixes
     the append-forever lists ``ServingMetrics`` used to carry.
  2. **Cheap on the hot path.** An observation is a few float ops and dict
     writes — no locks, no allocation beyond the first labelset. The
     optional ``SelfTime`` accumulator measures the telemetry layer's own
     host cost so the <2% overhead contract can be asserted from inside
     (see benchmarks/fig_serving.py ``telemetry_sweep``).
  3. **Deterministic exposition.** ``snapshot()`` (JSON) and
     ``to_prometheus()`` (text format) iterate in insertion order with
     sorted labels, so two identical runs — e.g. on the emulated clock —
     export byte-identical artifacts (asserted in tests/test_telemetry.py).
"""
from __future__ import annotations

import math
import time
from collections import deque
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

LabelKey = Tuple[Tuple[str, str], ...]


class SelfTime:
    """Accumulates the host seconds spent inside telemetry calls."""

    __slots__ = ("seconds",)

    def __init__(self):
        self.seconds = 0.0

    def add(self, dt: float):
        self.seconds += dt


def _labelkey(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _labelstr(key: LabelKey) -> str:
    if not key:
        return ""
    esc = [(k, v.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n"))
           for k, v in key]
    return "{" + ",".join(f'{k}="{v}"' for k, v in esc) + "}"


def exponential_buckets(start: float, factor: float, count: int) -> List[float]:
    return [start * factor ** i for i in range(count)]


def linear_buckets(start: float, width: float, count: int) -> List[float]:
    return [start + width * i for i in range(count)]


# 1µs .. ~530s in ~1.78x steps: covers interpreter-scale testbed iterations
# and accelerator-scale microseconds with <2x relative quantile error
DEFAULT_TIME_BUCKETS = exponential_buckets(1e-6, 10 ** 0.25, 35)


class Metric:
    """Base: a named family holding one value per labelset."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 self_time: Optional[SelfTime] = None):
        self.name = name
        self.help = help
        self._st = self_time

    def snapshot_values(self) -> Dict[str, Any]:  # pragma: no cover
        raise NotImplementedError

    def expose(self) -> List[str]:  # pragma: no cover
        raise NotImplementedError


class Counter(Metric):
    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 self_time: Optional[SelfTime] = None):
        super().__init__(name, help, self_time)
        self._v: Dict[LabelKey, float] = {}

    def inc(self, amount: float = 1.0, **labels):
        t0 = time.perf_counter() if self._st is not None else 0.0
        k = _labelkey(labels)
        self._v[k] = self._v.get(k, 0.0) + amount
        if self._st is not None:
            self._st.add(time.perf_counter() - t0)

    def value(self, **labels) -> float:
        return self._v.get(_labelkey(labels), 0.0)

    def snapshot_values(self) -> Dict[str, Any]:
        return {_labelstr(k) or "": v for k, v in sorted(self._v.items())}

    def expose(self) -> List[str]:
        return [f"{self.name}{_labelstr(k)} {v:g}"
                for k, v in sorted(self._v.items())]


class Gauge(Metric):
    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 self_time: Optional[SelfTime] = None,
                 fn: Optional[Callable[[], float]] = None):
        super().__init__(name, help, self_time)
        self._v: Dict[LabelKey, float] = {}
        self._fn = fn  # callback gauge: evaluated at collection time

    def set(self, value: float, **labels):
        t0 = time.perf_counter() if self._st is not None else 0.0
        self._v[_labelkey(labels)] = float(value)
        if self._st is not None:
            self._st.add(time.perf_counter() - t0)

    def value(self, **labels) -> float:
        if self._fn is not None:
            return float(self._fn())
        return self._v.get(_labelkey(labels), 0.0)

    def _items(self) -> List[Tuple[LabelKey, float]]:
        if self._fn is not None:
            return [((), float(self._fn()))]
        return sorted(self._v.items())

    def snapshot_values(self) -> Dict[str, Any]:
        return {_labelstr(k) or "": v for k, v in self._items()}

    def expose(self) -> List[str]:
        return [f"{self.name}{_labelstr(k)} {v:g}" for k, v in self._items()]


class Histogram(Metric):
    """Fixed-bucket histogram with p50/p95/p99-style quantile estimation.

    ``bounds`` are ascending bucket upper edges; an implicit +inf bucket
    catches the tail. Quantiles interpolate linearly inside the selected
    bucket, clamped to the observed min/max — on distributions wider than
    one bucket the estimate is within one bucket width of numpy's
    percentile (asserted against known distributions in
    tests/test_telemetry.py).
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 bounds: Optional[Sequence[float]] = None,
                 self_time: Optional[SelfTime] = None):
        super().__init__(name, help, self_time)
        bs = list(bounds if bounds is not None else DEFAULT_TIME_BUCKETS)
        if any(b2 <= b1 for b1, b2 in zip(bs, bs[1:])):
            raise ValueError(f"histogram bounds must be ascending: {bs}")
        self.bounds = bs
        self.counts = [0] * (len(bs) + 1)
        self.sum = 0.0
        self.count = 0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, value: float):
        t0 = time.perf_counter() if self._st is not None else 0.0
        v = float(value)
        # binary search beats linear scan once bounds get long
        lo, hi = 0, len(self.bounds)
        while lo < hi:
            mid = (lo + hi) // 2
            if v <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        self.sum += v
        self.count += 1
        self._min = min(self._min, v)
        self._max = max(self._max, v)
        if self._st is not None:
            self._st.add(time.perf_counter() - t0)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.bounds[i - 1] if i > 0 else self._min
                hi = self.bounds[i] if i < len(self.bounds) else self._max
                lo, hi = max(lo, self._min), min(hi, self._max)
                if hi <= lo:
                    return lo
                frac = (target - cum) / c
                return lo + frac * (hi - lo)
            cum += c
        return self._max

    def snapshot_values(self) -> Dict[str, Any]:
        return {"buckets": dict(zip([f"{b:g}" for b in self.bounds] + ["+Inf"],
                                    self.counts)),
                "sum": self.sum, "count": self.count,
                "p50": self.quantile(0.5), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}

    def expose(self) -> List[str]:
        lines, cum = [], 0
        for b, c in zip(self.bounds, self.counts):
            cum += c
            lines.append(f'{self.name}_bucket{{le="{b:g}"}} {cum}')
        lines.append(f'{self.name}_bucket{{le="+Inf"}} {self.count}')
        lines.append(f"{self.name}_sum {self.sum:g}")
        lines.append(f"{self.name}_count {self.count}")
        return lines


class Registry:
    """Named metric families, exposed as Prometheus text or a JSON dict."""

    def __init__(self, self_time: Optional[SelfTime] = None):
        self._metrics: Dict[str, Metric] = {}
        self._st = self_time

    def register(self, metric: Metric) -> Metric:
        """Adopt an externally-built metric (idempotent per name; the
        registered instance wins so late registration cannot fork a
        family). Also stitches the registry's self-time accumulator in."""
        existing = self._metrics.get(metric.name)
        if existing is not None:
            if type(existing) is not type(metric):
                raise ValueError(
                    f"metric {metric.name!r} re-registered as a different "
                    f"type ({existing.kind} vs {metric.kind})")
            return existing
        if metric._st is None:
            metric._st = self._st
        self._metrics[metric.name] = metric
        return metric

    def counter(self, name: str, help: str = "") -> Counter:
        return self.register(Counter(name, help))  # type: ignore[return-value]

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self.register(Gauge(name, help))  # type: ignore[return-value]

    def histogram(self, name: str, help: str = "",
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        return self.register(Histogram(name, help, bounds=bounds))  # type: ignore[return-value]

    def callback_gauge(self, name: str, fn: Callable[[], float],
                       help: str = "") -> Gauge:
        """A gauge evaluated lazily at collection time — zero hot-path cost
        for engine-side counters like ``executable_count``."""
        return self.register(Gauge(name, help, fn=fn))  # type: ignore[return-value]

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def snapshot(self) -> Dict[str, Any]:
        return {name: {"type": m.kind, "help": m.help,
                       "values": m.snapshot_values()}
                for name, m in self._metrics.items()}

    def to_prometheus(self) -> str:
        lines: List[str] = []
        for name, m in self._metrics.items():
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            lines.extend(m.expose())
        return "\n".join(lines) + "\n"


class RunningMean:
    """Exact mean over the full history in O(1) memory."""

    __slots__ = ("total", "count")

    def __init__(self):
        self.total = 0.0
        self.count = 0

    def add(self, value: float):
        self.total += float(value)
        self.count += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class BoundedSeries:
    """Append-only numeric series with O(maxlen) memory.

    Running aggregates (sum/count/mean) are exact over the FULL history;
    the window keeps the most recent ``maxlen`` raw values. ``quantile``
    is exact (numpy, linear interpolation) while the history still fits
    the window and falls back to the backing histogram's estimate once it
    has wrapped — the memory-bounded replacement for ServingMetrics'
    append-forever lists. Arrays append element-wise into the aggregates
    (an accept-length vector counts each slot), so ``mean`` reproduces
    ``np.concatenate(...).mean()`` bit-for-bit.
    """

    def __init__(self, maxlen: int = 4096,
                 hist: Optional[Histogram] = None):
        self._window: deque = deque(maxlen=maxlen)
        self.hist = hist
        self.total = 0.0
        self.count = 0

    def append(self, value):
        a = np.asarray(value)
        self.total += float(a.sum())
        self.count += int(a.size)
        self._window.append(value)
        if self.hist is not None:
            for v in a.reshape(-1):
                self.hist.observe(float(v))

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    @property
    def last(self):
        return self._window[-1]

    def quantile(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        if self.count <= self._window.maxlen:
            flat = np.concatenate([np.asarray(v).reshape(-1)
                                   for v in self._window])
            return float(np.percentile(flat, 100.0 * q))
        if self.hist is None:
            raise ValueError("series wrapped and has no backing histogram")
        return self.hist.quantile(q)

    # list-compatibility shims: emulation reads [-1], tests iterate/set()
    def __getitem__(self, idx):
        return self._window[idx]

    def __iter__(self) -> Iterable:
        return iter(self._window)

    def __len__(self) -> int:
        return len(self._window)

    def __bool__(self) -> bool:
        return self.count > 0
