"""Clock abstraction shared by every telemetry consumer.

The serving layer runs in two timing regimes: live (wall clock) and the
emulated testbed (profile-charged costs accumulated by a driver — see
serving/emulation.py). Telemetry must record the regime's OWN time, or the
deterministic benchmark artifacts get polluted with wall-clock noise: a
span recorded at ``perf_counter()`` inside an emulated run would make two
identical runs export different traces. Everything that stamps a time —
the tracer, the event log, request timestamps, ServingMetrics — therefore
goes through one injected ``Clock``.

``WallClock`` is ``time.perf_counter``. ``EmulatedClock`` only moves when
the driver advances it, so all timestamps taken between advances are
identical and bit-reproducible across runs.
"""
from __future__ import annotations

import time


class Clock:
    """Minimal interface: ``now()`` in (fractional) seconds."""

    def now(self) -> float:  # pragma: no cover - interface
        raise NotImplementedError


class WallClock(Clock):
    """Live time: a monotonic high-resolution wall clock."""

    def now(self) -> float:
        return time.perf_counter()


class EmulatedClock(Clock):
    """Manually-advanced clock for deterministic emulated-testbed runs.

    ``now()`` never moves on its own; the emulation driver calls
    ``advance(cost)`` with each profile-charged step cost (and
    ``advance_to(t)`` to jump over idle gaps to the next arrival).
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance an EmulatedClock by {dt}")
        self._t += float(dt)
        return self._t

    def advance_to(self, t: float) -> float:
        """Jump forward to absolute time ``t`` (never backwards)."""
        self._t = max(self._t, float(t))
        return self._t
