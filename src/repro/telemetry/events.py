"""Structured event log sharing the tracer's schema.

One lifecycle event (admission, park, truncation, retirement,
bucket_switch, compile, ...) goes three places from a single ``emit``:

  * the Python ``logging`` tree — as a JSON line (``--log-json``) or
    ``key=value`` text, under logger ``repro.serving``;
  * the tracer — as an instant on the ``events`` track, so the same
    events line up against spans in the Perfetto timeline;
  * nowhere else: metrics are the registry's job, not the log's.

Timestamps come from the injected clock, so emulated runs log emulated
seconds and stay deterministic (modulo the logging sink, which CI points
at a file).
"""
from __future__ import annotations

import json
import logging
from typing import Any, Dict, Optional

from .clock import Clock, WallClock
from .trace import Tracer


class JsonLineFormatter(logging.Formatter):
    """Formats records whose msg is a dict as one JSON line; falls back to
    plain formatting for foreign records."""

    def format(self, record: logging.LogRecord) -> str:
        if isinstance(record.msg, dict):
            return json.dumps(record.msg, sort_keys=True, default=str)
        return super().format(record)


class KeyValueFormatter(logging.Formatter):
    def format(self, record: logging.LogRecord) -> str:
        if isinstance(record.msg, dict):
            d = record.msg
            head = f"[{d.get('ts', 0.0):.6f}] {d.get('event', '?')}"
            rest = " ".join(f"{k}={v}" for k, v in sorted(d.items())
                            if k not in ("ts", "event"))
            return f"{head} {rest}".rstrip()
        return super().format(record)


class EventLog:
    def __init__(self, logger: Optional[logging.Logger] = None,
                 clock: Optional[Clock] = None,
                 tracer: Optional[Tracer] = None):
        self.logger = logger or logging.getLogger("repro.serving")
        self.clock = clock or WallClock()
        self.tracer = tracer

    def emit(self, event: str, level: int = logging.INFO,
             **fields) -> Dict[str, Any]:
        rec = {"ts": self.clock.now(), "event": event, **fields}
        self.logger.log(level, rec)
        if self.tracer is not None:
            self.tracer.instant(event, track="events", **fields)
        return rec


def configure_logging(level: str = "INFO", json_lines: bool = False,
                      stream=None) -> logging.Logger:
    """Set up the ``repro`` logger tree for the CLI: one handler, chosen
    formatter, no propagation to the root logger."""
    logger = logging.getLogger("repro")
    logger.setLevel(getattr(logging, level.upper(), logging.INFO))
    logger.propagate = False
    for h in list(logger.handlers):
        logger.removeHandler(h)
    handler = logging.StreamHandler(stream)
    handler.setFormatter(JsonLineFormatter() if json_lines
                         else KeyValueFormatter("%(message)s"))
    logger.addHandler(handler)
    return logger
