from repro.sharding.specs import (
    DEFAULT_RULES,
    current_mesh,
    named,
    param_shardings,
    shard,
    sharding_divides,
    spec_for,
    use_mesh,
)
