from repro.sharding.specs import (
    DEFAULT_RULES,
    activate,
    current_mesh,
    fsdp_shardings,
    named,
    param_shardings,
    shard,
    sharding_divides,
    sharding_for,
    spec_for,
    use_mesh,
)

__all__ = [
    "DEFAULT_RULES",
    "activate",
    "current_mesh",
    "fsdp_shardings",
    "named",
    "param_shardings",
    "shard",
    "sharding_divides",
    "sharding_for",
    "spec_for",
    "use_mesh",
]
