"""Logical-axis sharding rules.

Model code annotates tensors with *logical* axis names; this module resolves
them to mesh axes under the active mesh. Rules drop automatically when the
dimension is not divisible by the mesh-axis extent (e.g. 8 KV heads on a
16-way model axis), which is how the GQA head_dim-sharding fallback engages.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import ParamDef

# logical axis -> candidate mesh axes (joined as a tuple spec entry)
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "ff": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim_shard": ("model",),   # GQA fallback: shard the head dim
    "ssm_heads": ("model",),
    "experts": (),                  # tensor-parallel experts by default
    "expert_ff": ("model",),
    "cache_seq": ("model",),        # decode KV cache sharded along sequence
    "ssm_inner": ("model",),
    "seq": (),                      # activation sequence kept unsharded
    "d_model": (),
    "layers": (),
}

_ctx = threading.local()


def _state():
    if not hasattr(_ctx, "mesh"):
        _ctx.mesh, _ctx.rules = None, DEFAULT_RULES
    return _ctx


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[Dict[str, Tuple[str, ...]]] = None):
    st = _state()
    prev = (st.mesh, st.rules)
    st.mesh = mesh
    st.rules = {**DEFAULT_RULES, **(rules or {})}
    try:
        yield
    finally:
        st.mesh, st.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _state().mesh


@contextlib.contextmanager
def activate(mesh: Optional[Mesh],
             rules: Optional[Dict[str, Tuple[str, ...]]] = None):
    """Enter a mesh for both this module's logical-axis resolution AND jax's
    own mesh context (so `jax.make_mesh` axis names resolve inside jit).
    No-op when `mesh` is None — callers can wrap unconditionally."""
    if mesh is None:
        yield
        return
    with use_mesh(mesh, rules=rules), mesh:
        yield


def _resolve_entry(logical: Optional[str], dim: int, mesh: Mesh,
                   rules: Dict[str, Tuple[str, ...]], used: set):
    if logical is None:
        return None
    # extent-1 axes shard nothing and jit normalizes them out of reported
    # output specs; keeping them would make device_put placements and jit
    # outputs structurally unequal (an executable-cache miss per call site)
    axes = [a for a in rules.get(logical, ())
            if a in mesh.axis_names and a not in used and mesh.shape[a] > 1]
    if not axes:
        return None
    extent = 1
    for a in axes:
        extent *= mesh.shape[a]
    if dim % extent != 0:
        # partial fallback: try a prefix of the axes that divides
        while axes:
            axes = axes[:-1]
            extent = 1
            for a in axes:
                extent *= mesh.shape[a]
            if axes and dim % extent == 0:
                break
        if not axes:
            return None
    used.update(axes)
    return tuple(axes) if len(axes) > 1 else axes[0]


def spec_for(axes: Sequence[Optional[str]], shape: Sequence[int],
             mesh: Optional[Mesh] = None) -> P:
    mesh = mesh or current_mesh()
    if mesh is None:
        return P()
    rules = _state().rules
    used: set = set()
    entries = [_resolve_entry(ax, dim, mesh, rules, used)
               for ax, dim in zip(axes, shape)]
    # normalize away trailing Nones: jit outputs report truncated specs, and
    # a P(..., None) vs P(...) mismatch is enough to miss the executable
    # cache (a silent recompile) even though the shardings are identical
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Apply a sharding constraint from logical axes; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    assert len(axes) == x.ndim, (axes, x.shape)
    spec = spec_for(axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def sharding_for(axes: Sequence[Optional[str]], shape: Sequence[int],
                 mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    """NamedSharding from logical axes, for `jax.device_put` placement of
    host-built arrays (the eager counterpart of `shard`)."""
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(axes, shape, mesh))


def sharding_divides(logical: str, dim: int, mesh: Optional[Mesh] = None) -> bool:
    """True if the rule would shard `dim` at all (possibly over a prefix of
    its axes, per the divisibility fallback), considering this dim in
    isolation. Mirrors `_resolve_entry` so the predicate always agrees with
    what `spec_for` actually emits."""
    mesh = mesh or current_mesh()
    if mesh is None:
        return True
    return _resolve_entry(logical, dim, mesh, _state().rules, set()) is not None


def param_shardings(defs: Any, mesh: Optional[Mesh] = None) -> Any:
    """NamedSharding pytree matching a ParamDef table."""
    mesh = mesh or current_mesh()

    def one(d: ParamDef):
        if mesh is None:
            return None
        return NamedSharding(mesh, spec_for(d.axes, d.shape, mesh))

    return jax.tree.map(one, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def named(mesh: Mesh, *entries) -> NamedSharding:
    return NamedSharding(mesh, P(*entries))


def fsdp_shardings(defs: Any, mesh: Mesh, axis: str = "data",
                   min_size: int = 2 ** 18) -> Any:
    """FSDP/ZeRO-3-style parameter shardings for training.

    Start from the tensor-parallel spec (`param_shardings`), then for each
    parameter additionally shard its largest still-replicated dim over
    ``axis`` (and ``pod`` when present). XLA sharding propagation inserts the
    per-layer all-gather (forward) / reduce-scatter (backward) — this is what
    lets the 34B–52B assigned archs hold params+grads+opt state on v5e HBM.

    Small tensors (< min_size elements) stay on the TP spec: gathering a norm
    scale per layer costs more latency than the bytes it saves.
    """
    fsdp_axes = tuple(a for a in ("pod", axis) if a in mesh.axis_names)
    extent = 1
    for a in fsdp_axes:
        extent *= mesh.shape[a]

    def one(d: ParamDef):
        spec = list(spec_for(d.axes, d.shape, mesh))
        spec += [None] * (len(d.shape) - len(spec))
        n = 1
        for s in d.shape:
            n *= s
        if extent > 1 and n >= min_size:
            # largest unsharded dim divisible by the fsdp extent
            cands = [(d.shape[i], i) for i in range(len(d.shape))
                     if spec[i] is None and d.shape[i] % extent == 0]
            if cands:
                _, i = max(cands)
                spec[i] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, defs, is_leaf=lambda x: isinstance(x, ParamDef))
