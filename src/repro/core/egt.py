"""Equal-Growth Tree drafting (paper §4.2) plus static tree-template drafting
(SpecInfer k-ary / Sequoia-style / sequence baselines).

The draft loop is a *python* loop over exactly D steps of exactly W nodes, so
the whole thing traces into one static graph per ⟨D, W⟩ bucket. Leaves attach
anywhere in the partial tree: at each step the globally best (node, candidate)
pairs by path log-probability are expanded — generation probabilities as the
acceptance surrogate [OPT-tree 44].
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.tree import TreeArrays, empty_tree
from repro.models.model import Model


class DraftSpec(NamedTuple):
    """Static drafting configuration (hashable -> jit bucket key)."""
    mode: str                 # "egt" | "template"
    depth: int                # D_draft: number of drafter invocations
    width: int                # W_draft: nodes added per step (EGT)
    num_nodes: int            # N = total tree slots
    template_parents: Optional[Tuple[int, ...]] = None
    template_ranks: Optional[Tuple[int, ...]] = None

    @property
    def cand_k(self) -> int:
        if self.mode == "template":
            return max(self.template_ranks) + 1
        return self.width


def egt_spec(depth: int, width: int) -> DraftSpec:
    return DraftSpec("egt", depth, width, 1 + depth * width)


def template_spec(parents, ranks) -> DraftSpec:
    """Build a spec from a static template (see tree.py templates)."""
    import numpy as np
    p = np.asarray(parents)
    r = np.asarray(ranks)
    d = np.zeros(len(p), np.int32)
    for i in range(1, len(p)):
        d[i] = d[p[i]] + 1
    return DraftSpec("template", int(d.max()), 0, len(p),
                     tuple(int(x) for x in p), tuple(int(x) for x in r))


class DraftResult(NamedTuple):
    tree: TreeArrays
    amask: jax.Array        # [B, N, N] ancestor-or-self mask
    draft_probs: jax.Array  # [B, N, V] drafter distribution at each node
    cand_tok: jax.Array     # [B, N, K] top-K continuations per node
    cand_lp: jax.Array      # [B, N, K] their log-probs
    scratch: Dict           # drafter per-layer tree K/V (for cache commit)


def _dist(logits: jax.Array, temperature: float) -> jax.Array:
    if temperature == 0.0:
        return jax.nn.softmax(logits, axis=-1)  # probs used only for ranking
    return jax.nn.softmax(logits / temperature, axis=-1)


def draft_tree(drafter: Model, params, cache: Dict, root_token: jax.Array,
               spec: DraftSpec, temperature: float = 0.0,
               sample_key: Optional[jax.Array] = None) -> DraftResult:
    """Grow a speculation tree on the drafter. Fully static shapes.

    root_token: [B] the confirmed-but-uncommitted head token (slot 0).
    sample_key: when given (temperature > 0), the rank-0 candidate of every
    node is *sampled* from the drafter distribution instead of argmax — this
    makes W=1 chain speculation exactly Leviathan speculative sampling.
    """
    cfg = drafter.cfg
    B = root_token.shape[0]
    N, D, K = spec.num_nodes, spec.depth, spec.cand_k
    V = cfg.vocab_size

    tree = empty_tree(B, N)
    tree = tree._replace(
        tokens=tree.tokens.at[:, 0].set(root_token),
        path_lp=tree.path_lp.at[:, 0].set(0.0),
        live=tree.live.at[:, 0].set(True),
    )
    amask = jnp.zeros((B, N, N), bool).at[:, 0, 0].set(True)
    draft_probs = jnp.zeros((B, N, V), jnp.float32)
    cand_tok = jnp.zeros((B, N, K), jnp.int32)
    cand_lp = jnp.full((B, N, K), -jnp.inf, jnp.float32)
    taken = jnp.zeros((B, N, K), bool)
    scratch = drafter.init_tree_scratch(B, N)

    if sample_key is not None:
        n_calls = 1 + (D if spec.mode == "egt" else D)
        sample_keys = list(jax.random.split(sample_key, n_calls))

    def process(new_tokens, new_depths, rows, offset, q,
                draft_probs, cand_tok, cand_lp, scratch):
        """Run drafter on q new nodes; record their dists and candidates."""
        logits, scratch = drafter.tree_extend(
            params, new_tokens, new_depths, rows, scratch, offset, cache)
        probs = _dist(logits, temperature)                       # [B, q, V]
        lp = jnp.log(jnp.maximum(probs, 1e-30))
        top_lp, top_tok = jax.lax.top_k(lp, K)                    # [B, q, K]
        if sample_key is not None:
            # rank-0 candidate drawn from the drafter distribution
            sk = sample_keys.pop()
            samp = jax.random.categorical(sk, lp, axis=-1).astype(jnp.int32)
            samp_lp = jnp.take_along_axis(lp, samp[..., None], -1)[..., 0]
            top_tok = top_tok.at[..., 0].set(samp)
            top_lp = top_lp.at[..., 0].set(samp_lp)
        draft_probs = jax.lax.dynamic_update_slice_in_dim(
            draft_probs, probs.astype(jnp.float32), offset, axis=1)
        cand_tok = jax.lax.dynamic_update_slice_in_dim(
            cand_tok, top_tok.astype(jnp.int32), offset, axis=1)
        cand_lp = jax.lax.dynamic_update_slice_in_dim(
            cand_lp, top_lp, offset, axis=1)
        return draft_probs, cand_tok, cand_lp, scratch

    # ---- root (the ahead-of-time head draft lives here: see engine) ----
    rows0 = amask[:, 0:1, :]
    draft_probs, cand_tok, cand_lp, scratch = process(
        tree.tokens[:, 0:1], tree.depths[:, 0:1], rows0, 0, 1,
        draft_probs, cand_tok, cand_lp, scratch)

    offset = 1
    if spec.mode == "template":
        import numpy as np
        tpl_p = np.asarray(spec.template_parents)
        tpl_r = np.asarray(spec.template_ranks)
        tpl_d = np.zeros(len(tpl_p), np.int32)
        for i in range(1, len(tpl_p)):
            tpl_d[i] = tpl_d[tpl_p[i]] + 1
        steps = [(lvl, np.nonzero(tpl_d == lvl)[0]) for lvl in range(1, D + 1)]
    else:
        steps = [(s, None) for s in range(1, D + 1)]

    b_idx = jnp.arange(B)[:, None]
    for s, tpl_nodes in steps:
        if spec.mode == "egt":
            w = spec.width
            scores = tree.path_lp[:, :, None] + cand_lp          # [B, N, K]
            scores = jnp.where(tree.live[:, :, None] & ~taken, scores, -jnp.inf)
            top_s, flat = jax.lax.top_k(scores.reshape(B, N * K), w)
            par = (flat // K).astype(jnp.int32)                  # [B, w]
            rank = (flat % K).astype(jnp.int32)
            taken = taken.at[b_idx, par, rank].set(True)
        else:
            w = len(tpl_nodes)
            par = jnp.broadcast_to(jnp.array(tpl_p[tpl_nodes]), (B, w)).astype(jnp.int32)
            rank = jnp.broadcast_to(jnp.array(tpl_r[tpl_nodes]), (B, w)).astype(jnp.int32)
            top_s = (tree.path_lp[b_idx, par]
                     + cand_lp[b_idx, par, rank])

        tok = cand_tok[b_idx, par, rank]                          # [B, w]
        dep = tree.depths[b_idx, par] + 1
        new_slots = offset + jnp.arange(w)[None, :]

        tree = tree._replace(
            tokens=jax.lax.dynamic_update_slice_in_dim(tree.tokens, tok, offset, 1),
            parents=jax.lax.dynamic_update_slice_in_dim(tree.parents, par, offset, 1),
            depths=jax.lax.dynamic_update_slice_in_dim(tree.depths, dep, offset, 1),
            path_lp=jax.lax.dynamic_update_slice_in_dim(
                tree.path_lp, top_s.astype(jnp.float32), offset, 1),
            live=jax.lax.dynamic_update_slice_in_dim(
                tree.live, jnp.ones((B, w), bool), offset, 1),
        )
        # ancestor rows for the new nodes = parent's row + self bit
        parent_rows = amask[b_idx, par]                           # [B, w, N]
        rows = parent_rows.at[jnp.arange(B)[:, None],
                              jnp.arange(w)[None, :], new_slots].set(True)
        amask = jax.lax.dynamic_update_slice(amask, rows, (0, offset, 0))

        draft_probs, cand_tok, cand_lp, scratch = process(
            tok, dep, rows, offset, w, draft_probs, cand_tok, cand_lp, scratch)
        offset += w

    return DraftResult(tree, amask, draft_probs, cand_tok, cand_lp, scratch)
