from repro.core.buckets import Bucket, DEFAULT_BUCKETS, select_bucket
from repro.core.egt import DraftSpec, draft_tree, egt_spec, template_spec
from repro.core.engine import (EngineConfig, GenStats, SpeculativeEngine,
                               generate_autoregressive)
from repro.core.objective import (LatencyProfile, estimate_aal,
                                  speedup_objective)
from repro.core.pruning import dp_prune_reference, topk_prune
from repro.core.tree import (TreeArrays, ancestor_mask, ancestor_paths,
                             chain_template, kary_template)
from repro.core.verify import greedy_accept, stochastic_accept
