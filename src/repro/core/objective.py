"""Latency-aware speedup objective (paper §4.1, Eq. 3).

Speedup(⟨W_d, D_d, W_v⟩) = AAL · T_verify(1)
                           / (T_draft(1) + D_d · T_draft(W_d)
                              + T_verify(W_v) + overhead)

T_draft/T_verify come from hardware profiles (the latency-vs-width curve of
Fig. 5), measured once per (model, runtime) pair by the benchmark harness and
interpolated piecewise-linearly. AAL is estimated from the tree's path
probabilities: E[accepted] ≈ 1 + Σ_kept P(root->node path all accepted),
using drafter probabilities as the acceptance surrogate.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclass
class LatencyProfile:
    """Piecewise-linear latency models for one (drafter, verifier, runtime)."""
    verify_widths: List[int]
    verify_times: List[float]     # seconds per verifier call at width W
    draft_widths: List[int]
    draft_times: List[float]      # seconds per drafter call at width W
    step_overhead: float = 0.0    # fixed per-iteration runtime cost (s)

    def t_verify(self, w) -> float:
        return float(np.interp(w, self.verify_widths, self.verify_times))

    def t_draft(self, w) -> float:
        return float(np.interp(w, self.draft_widths, self.draft_times))

    def save(self, path: str):
        with open(path, "w") as f:
            json.dump(dataclasses.asdict(self), f, indent=2)

    @classmethod
    def load(cls, path: str) -> "LatencyProfile":
        with open(path) as f:
            return cls(**json.load(f))

    @classmethod
    def synthetic(cls, base_verify: float = 1.0, slope: float = 0.01,
                  draft_frac: float = 0.1, saturate_at: int = 32,
                  overhead: float = 0.05) -> "LatencyProfile":
        """An analytic profile with the paper's Fig.5 shape: flat while the
        chip is memory-bound, then linearly increasing once compute saturates."""
        widths = [1, 2, 4, 8, 16, 32, 64, 128, 256]
        def curve(base):
            return [base * (1.0 + slope * max(0, w - saturate_at)) for w in widths]
        return cls(widths, curve(base_verify), widths,
                   curve(base_verify * draft_frac), overhead)


def estimate_aal(path_probs_kept: np.ndarray) -> float:
    """E[accept_len] ≈ 1 (root) + Σ kept non-root path probabilities."""
    return 1.0 + float(np.sum(path_probs_kept))


def ema_update(table: Dict, key, value: float, alpha: float):
    """Keyed EMA: the first observation replaces the (absent) prior, later
    ones blend with weight ``alpha``. Shared by the AAL and iteration-time
    estimators so their seeding semantics cannot drift apart."""
    prev = table.get(key)
    table[key] = (float(value) if prev is None
                  else (1 - alpha) * prev + alpha * float(value))


class AALEstimator:
    """Online per-bucket AAL estimate: an EMA of observed accept lengths.

    Unvisited buckets report the optimistic prior depth+1 (full acceptance),
    which is what pushes an adaptive scheduler to try a bucket once before
    the EMA takes over. The ``estimates`` dict plugs straight into
    ``select_bucket(..., aal_estimates=...)`` / ``choose_config``.
    """

    def __init__(self, alpha: float = 0.3):
        self.alpha = alpha
        self._ema: Dict[Tuple[int, int, int], float] = {}

    def update(self, key: Tuple[int, int, int], observed_aal: float):
        ema_update(self._ema, key, observed_aal, self.alpha)

    def estimate(self, key: Tuple[int, int, int]) -> float:
        depth = key[0]
        return self._ema.get(key, float(depth) + 1.0)

    def estimates(self, keys: Sequence[Tuple[int, int, int]]
                  ) -> Dict[Tuple[int, int, int], float]:
        return {k: self.estimate(k) for k in keys}

    def observed(self, key: Tuple[int, int, int]) -> bool:
        return key in self._ema


def step_latency(profile: LatencyProfile, depth: int, width: int,
                 verify_w: int, batch: int = 1) -> float:
    """Modeled per-iteration latency (the denominator of Eq. 3).

    ``batch`` scales the work fed into the width-latency curves: a pool of
    `batch` active sequences drafts batch·W nodes per level and verifies
    batch·V tree tokens in one dispatch, so a full pool pushes wide/deep
    buckets past the chip's saturation knee while a draining pool keeps
    them in the flat memory-bound region. batch=1 is exactly Eq. 3.
    """
    return (profile.t_draft(batch) + depth * profile.t_draft(batch * width)
            + profile.t_verify(batch * verify_w) + profile.step_overhead)


def speedup_objective(profile: LatencyProfile, aal: float, depth: int,
                      width: int, verify_w: int, batch: int = 1) -> float:
    """Eq. 3 with explicit root-draft and runtime overhead terms. ``batch``
    makes the objective occupancy-aware (see ``step_latency``): the AR
    baseline in the numerator decodes the same `batch` sequences."""
    return (aal * profile.t_verify(batch)
            / step_latency(profile, depth, width, verify_w, batch))


def aal_objective(aal: float, *_args, **_kw) -> float:
    """The naive objective prior work maximizes (ablation baseline)."""
    return aal


def choose_config(profile: LatencyProfile,
                  candidates: Sequence[Tuple[int, int, int]],
                  aal_estimates: Dict[Tuple[int, int, int], float],
                  objective: str = "speedup") -> Tuple[int, int, int]:
    """Pick ⟨D, W, V⟩ maximizing the objective over a candidate bucket set."""
    best, best_v = None, -np.inf
    for (d, w, v) in candidates:
        aal = aal_estimates[(d, w, v)]
        val = (speedup_objective(profile, aal, d, w, v)
               if objective == "speedup" else aal)
        if val > best_v:
            best, best_v = (d, w, v), val
    return best
