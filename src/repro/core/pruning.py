"""Verification-width pruning (paper §4.2): extract the value-maximal subtree
of size W_verify from the drafted tree.

Because a child's path probability never exceeds its parent's, the top-V
nodes by path probability are automatically parent-closed, so the maximum-
value subtree reduces to a (static-shape) top-k — this is the in-graph fast
path. The paper's bottom-up dynamic program is implemented as the host-side
reference (`dp_prune_reference`) and the equivalence is property-tested.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import TreeArrays, gather_subtree


def topk_prune(tree: TreeArrays, v: int, max_depth: int
               ) -> Tuple[TreeArrays, jax.Array]:
    """Select the V best nodes (by path log-prob) as a re-indexed subtree.

    Root is always kept (its path_lp is 0 >= all others). Returns
    (subtree, select_idx [B, V] ascending).
    """
    scores = jnp.where(tree.live, tree.path_lp, -jnp.inf)
    scores = scores.at[:, 0].set(jnp.inf)  # force root
    _, idx = jax.lax.top_k(scores, v)
    select_idx = jnp.sort(idx, axis=-1)    # parents stay before children
    sub, _ = gather_subtree(tree, select_idx, v, max_depth)
    return sub, select_idx


def expected_aal_topv(tree: TreeArrays, v: int) -> jax.Array:
    """[B] estimated AAL if the top-V subtree is verified."""
    scores = jnp.where(tree.live, tree.path_lp, -jnp.inf)
    scores = scores.at[:, 0].set(0.0)
    top, _ = jax.lax.top_k(scores, v)
    probs = jnp.exp(jnp.where(jnp.isfinite(top), top, -jnp.inf))
    # root contributes prob 1; AAL = sum of kept path probs (root incl.)
    return probs.sum(-1)


def dp_prune_reference(parents: np.ndarray, path_probs: np.ndarray,
                       v: int) -> Tuple[np.ndarray, float]:
    """Exact bottom-up tree-knapsack DP (the paper's formulation).

    Maximize Σ path_probs over parent-closed subtrees containing the root
    with at most v nodes. Returns (selected node indices, value).
    """
    n = len(parents)
    children = [[] for _ in range(n)]
    for i in range(1, n):
        if parents[i] >= 0:
            children[parents[i]].append(i)

    memo = {}

    # dp[node] = list over size s of (best value, choice) using exactly s
    # nodes from node's subtree, node included (size >= 1)
    def solve(node):
        if node in memo:
            return memo[node]
        base = np.full(v + 1, -np.inf)
        base[1] = path_probs[node]
        choice = {s: [] for s in range(v + 1)}
        choice[1] = []
        for c in children[node]:
            c_val, c_choice = solve(c)
            new = base.copy()
            new_choice = dict(choice)
            for s in range(1, v + 1):
                if not np.isfinite(base[s]):
                    continue
                for cs in range(1, v + 1 - s):
                    if not np.isfinite(c_val[cs]):
                        continue
                    if base[s] + c_val[cs] > new[s + cs]:
                        new[s + cs] = base[s] + c_val[cs]
                        new_choice[s + cs] = choice[s] + [(c, cs)]
            base, choice = new, new_choice
        memo[node] = (base, choice)
        return base, choice

    import sys
    sys.setrecursionlimit(10000)
    val, choice = solve(0)
    best_s = int(np.nanargmax(np.where(np.isfinite(val), val, -np.inf)))

    # reconstruct
    selected = []

    def collect(node, size):
        selected.append(node)
        _, ch = solve(node)
        for c, cs in ch[size]:
            collect(c, cs)

    collect(0, best_s)
    return np.sort(np.array(selected)), float(val[best_s])
