"""Draft-depth predictor (paper §4.2, O5).

A two-layer MLP encoder over the verifier's last-token hidden state with
multiple classification heads — one per candidate depth bucket — trained
offline on (embedding, achieved accept-length) pairs collected by profiling
an in-domain corpus. At runtime the head scores select D_draft per request.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef, init_params
from repro.training.optimizer import OptConfig, adamw_update, init_opt_state


def predictor_defs(d_model: int, hidden: int, depth_options: Sequence[int]
                   ) -> Dict[str, ParamDef]:
    return {
        "w1": ParamDef((d_model, hidden), (None, None)),
        "b1": ParamDef((hidden,), (None,), init="zeros"),
        "w2": ParamDef((hidden, hidden), (None, None)),
        "b2": ParamDef((hidden,), (None,), init="zeros"),
        "heads": ParamDef((hidden, len(depth_options)), (None, None)),
        "head_b": ParamDef((len(depth_options),), (None,), init="zeros"),
    }


def init_predictor(key, d_model: int, depth_options: Sequence[int],
                   hidden: int = 128):
    return init_params(predictor_defs(d_model, hidden, depth_options), key)


def predictor_logits(p: Dict, h: jax.Array) -> jax.Array:
    """h: [B, d_model] -> [B, num_depth_options]."""
    x = jax.nn.gelu(h @ p["w1"] + p["b1"])
    x = jax.nn.gelu(x @ p["w2"] + p["b2"])
    return x @ p["heads"] + p["head_b"]


def predict_depth(p: Dict, h: jax.Array, depth_options: Sequence[int]
                  ) -> jax.Array:
    """[B] predicted optimal draft depth."""
    idx = jnp.argmax(predictor_logits(p, h), axis=-1)
    return jnp.asarray(depth_options)[idx]


def best_bucket_labels(accept_lens: jax.Array, depth_options: Sequence[int]
                       ) -> jax.Array:
    """Label = smallest depth option >= the achieved accept length (drafting
    deeper than what gets accepted is wasted work; shallower caps AAL)."""
    opts = jnp.asarray(depth_options)                      # [K] ascending
    ge = opts[None, :] >= jnp.minimum(accept_lens[:, None], opts[-1])
    return jnp.argmax(ge, axis=-1)


def train_predictor(key, embeddings: jax.Array, accept_lens: jax.Array,
                    depth_options: Sequence[int], steps: int = 300,
                    batch: int = 64, hidden: int = 128,
                    lr: float = 1e-3) -> Tuple[Dict, List[float]]:
    """Offline training on profiling data. embeddings: [N, d]; accept_lens:
    [N] achieved accepted length with a deep draft."""
    n, d = embeddings.shape
    params = init_predictor(key, d, depth_options, hidden)
    labels = best_bucket_labels(accept_lens, depth_options)
    opt_cfg = OptConfig(lr=lr, warmup_steps=10, total_steps=steps,
                        weight_decay=0.0)
    state = init_opt_state(params)

    @jax.jit
    def step(params, state, idx):
        def lf(p):
            logits = predictor_logits(p, embeddings[idx])
            logz = jax.nn.logsumexp(logits, -1)
            gold = jnp.take_along_axis(logits, labels[idx][:, None], -1)[:, 0]
            return (logz - gold).mean()
        loss, grads = jax.value_and_grad(lf)(params)
        params, state, _ = adamw_update(params, grads, state, opt_cfg)
        return params, state, loss

    losses = []
    k = key
    for i in range(steps):
        k, sk = jax.random.split(k)
        idx = jax.random.randint(sk, (min(batch, n),), 0, n)
        params, state, loss = step(params, state, idx)
        if i % 50 == 0:
            losses.append(float(loss))
    return params, losses
