"""Static baseline tree structures (the paper's comparison set, Fig. 11).

* chain        — sequence speculative decoding (Leviathan).
* k-ary        — SpecInfer top-K expansion at every node.
* sequoia      — dataset-adaptive static tree: given rank-conditional
                 acceptance probabilities measured on a calibration corpus,
                 greedily grow the tree that maximizes expected AAL under a
                 node budget (Sequoia's dynamic program reduces to this
                 greedy under positional independence, which is the
                 assumption its profiling makes).

All return (parents, expand_rank) templates consumable by
``egt.template_spec`` — the same static-shape machinery as EGT, so every
baseline enjoys identical runtime treatment (compiled bucket replay) and
comparisons isolate the *tree structure*.
"""
from __future__ import annotations

import heapq
from typing import List, Sequence, Tuple

import numpy as np


def chain(depth: int) -> Tuple[np.ndarray, np.ndarray]:
    parents = np.arange(-1, depth - 1, dtype=np.int32)
    return parents, np.zeros(depth, np.int32)


def kary(k: int, depth: int) -> Tuple[np.ndarray, np.ndarray]:
    parents: List[int] = [-1]
    ranks: List[int] = [0]
    level = [0]
    nid = 1
    for _ in range(depth):
        nxt = []
        for p in level:
            for r in range(k):
                parents.append(p)
                ranks.append(r)
                nxt.append(nid)
                nid += 1
        level = nxt
    return np.array(parents, np.int32), np.array(ranks, np.int32)


def sequoia(rank_accept: Sequence[float], budget: int,
            max_depth: int = 64) -> Tuple[np.ndarray, np.ndarray]:
    """Greedy expected-AAL-maximal static tree under a node budget.

    rank_accept[r] = P(candidate of rank r is accepted | parent accepted),
    estimated by profiling the drafter/verifier pair on an in-domain corpus
    (see ``measure_rank_accept``). Root occupies slot 0 with prob 1.
    """
    pa = np.asarray(rank_accept, np.float64)
    parents = [-1]
    ranks = [0]
    depth = [0]
    probs = [1.0]
    # heap of candidate expansions: (-path_prob, parent, rank)
    heap: List[Tuple[float, int, int]] = []

    def push(parent: int):
        if depth[parent] + 1 > max_depth:
            return
        for r in range(len(pa)):
            p = probs[parent] * pa[r]
            if p > 0:
                heapq.heappush(heap, (-p, parent, r))

    push(0)
    used = set()
    while len(parents) < budget and heap:
        negp, parent, r = heapq.heappop(heap)
        if (parent, r) in used:
            continue
        used.add((parent, r))
        nid = len(parents)
        parents.append(parent)
        ranks.append(r)
        depth.append(depth[parent] + 1)
        probs.append(-negp)
        push(nid)
    return np.array(parents, np.int32), np.array(ranks, np.int32)


def expected_aal(parents: np.ndarray, ranks: np.ndarray,
                 rank_accept: Sequence[float]) -> float:
    """Analytic E[AAL] of a template under positional independence."""
    pa = np.asarray(rank_accept, np.float64)
    probs = np.ones(len(parents))
    for i in range(1, len(parents)):
        probs[i] = probs[parents[i]] * pa[min(ranks[i], len(pa) - 1)]
    return float(probs.sum())


def measure_rank_accept(drafter, d_params, verifier, v_params, prompts,
                        lengths, *, k: int = 8, iters: int = 24,
                        key=None) -> np.ndarray:
    """Profile P(rank-r draft == verifier greedy) on a calibration corpus.

    Decodes with the verifier (greedy) and at each step asks the drafter for
    its top-k candidates; rank r scores a hit when candidate r matches the
    verifier's next token. This is the Sequoia-style dataset profiling pass.
    """
    import jax
    import jax.numpy as jnp
    from repro.models.cache import make_kv_cache

    B = prompts.shape[0]
    L = int(lengths.max()) + iters + 8
    vcache = make_kv_cache(verifier.cfg).init(B, L)
    dcache = make_kv_cache(drafter.cfg).init(B, L)
    v_logits, vcache, _ = verifier.prefill(v_params, prompts, lengths, vcache)
    d_logits, dcache, _ = drafter.prefill(d_params, prompts, lengths, dcache)

    v_step = jax.jit(lambda p, t, c: verifier.decode(p, t, c))
    d_step = jax.jit(lambda p, t, c: drafter.decode(p, t, c))

    hits = np.zeros(k, np.float64)
    total = 0
    tok = jnp.argmax(v_logits, -1).astype(jnp.int32)
    for _ in range(iters):
        # drafter's top-k candidates for the SAME position `tok` fills
        _, d_top = jax.lax.top_k(d_logits, k)
        hits += np.asarray(d_top == tok[:, None]).sum(0)     # [B, k] hits
        total += B
        v_logits, vcache, _ = v_step(v_params, tok, vcache)
        d_logits, dcache, _ = d_step(d_params, tok, dcache)
        tok = jnp.argmax(v_logits, -1).astype(jnp.int32)
    return hits / max(total, 1)
