"""Tree verification & acceptance.

Greedy mode is exactly lossless versus greedy autoregressive decoding
(property-tested). Stochastic mode implements SpecInfer-style multi-branch
rejection sampling: at each node, children are tried in slot order; on
rejection the target residual is updated p <- norm(max(p - q, 0)). The bonus
token is sampled from the final residual, so every iteration commits at
least one target-distributed token.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.tree import TreeArrays, ancestor_mask, ancestor_paths


class AcceptResult(NamedTuple):
    node_idx: jax.Array    # [B, A_max] accepted chain, front-aligned (root=0)
    accept_len: jax.Array  # [B] >= 1
    bonus: jax.Array       # [B] next confirmed token (target-distributed)
    last_node: jax.Array   # [B] deepest accepted node slot


def _chain_from_last(parents: jax.Array, last: jax.Array, a_max: int,
                     accept_len: jax.Array) -> jax.Array:
    """Front-aligned root->last chain as [B, A_max] (pad trail with last)."""
    paths = ancestor_paths(parents, a_max)                 # [B, N, A_max]
    b_idx = jnp.arange(parents.shape[0])
    chain = paths[b_idx, last]                             # [B, A_max], front-pad -1
    n_pad = a_max - accept_len
    # roll left per batch to front-align
    pos = (jnp.arange(a_max)[None, :] + n_pad[:, None]) % a_max
    chain = jnp.take_along_axis(chain, pos, axis=1)
    # pad tail (beyond accept_len) with the last node (harmless: commit masks)
    chain = jnp.where(jnp.arange(a_max)[None] < accept_len[:, None],
                      chain, last[:, None])
    return chain


def greedy_accept(tree: TreeArrays, target_logits: jax.Array, a_max: int
                  ) -> AcceptResult:
    """tree: V-node pruned subtree; target_logits: [B, V, Vocab]."""
    B, V = tree.tokens.shape
    tgt = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)  # [B, V]
    b_idx = jnp.arange(B)[:, None]
    parent_safe = jnp.clip(tree.parents, 0, V - 1)
    ok = tree.tokens == tgt[b_idx, parent_safe]
    ok = jnp.where(tree.parents >= 0, ok, True) & tree.live      # root ok

    amask = ancestor_mask(tree.parents, a_max)                   # [B, V, V]
    accepted = ~jnp.any(amask & ~ok[:, None, :], axis=-1) & tree.live

    depth_score = jnp.where(accepted, tree.depths, -1)
    last = jnp.argmax(depth_score, axis=-1).astype(jnp.int32)    # [B]
    accept_len = depth_score[jnp.arange(B), last] + 1            # root depth 0
    bonus = tgt[jnp.arange(B), last]
    chain = _chain_from_last(tree.parents, last, a_max, accept_len)
    return AcceptResult(chain, accept_len.astype(jnp.int32), bonus, last)


def _sample_from(probs: jax.Array, key: jax.Array) -> jax.Array:
    return jax.random.categorical(key, jnp.log(jnp.maximum(probs, 1e-30)), axis=-1)


def stochastic_accept(tree: TreeArrays, draft_probs: jax.Array,
                      target_probs: jax.Array, key: jax.Array, a_max: int,
                      max_children: int) -> AcceptResult:
    """Multi-branch rejection sampling (SpecInfer [31], Alg. in §Related).

    draft_probs: [B, V, Vocab] drafter dist at each subtree node;
    target_probs: [B, V, Vocab] verifier dist at each node (temperature-
    adjusted). Root (slot 0) is confirmed by construction.
    """
    B, V = tree.tokens.shape
    b_r = jnp.arange(B)

    # children of each node ordered by slot: [B, V, max_children]
    slot = jnp.arange(V)
    is_child = (tree.parents[:, None, :] == slot[None, :, None]) & tree.live[:, None, :]
    child_order = jnp.argsort(~is_child, axis=-1)[..., :max_children]
    has_child = jnp.take_along_axis(is_child, child_order, axis=-1)
    children = jnp.where(has_child, child_order, -1)       # [B, V, C]

    cur = jnp.zeros((B,), jnp.int32)
    done = jnp.zeros((B,), bool)
    res = target_probs[:, 0]                               # residual at root
    keys = jax.random.split(key, a_max * max_children + 1)
    ki = 0
    for _level in range(a_max - 1):
        moved = jnp.zeros((B,), bool)
        level_children = children[b_r, cur]                # [B, C]
        q_cur = draft_probs[b_r, cur]                      # [B, Vocab]
        for r in range(max_children):
            c_slot = level_children[:, r]
            valid = (c_slot >= 0) & ~done & ~moved
            c_safe = jnp.clip(c_slot, 0, V - 1)
            tok = tree.tokens[b_r, c_safe]
            p_tok = res[b_r, tok]
            q_tok = q_cur[b_r, tok]
            ratio = p_tok / jnp.maximum(q_tok, 1e-30)
            u = jax.random.uniform(keys[ki], (B,)); ki += 1
            accept = valid & (u <= ratio)
            reject = valid & ~accept
            cur = jnp.where(accept, c_safe, cur)
            moved = moved | accept
            # residual update on rejection: p <- norm(max(p - q, 0))
            new_res = jnp.maximum(res - q_cur, 0.0)
            new_res = new_res / jnp.maximum(new_res.sum(-1, keepdims=True), 1e-30)
            res = jnp.where(reject[:, None], new_res, res)
        # descend: residual at the new node restarts from the target dist
        res = jnp.where(moved[:, None], target_probs[b_r, cur], res)
        done = done | ~moved

    bonus = _sample_from(res, keys[ki]).astype(jnp.int32)
    accept_len = tree.depths[b_r, cur] + 1
    chain = _chain_from_last(tree.parents, cur, a_max, accept_len)
    return AcceptResult(chain, accept_len.astype(jnp.int32), bonus, cur)
