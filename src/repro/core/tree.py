"""TokenTree: the static-shape speculation-tree abstraction (paper §6).

A tree over N slots is encoded entirely in *data* (never in shapes):
    tokens   [B, N] int32
    parents  [B, N] int32   (-1 for the root at slot 0; parent < child)
    depths   [B, N] int32   (root = 0)
    path_lp  [B, N] f32     cumulative drafter log-prob of the root->node path
    live     [B, N] bool    slot is populated

All structure helpers are pure jnp and jit-compatible; the equal-growth
invariant (W new nodes per step) keeps every shape static across decoding
iterations, which is what lets the whole speculation step compile once and
be replayed — the EGT/static-runtime bridge of the paper.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class TreeArrays(NamedTuple):
    tokens: jax.Array    # [B, N]
    parents: jax.Array   # [B, N]
    depths: jax.Array    # [B, N]
    path_lp: jax.Array   # [B, N]
    live: jax.Array      # [B, N]


def empty_tree(batch: int, n: int) -> TreeArrays:
    return TreeArrays(
        tokens=jnp.zeros((batch, n), jnp.int32),
        parents=jnp.full((batch, n), -1, jnp.int32),
        depths=jnp.zeros((batch, n), jnp.int32),
        path_lp=jnp.full((batch, n), -jnp.inf, jnp.float32),
        live=jnp.zeros((batch, n), bool),
    )


# --------------------------------------------------------------- masks ----
def ancestor_mask(parents: jax.Array, max_depth: int) -> jax.Array:
    """[B?, N, N] bool: mask[i, j] = j is an ancestor of i or i itself.

    parents: [..., N] with parent index < node index; -1 = no parent.
    """
    n = parents.shape[-1]
    eye = jnp.broadcast_to(jnp.eye(n, dtype=bool), parents.shape + (n,))

    def step(mask, _):
        # mask[i] |= mask[parent[i]]
        safe = jnp.clip(parents, 0, n - 1)
        parent_rows = jnp.take_along_axis(
            mask, safe[..., None].repeat(n, -1), axis=-2)
        upd = jnp.where((parents >= 0)[..., None], mask | parent_rows, mask)
        return upd, None

    mask, _ = jax.lax.scan(step, eye, None, length=max_depth)
    return mask


def node_depths(parents: jax.Array, max_depth: int) -> jax.Array:
    """[..., N] depth of each node (root = 0)."""
    n = parents.shape[-1]
    d = jnp.zeros(parents.shape, jnp.int32)

    def step(d, _):
        safe = jnp.clip(parents, 0, n - 1)
        pd = jnp.take_along_axis(d, safe, axis=-1)
        return jnp.where(parents >= 0, pd + 1, 0), None

    d, _ = jax.lax.scan(step, d, None, length=max_depth)
    return d


def ancestor_paths(parents: jax.Array, max_len: int) -> jax.Array:
    """[..., N, max_len] root->node chains, -1 padded at the FRONT.

    path[i, max_len-1] == i; path[i, max_len-1-d] == d-th ancestor.
    """
    n = parents.shape[-1]
    idx = jnp.broadcast_to(jnp.arange(n), parents.shape)
    cols = [idx]
    cur = idx
    for _ in range(max_len - 1):
        safe = jnp.clip(cur, 0, n - 1)
        cur = jnp.where(cur >= 0, jnp.take_along_axis(parents, safe, axis=-1), -1)
        cols.append(cur)
    # cols[t] = t-th ancestor (0th = self); reverse into front-padded layout
    return jnp.stack(cols[::-1], axis=-1)


def chain_template(depth: int) -> Dict[str, jnp.ndarray]:
    """Sequence speculation = a linear chain of `depth` nodes."""
    parents = jnp.arange(-1, depth - 1, dtype=jnp.int32)
    return {"parents": parents, "expand_rank": jnp.zeros((depth,), jnp.int32)}


def kary_template(k: int, depth: int) -> Dict[str, jnp.ndarray]:
    """SpecInfer-style full k-ary tree template (N = (k^(d+1)-1)/(k-1))."""
    parents = [-1]
    ranks = [0]
    level = [0]
    nid = 1
    for _ in range(depth):
        nxt = []
        for p in level:
            for r in range(k):
                parents.append(p)
                ranks.append(r)
                nxt.append(nid)
                nid += 1
        level = nxt
    return {"parents": jnp.array(parents, jnp.int32),
            "expand_rank": jnp.array(ranks, jnp.int32)}


def template_steps(parents: jnp.ndarray) -> Tuple[Tuple[int, ...], jnp.ndarray]:
    """Group template nodes by depth: returns (#nodes per depth, depths)."""
    import numpy as np
    p = np.asarray(parents)
    d = np.zeros(len(p), np.int32)
    for i in range(1, len(p)):
        d[i] = d[p[i]] + 1
    counts = tuple(int((d == lvl).sum()) for lvl in range(d.max() + 1))
    return counts, jnp.array(d)


def gather_subtree(tree: TreeArrays, select_idx: jax.Array, v: int,
                   max_depth: int) -> Tuple[TreeArrays, jax.Array]:
    """Extract the V selected nodes as a re-indexed tree.

    select_idx: [B, V] node indices sorted ascending (parent-closed: for
    every selected node its parent is selected — guaranteed by monotone
    path probabilities, see pruning.py). Returns (subtree, old->new map).
    """
    b, n = tree.tokens.shape
    b_idx = jnp.arange(b)[:, None]
    # old -> new index map (N entries; unselected -> -1)
    remap = jnp.full((b, n), -1, jnp.int32)
    remap = remap.at[b_idx, select_idx].set(
        jnp.broadcast_to(jnp.arange(v), (b, v)))
    old_parents = tree.parents[b_idx, select_idx]          # [B, V]
    new_parents = jnp.where(
        old_parents >= 0,
        jnp.take_along_axis(remap, jnp.clip(old_parents, 0, n - 1), axis=1),
        -1)
    sub = TreeArrays(
        tokens=tree.tokens[b_idx, select_idx],
        parents=new_parents,
        depths=tree.depths[b_idx, select_idx],
        path_lp=tree.path_lp[b_idx, select_idx],
        live=tree.live[b_idx, select_idx],
    )
    return sub, remap
