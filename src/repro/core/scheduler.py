"""Stage-based scheduling runtime (paper §5).

The speculation iteration decomposes into stages
    head-draft -> grow(D) -> prune -> verify -> accept -> tail-draft -> commit
with a host/device boundary wherever a stage's *control* depends on a prior
stage's *values* (the CPU-logic bubbles of Fig. 9-a). Execution plans differ
in where those boundaries sit:

  * staged        — draft | verify | (host) accept | commit as separate
                    dispatches; acceptance runs on the host (numpy) and a
                    python conditional decides the tail draft, exactly the
                    naive pipeline the paper starts from.
  * staged_device — acceptance stays on device but commit is a separate
                    dispatch (one host sync to read accept_len).
  * fused         — the single megastep: all stages in one graph, the
                    conditional tail/head drafts replaced by ahead-of-time
                    superset computation (§5.1); zero intra-iteration syncs.

`search_plan` is the profile-guided offline search of §5.2: measure each
plan's per-iteration latency on a calibration prompt and pick the argmin
(the dependency graph is small, so exhaustive grid search is exact).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import numpy as np

PLANS = ("staged", "staged_device", "fused")


# ----------------------------------------------------- host-side accept ----
def greedy_accept_host(tokens: np.ndarray, parents: np.ndarray,
                       depths: np.ndarray, live: np.ndarray,
                       tgt_argmax: np.ndarray, a_max: int
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Numpy mirror of verify.greedy_accept (the 'CPU accept management'
    stage of the naive pipeline). Arrays are [B, V]."""
    B, V = tokens.shape
    node_idx = np.zeros((B, a_max), np.int32)
    accept_len = np.ones((B,), np.int32)
    bonus = np.zeros((B,), np.int32)
    last = np.zeros((B,), np.int32)
    for b in range(B):
        cur = 0
        chain = [0]
        while True:
            want = tgt_argmax[b, cur]
            nxt = -1
            for i in range(V):
                if live[b, i] and parents[b, i] == cur and tokens[b, i] == want:
                    nxt = i
                    break
            if nxt < 0 or len(chain) >= a_max:
                break
            cur = nxt
            chain.append(cur)
        accept_len[b] = len(chain)
        bonus[b] = tgt_argmax[b, cur]
        last[b] = cur
        node_idx[b, :len(chain)] = chain
        node_idx[b, len(chain):] = cur
    return node_idx, accept_len, bonus, last


# ------------------------------------------------------------- profiling ----
@dataclass
class StageProfile:
    per_stage: Dict[str, float]          # measured stage latencies (s)
    plan_times: Dict[str, float]         # measured per-iteration latency
    # mesh the profile was measured on: plan choice is mesh-dependent (the
    # staged host boundary now also gathers sharded acceptance results, and
    # fused folds the collectives into one dispatch), so a profile measured
    # unsharded must not silently drive a sharded deployment
    mesh_shape: Optional[Dict[str, int]] = None
    mesh_devices: int = 1

    def predicted(self, dispatch_overhead: float) -> Dict[str, float]:
        """Analytic plan model: staged pays every boundary, fused pays one."""
        s = self.per_stage
        return {
            "staged": (s.get("draft", 0) + s.get("verify", 0)
                       + s.get("host_accept", 0) + s.get("commit", 0)
                       + 4 * dispatch_overhead),
            "staged_device": (s.get("draft", 0) + s.get("verify", 0)
                              + s.get("accept_commit", 0)
                              + 3 * dispatch_overhead),
            "fused": s.get("megastep", 0) + dispatch_overhead,
        }


def time_call(fn: Callable, *args, repeat: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def search_plan(engine, prompt, lengths, *, spec, verify_v,
                iters: int = 16) -> Tuple[str, StageProfile]:
    """Profile every execution plan on a calibration prompt and return the
    best plan plus the measured profile (offline, per §5.2)."""
    times: Dict[str, float] = {}
    orig_plan = engine.cfg.plan
    for plan in PLANS:
        engine.cfg.plan = plan
        _, stats = engine.generate(prompt, lengths, iters, spec=spec,
                                   verify_v=verify_v)
        # drop the first (compile) iteration
        its = stats.iter_times[1:] or stats.iter_times
        times[plan] = float(np.median(its))
    engine.cfg.plan = orig_plan
    minfo = engine.mesh_info()
    prof = StageProfile(per_stage={}, plan_times=times,
                        mesh_shape=minfo["shape"],
                        mesh_devices=minfo["devices"])
    best = min(times, key=times.get)
    return best, prof
