"""SpeculativeEngine: drafter + verifier + EGT + scheduling runtime.

Execution plans (paper §5):
  * "fused"  — one jitted megastep per bucket: draft D×W, prune to V, tree-
    verify, accept, commit BOTH caches, and ahead-of-time stages (the next
    head/tail draft folds into the next megastep's root processing; the
    conditional tail-draft branch is eliminated by unconditional in-graph
    superset compute). Zero host syncs inside an iteration.
  * "staged" — the naive pipeline: draft / verify / accept / commit as
    separate dispatches with a host round-trip on the acceptance result
    driving a conditional tail draft (the CPU-logic bubbles of Fig. 9-a).

Each ⟨D, W, V⟩ bucket compiles exactly once (static shapes via EGT); the
runtime replays executables — the JAX analogue of CUDA-graph replay.

Mesh execution (sharded serving):
  Pass ``mesh=`` (a ``jax.sharding.Mesh`` with ``data``/``model`` axes) and
  the engine becomes mesh-native: drafter/verifier params are placed via the
  logical-axis rules (tensor-parallel on ``model``), both KV caches live
  sharded (slots over ``data``, cache sequence over ``model``), and every
  jitted executable — megastep, staged parts, slot prefill/reset — pins its
  output shardings with explicit constraints so the state that cycles
  through ``decode_step`` keeps one canonical placement. That is what
  preserves the zero-recompile guarantee under slot churn: a drifting
  output sharding would silently retrace the megastep on the next call.

Stepwise API (continuous batching):
  The engine also exposes the decode loop one iteration at a time on an
  explicit ``DecodeState`` (both caches + per-slot roots/progress):

    state = engine.init_decode_state(batch_size)
    state = engine.prefill_into_slot(state, slot, tokens, length)
    state, res = engine.decode_step(state, spec=..., verify_v=...)

  ``prefill_into_slot`` prefills a single batch slot (one compiled B=1
  executable, slot index traced) and scatters it into the batched caches
  without touching the other slots, so a serving loop can retire a finished
  request and refill its slot mid-flight while the megastep keeps replaying
  the same static-shape executable. ``generate`` is a thin wrapper over
  ``decode_step``. See serving/continuous.py for the slot scheduler.
"""
from __future__ import annotations

import time
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pruning, verify
from repro.core.buckets import Bucket, select_bucket
from repro.core.depth_predictor import predict_depth
from repro.core.egt import DraftSpec, draft_tree, egt_spec
from repro.core.objective import LatencyProfile
from repro.core.tree import ancestor_paths
from repro.models import cache as cache_lib
from repro.models.cache import PageState, place_cache
from repro.models.model import Model
from repro.quant import QuantConfig, dequant_params, quantize_params
from repro.sharding import specs as sharding


@dataclass
class EngineConfig:
    temperature: float = 0.0
    plan: str = "fused"            # fused | staged
    accept_mode: str = "auto"      # greedy | stochastic | auto (by temperature)
    objective: str = "speedup"     # speedup | aal (ablation)
    max_target_len: int = 512
    prune: bool = True             # O3 verification-width pruning
    sample_draft: bool = True      # sample rank-0 candidate when temp > 0
    quant: QuantConfig = QuantConfig()  # int8 KV cache / weight-only params
    verify_kernel: Optional[str] = None  # override BOTH models' cached/tree
                                   # attention hot path: "fused" (GQA-native
                                   # length-aware Pallas kernel) | "xla"
                                   # (einsum oracle) | "auto"; None keeps
                                   # each ModelConfig's own setting
    cache_layout: Optional[str] = None  # override BOTH models' decode-cache
                                   # storage: "contiguous" | "paged" (page
                                   # pool + per-slot table with cross-request
                                   # prefix sharing); None keeps each
                                   # ModelConfig's own setting
    page_len: Optional[int] = None  # tokens per pool page (paged layout);
                                   # must divide max_target_len
    cache_pages: int = 0           # paged pool size in pages; 0 = full
                                   # coverage (batch * pages_per_slot + 1)

    def resolve_accept(self) -> str:
        if self.accept_mode != "auto":
            return self.accept_mode
        return "greedy" if self.temperature == 0.0 else "stochastic"


@dataclass
class GenStats:
    accept_lens: List[np.ndarray] = field(default_factory=list)
    iter_times: List[float] = field(default_factory=list)
    buckets: List[Tuple[int, int, int]] = field(default_factory=list)
    compiles: int = 0
    length_capped: bool = False  # stopped at the cache cap before max_new

    @property
    def aal(self) -> float:
        if not self.accept_lens:
            return 0.0
        return float(np.mean(np.concatenate([a.reshape(-1) for a in self.accept_lens])))

    @property
    def tokens_generated(self) -> int:
        return int(sum(a.sum() for a in self.accept_lens))

    @property
    def total_time(self) -> float:
        return float(sum(self.iter_times))

    def summary(self) -> Dict[str, float]:
        return {"aal": self.aal, "iters": len(self.iter_times),
                "tokens": self.tokens_generated, "time_s": self.total_time,
                "tpot_ms": 1e3 * self.total_time / max(self.tokens_generated, 1),
                "compiles": self.compiles,
                "length_capped": self.length_capped}


@dataclass
class DecodeState:
    """Explicit decode-loop state carried between ``decode_step`` calls.

    Device side: both caches (donated every step), the per-slot root token
    (last confirmed token, drafted from next) and its verifier hidden state
    (feeds the depth predictor). Host side: per-slot produced-token counts.
    """
    dcache: Any
    vcache: Any
    root: jax.Array        # [B] int32 last confirmed token per slot
    h_last: jax.Array      # [B, d_verifier] hidden at the last confirmed token
    key: jax.Array
    produced: np.ndarray   # [B] int64 tokens emitted per slot (incl. root)
    pages: Optional[PageState] = None  # host page allocator (paged layout);
                                       # one instance shared by both caches

    @property
    def batch_size(self) -> int:
        return int(self.root.shape[0])


@dataclass
class StepResult:
    """Host-visible outcome of one ``decode_step``.

    ``tokens[b]`` holds the tokens slot b emitted this iteration, front-
    aligned and -1 padded: accepted drafts (the chain minus the already-
    emitted root) followed by the bonus token.
    """
    tokens: np.ndarray      # [B, A_max] int64, -1 padded
    accept_len: np.ndarray  # [B] accepted chain length (>= 1)
    bucket: Tuple[int, int, int]
    iter_time: float

    def mean_accept(self, slots: Optional[List[int]] = None) -> float:
        """Mean accept length this step — over `slots` when given (a serving
        loop passes the active slots so idle garbage decodes don't pollute
        the online AAL estimate)."""
        a = self.accept_len if slots is None else self.accept_len[slots]
        return float(np.mean(a)) if np.size(a) else 0.0


class SpeculativeEngine:
    def __init__(self, drafter: Model, d_params, verifier: Model, v_params,
                 profile: Optional[LatencyProfile] = None,
                 buckets: Optional[Tuple[Bucket, ...]] = None,
                 predictor_params: Optional[Dict] = None,
                 depth_options: Tuple[int, ...] = (2, 4, 8),
                 config: Optional[EngineConfig] = None,
                 mesh: Optional[jax.sharding.Mesh] = None):
        self.drafter, self.d_params = drafter, d_params
        self.verifier, self.v_params = verifier, v_params
        self.profile = profile or LatencyProfile.synthetic()
        self.buckets = buckets
        self.predictor_params = predictor_params
        self.depth_options = depth_options
        self.cfg = config or EngineConfig()
        self.mesh = mesh
        if self.cfg.verify_kernel is not None:
            # one switch for the whole runtime: every cached/tree attention
            # in the megastep, staged parts and slot prefill follows it
            # (kernel dispatch happens per-call in models/attention.py)
            vk = self.cfg.verify_kernel
            if drafter.cfg.verify_kernel != vk:
                self.drafter = Model(drafter.cfg.replace(verify_kernel=vk))
            if verifier.cfg.verify_kernel != vk:
                self.verifier = Model(verifier.cfg.replace(verify_kernel=vk))
        cache_kw = {}
        if self.cfg.cache_layout is not None:
            cache_kw["cache_layout"] = self.cfg.cache_layout
        if self.cfg.page_len is not None:
            cache_kw["page_len"] = self.cfg.page_len
        if cache_kw:
            # cache layout is a runtime choice, not an architecture one: the
            # engine stamps it into both model configs (same rebuild idiom as
            # verify_kernel) so every cache op dispatches consistently
            for attr in ("drafter", "verifier"):
                m = getattr(self, attr)
                if any(getattr(m.cfg, k) != v for k, v in cache_kw.items()):
                    setattr(self, attr, Model(m.cfg.replace(**cache_kw)))
        self.kv_d = cache_lib.make_kv_cache(self.drafter.cfg)
        self.kv_v = cache_lib.make_kv_cache(self.verifier.cfg)
        if self.kv_d.layout != self.kv_v.layout:
            raise ValueError(
                "drafter and verifier must share a cache layout "
                f"({self.kv_d.layout} vs {self.kv_v.layout}) — they commit "
                "identical positions and share one page table")
        self.paged = self.kv_v.layout == "paged"
        if self.paged and self.drafter.cfg.page_len != self.verifier.cfg.page_len:
            raise ValueError("drafter and verifier must share page_len")
        if self.paged:
            self.kv_v.pages_per_slot(self.cfg.max_target_len)  # divisibility
        if mesh is not None:
            # tensor-parallel placement via the logical-axis rules; GQA archs
            # whose kv_heads don't divide the model axis fall back to
            # head-dim sharding (see sharding/specs.py)
            self.d_params = jax.device_put(
                d_params, sharding.param_shardings(drafter.param_defs(), mesh))
            self.v_params = jax.device_put(
                v_params, sharding.param_shardings(verifier.param_defs(), mesh))
        if self.cfg.quant.weights:
            # after mesh placement: QTensor payload/scales inherit the
            # placed weights' shardings elementwise. Every compiled step
            # dequantizes in-graph (dequant_params at the top), so HBM
            # holds int8 while compute stays at the original dtype.
            self.d_params = quantize_params(self.d_params)
            self.v_params = quantize_params(self.v_params)
        self._step_cache: Dict[Any, Any] = {}
        # Executable-cache identity of the sampling config. Keys must carry
        # no raw floats: two bit-different-but-equal temperatures would mint
        # duplicate executables and skew executable_count(), the honest
        # recompile signal. repr() is the canonical shortest form, and
        # temperature 0 collapses to the "greedy" token the sampler
        # special-cases anyway. cfg is frozen after construction (every
        # compiled graph bakes it in), so this is computed once.
        self._cfg_key = (self.cfg.resolve_accept(),
                         "greedy" if self.cfg.temperature == 0.0
                         else repr(float(self.cfg.temperature)),
                         bool(self.cfg.prune), bool(self.cfg.sample_draft))
        self._compile_count = 0
        self.telemetry = None  # opt-in: see attach_telemetry

    # ----------------------------------------------------------- telemetry --
    def attach_telemetry(self, telemetry) -> None:
        """Bind a :class:`repro.telemetry.Telemetry` bundle. Engine counters
        become registry callback gauges (evaluated lazily at collection —
        zero hot-path cost), and every executable build is stamped as a
        tracer instant on the ``engine`` track, tied to the enclosing span
        (so a recompile shows up INSIDE the megastep that caused it)."""
        self.telemetry = telemetry
        if telemetry is None:
            return
        reg = telemetry.registry
        reg.callback_gauge("engine_executable_count", self.executable_count,
                           "traced executables across the step cache")
        reg.callback_gauge("engine_compile_count",
                           lambda: float(self._compile_count),
                           "builder-level executable compiles")
        b = self.cache_bytes_per_slot()
        g = reg.gauge("engine_cache_bytes_per_slot",
                      "device bytes one decode slot pins in both caches")
        for which in ("total", "verifier", "drafter"):
            g.set(b[which], which=which)
        info = reg.gauge("engine_info",
                         "static engine configuration (labels carry values)")
        info.set(1.0, plan=self.cfg.plan, verify_path=self.verify_path(),
                 quant_mode=self.cfg.quant.mode,
                 accept=self.cfg.resolve_accept())

    def _note_compile(self, kind: str) -> None:
        """Every executable build funnels through here: bump the honest
        builder counter, and — when telemetry is attached — count it by
        kind and stamp it into the trace."""
        self._compile_count += 1
        tel = self.telemetry
        if tel is None:
            return
        tel.registry.counter("engine_compiles_total",
                             "executable builds by kind").inc(kind=kind)
        if tel.tracer is not None:
            tel.tracer.instant("compile", track="engine", kind=kind)

    def _tracer(self):
        return self.telemetry.tracer if self.telemetry is not None else None

    # ---------------------------------------------------------------- mesh --
    def _ctx(self):
        """Mesh context every trace/dispatch runs under (no-op unsharded)."""
        return sharding.activate(self.mesh)

    def _put(self, x: jax.Array, *axes: Optional[str]) -> jax.Array:
        """Place an eagerly-built array onto its logical-axis sharding."""
        s = sharding.sharding_for(axes, x.shape, self.mesh)
        return x if s is None else jax.device_put(x, s)

    def _constrain_state(self, dcache, vcache, root, h_last):
        """In-graph sharding pins for everything that cycles through the
        decode loop; keeps executables' output placements canonical so
        repeated calls never retrace. No-op without a mesh."""
        if self.mesh is None:
            return dcache, vcache, root, h_last
        return (cache_lib.shard_cache(dcache), cache_lib.shard_cache(vcache),
                sharding.shard(root, "batch"),
                sharding.shard(h_last, "batch", None))

    def mesh_info(self) -> Dict[str, Any]:
        """Mesh placement summary for logs/benchmark artifacts."""
        if self.mesh is None:
            return {"devices": 1, "shape": None}
        return {"devices": int(self.mesh.devices.size),
                "shape": {k: int(v) for k, v in self.mesh.shape.items()}}

    def verify_path(self) -> str:
        """Which cached/tree-attention implementation the VERIFIER's
        megastep resolves to — "fused" (the GQA-native length-aware Pallas
        kernel) or "xla" (the einsum oracle) — via the same predicate
        ``cached_attention`` dispatches on, so this can't drift from the
        real hot path. (A sliding-window drafter can individually fall back
        to xla while the verifier stays fused.)"""
        from repro.models.attention import fused_dispatch_ok
        return ("fused" if fused_dispatch_ok(
            self.verifier.cfg, mesh_active=self.mesh is not None) else "xla")

    # ------------------------------------------------------------- quant --
    def _kv_dtype(self):
        """KV-cache storage dtype for KVCache.init (None = compute dtype)."""
        return jnp.int8 if self.cfg.quant.kv_int8 else None

    def cache_bytes_per_slot(self, live_tokens: Optional[int] = None
                             ) -> Dict[str, int]:
        """Device bytes ONE decode slot pins in both caches — the quantity
        serving capacity planning divides an HBM budget by (see
        serving.continuous.slots_at_budget).

        Contiguous slots pin their full ``max_target_len`` row regardless of
        occupancy. Paged slots pin only mapped pages, so the price is
        ``ceil(live_tokens / page_len)`` pages (``live_tokens=None`` prices
        worst case: full virtual coverage) plus the per-slot table row —
        this repricing is where the paged layout's capacity win comes from.
        """
        L = self.cfg.max_target_len
        kv_dt = self._kv_dtype()
        if self.paged:
            t_rows = self.kv_v.pages_per_slot(L)
            if live_tokens is None:
                pages = t_rows
            else:
                pages = min(-(-int(live_tokens) // self.kv_v.page_len), t_rows)
            row = 4 * t_rows + 4  # table row + length
            v = pages * self.kv_v.page_nbytes(kv_dtype=kv_dt) + row
            d = pages * self.kv_d.page_nbytes(kv_dtype=kv_dt) + row
        else:
            v = self.kv_v.nbytes(1, L, kv_dtype=kv_dt)
            d = self.kv_d.nbytes(1, L, kv_dtype=kv_dt)
        return {"verifier": v, "drafter": d, "total": v + d}

    def _n_pages(self, batch: int) -> int:
        """Pool size for a batch-``batch`` paged decode state."""
        return (self.cfg.cache_pages
                or self.kv_v.default_pages(batch, self.cfg.max_target_len))

    def executable_count(self) -> int:
        """Total traced executables across the step cache — unlike
        ``_compile_count`` this also sees silent jit retraces (e.g. an input
        sharding drifting under a mesh), so the serving layer can assert the
        zero-recompile contract honestly."""
        n = 0
        for entry in self._step_cache.values():
            fns = entry.values() if isinstance(entry, dict) else (entry,)
            for f in fns:
                size = getattr(f, "_cache_size", None)
                n += int(size()) if callable(size) else 0
        return n

    # ------------------------------------------------------------ prefill --
    def prefill(self, tokens: jax.Array, lengths: jax.Array,
                enc_feats: Optional[jax.Array] = None):
        if self.paged:
            raise NotImplementedError(
                "batched prefill requires the contiguous layout — paged "
                "serving admits through the stepwise slot API "
                "(prefill_into_slot / prefill_chunk_into_slot)")
        B = tokens.shape[0]
        L = self.cfg.max_target_len
        kv_dt = self._kv_dtype()
        with self._ctx():
            tokens = self._put(jnp.asarray(tokens), "batch", None)
            lengths = self._put(jnp.asarray(lengths), "batch")
            vcache = place_cache(self.kv_v.init(B, L, kv_dtype=kv_dt),
                                 self.mesh)
            dcache = place_cache(self.kv_d.init(B, L, kv_dtype=kv_dt),
                                 self.mesh)
            # batch prefill runs eagerly (it always has), so in w8 mode this
            # dequant materializes a transient fp32 param copy for the call;
            # the hot paths — megastep, staged parts, slot prefill — all
            # dequantize INSIDE their compiled graphs instead, which is
            # where the serving loop spends its life.
            v_logits, vcache, h_last = self.verifier.prefill(
                dequant_params(self.v_params), tokens, lengths, vcache,
                enc_feats=enc_feats)
            _, dcache, _ = self.drafter.prefill(
                dequant_params(self.d_params), tokens, lengths, dcache)
            # pin the eager outputs to the canonical decode-loop placement so
            # the first decode_step compiles against the same shardings every
            # later step reproduces
            vcache = place_cache(vcache, self.mesh)
            dcache = place_cache(dcache, self.mesh)
            h_last = self._put(h_last, "batch", None)
        return v_logits, vcache, dcache, h_last

    # ------------------------------------------------------ stepwise API --
    def init_decode_state(self, batch_size: int,
                          key: Optional[jax.Array] = None) -> DecodeState:
        """Empty decode state: zeroed caches, no slot holds a request yet.
        Under the paged layout both caches share one pool geometry and one
        host ``PageState`` (drafter and verifier commit identical
        positions, so a single page table serves both pools)."""
        L = self.cfg.max_target_len
        kv_dt = self._kv_dtype()
        pages = None
        n_pages = self._n_pages(batch_size) if self.paged else 0
        if self.paged:
            pages = self.kv_v.make_page_state(batch_size, L, pages=n_pages)
        with self._ctx():
            return DecodeState(
                dcache=place_cache(
                    self.kv_d.init(batch_size, L, kv_dtype=kv_dt,
                                   pages=n_pages), self.mesh),
                vcache=place_cache(
                    self.kv_v.init(batch_size, L, kv_dtype=kv_dt,
                                   pages=n_pages), self.mesh),
                root=self._put(jnp.zeros((batch_size,), jnp.int32), "batch"),
                h_last=self._put(
                    jnp.zeros((batch_size, self.verifier.cfg.d_model),
                              jnp.float32), "batch", None),
                key=key if key is not None else jax.random.PRNGKey(0),
                produced=np.zeros((batch_size,), np.int64),
                pages=pages)

    # ------------------------------------------------------- paged sync --
    PAGE_CLEAR_CHUNK = 64  # fixed clear-executable width (static shape)

    def _drain_page_clears(self, state: DecodeState) -> None:
        """Run queued device pos-clears for freed pages — the device half of
        the 'free pages are always clean' invariant. Must complete before a
        recycled page's new mapping is dispatched; every paged dispatch
        funnels through ``_paged_sync`` which calls this first. One fixed-
        width executable (ids padded with the trash page, whose pos lanes
        are clear-safe by construction), so drains never retrace."""
        ps = state.pages
        if not ps.pending_clear:
            return
        ck = ("page_clear",)
        if ck not in self._step_cache:
            def _clear(dc, vc, ids):
                return (cache_lib.shard_cache(self.kv_d.clear_pages(dc, ids)),
                        cache_lib.shard_cache(self.kv_v.clear_pages(vc, ids)))
            self._step_cache[ck] = jax.jit(_clear, donate_argnums=(0, 1))
            self._note_compile("page_clear")
        fn = self._step_cache[ck]
        todo, ps.pending_clear = ps.pending_clear, []
        K = self.PAGE_CLEAR_CHUNK
        with self._ctx():
            for i in range(0, len(todo), K):
                chunk = todo[i:i + K]
                ids = np.full((K,), cache_lib.TRASH_PAGE, np.int32)
                ids[:len(chunk)] = chunk
                state.dcache, state.vcache = fn(state.dcache, state.vcache,
                                                jnp.asarray(ids))

    def _push_tables(self, state: DecodeState) -> None:
        """Refresh the device page-table leaf of BOTH caches from the host
        allocator. Two separate device arrays on purpose: the megastep
        donates both cache pytrees, and a shared buffer would be donated
        twice. Dict-level update — no executable, no retrace (the pytree
        structure and the [B, T] shape never change)."""
        ps = state.pages
        tbl = np.ascontiguousarray(ps.table)
        state.dcache = {**state.dcache,
                        "table": self._put(jnp.asarray(tbl), "batch", None)}
        state.vcache = {**state.vcache,
                        "table": self._put(jnp.asarray(np.array(tbl)),
                                           "batch", None)}

    def _paged_sync(self, state: DecodeState, grow: int = 0) -> None:
        """Pre-dispatch barrier for every paged executable: grow live
        slots' mappings to cover the tokens the dispatch may commit
        (``host_len + grow``), clear recycled pages, and mirror the table
        to the device."""
        ps = state.pages
        if grow:
            for b in range(ps.batch):
                if ps.live[b]:
                    ps.ensure(b, int(ps.host_len[b]) + grow)
        self._drain_page_clears(state)
        self._push_tables(state)

    def adopt_prefix(self, state: DecodeState, slot: int,
                     tokens: np.ndarray, length: int) -> int:
        """Paged admission: map the longest resident shared-prefix pages
        into (freshly reset) slot ``slot`` and return the hit length in
        tokens — prefill then starts at ``hit`` instead of 0. Remembers the
        prompt so the finishing prefill chunk can publish the slot's own
        full pages to the ``PrefixStore``. Contiguous layout: no-op, 0.

        A serving loop that chunks the prefill MUST call this immediately
        before dispatching the slot's FIRST chunk (not earlier): until the
        first chunk pins the slot's committed length past the shared rows,
        an interleaved garbage megastep over the empty slot would write
        positions 0.. straight into the shared pages.
        """
        if not self.paged:
            return 0
        ps = state.pages
        if ps.mapped[slot] or ps.live[slot]:
            ps.release(slot)  # stale mapping from an un-reset slot
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)[:int(length)]]
        hit = ps.store.adopt(slot, toks)
        ps.pending_prompt[slot] = toks
        ps.host_len[slot] = hit
        return hit

    def _build_slot_prefill(self):
        """One compiled executable that prefills a batch-1 prompt and
        scatters it into a (traced) batch slot of the live caches. Shape
        specialization per prompt length comes from jit retracing; the
        per-pad cache key in `prefill_into_slot` only tracks the compile
        count honestly."""
        if self.verifier.cfg.is_encoder_decoder:
            raise NotImplementedError(
                "slot prefill does not support encoder-decoder models yet")
        assert not self.paged, (
            "monolithic slot prefill builds a private B=1 cache and cannot "
            "target the shared page pool; paged prefill_into_slot routes "
            "through the chunk executable instead")
        L = self.cfg.max_target_len
        kv_dt = self._kv_dtype()

        def fn(d_params, v_params, dcache, vcache, root, h_last,
               tokens, length, slot, key):
            d_params = dequant_params(d_params)
            v_params = dequant_params(v_params)
            vc1 = self.kv_v.init(1, L, kv_dtype=kv_dt)
            dc1 = self.kv_d.init(1, L, kv_dtype=kv_dt)
            v_logits, vc1, h1 = self.verifier.prefill(
                v_params, tokens, length, vc1)
            _, dc1, _ = self.drafter.prefill(d_params, tokens, length, dc1)
            tok = self._sample(v_logits, key)
            vcache = self.kv_v.merge_slot(vcache, slot, vc1)
            dcache = self.kv_d.merge_slot(dcache, slot, dc1)
            root = jax.lax.dynamic_update_index_in_dim(root, tok[0], slot, 0)
            h_last = jax.lax.dynamic_update_index_in_dim(
                h_last, h1[0].astype(h_last.dtype), slot, 0)
            return self._constrain_state(dcache, vcache, root, h_last)

        return jax.jit(fn, donate_argnums=(2, 3, 4, 5))

    def prefill_into_slot(self, state: DecodeState, slot: int,
                          tokens: np.ndarray, length: int) -> DecodeState:
        """Prefill one prompt into batch slot `slot` of `state`, leaving the
        other slots untouched. `tokens` is a [P] right-padded prompt; every
        distinct P compiles once, so a serving loop should pad to a fixed
        prompt length. The slot's first generated token (sampled from the
        prompt's last-position logits) lands in ``state.root[slot]``."""
        pad = int(np.shape(tokens)[-1])
        if not 0 <= int(length) <= pad:
            # the scalar-prefetched `lengths` driving kv-block skipping in
            # the fused kernel derive from this value: a length past the
            # written token extent would make invisible garbage visible
            raise ValueError(f"prompt length {length} disagrees with the "
                             f"padded prompt width {pad}")
        if self.paged:
            # the shared pool can't be populated from a private B=1 cache;
            # run the prompt as a single final chunk through the slot-view
            # machinery, skipping any resident shared prefix. One executable
            # per pad width, exactly like the monolithic path.
            hit = self.adopt_prefix(state, slot, tokens, length)
            chunk = np.zeros((pad,), np.int32)
            valid = int(length) - hit
            chunk[:valid] = np.asarray(tokens).reshape(-1)[hit:length]
            return self.prefill_chunk_into_slot(state, slot, chunk,
                                                start=hit, valid=valid,
                                                final=True)
        tr = self._tracer()
        if tr is not None:
            tr.begin("slot_prefill", track="engine", slot=int(slot), pad=pad)
        ck = ("slot_prefill", pad, self._cfg_key)
        if ck not in self._step_cache:
            self._step_cache[ck] = self._build_slot_prefill()
            self._note_compile("slot_prefill")
        fn = self._step_cache[ck]
        key, sk = jax.random.split(state.key)
        with self._ctx():
            dcache, vcache, root, h_last = fn(
                self.d_params, self.v_params, state.dcache, state.vcache,
                state.root, state.h_last,
                jnp.asarray(tokens, jnp.int32).reshape(1, pad),
                jnp.asarray([length], jnp.int32),
                jnp.asarray(slot, jnp.int32), sk)
        if tr is not None:
            tr.end(track="engine")
        produced = state.produced.copy()
        produced[slot] = 1  # the root token is the slot's first output
        return DecodeState(dcache, vcache, root, h_last, key, produced)

    def _build_slot_prefill_chunk(self, chunk_len: int):
        """One compiled executable that advances a single slot's prefill by
        one fixed-width chunk. The chunk is run as a depth-``chunk_len``
        CHAIN through ``tree_verify`` (depths = arange, causal lower-
        triangular tree mask), so RoPE positions and attention visibility
        are exactly what a monolithic prefill computes for the same tokens,
        and ``commit`` lands the accepted prefix in the slot's caches at
        positions ``start + j``. Everything that varies per call — tokens,
        start cursor, valid count, slot, finality, PRNG key — is traced, so
        one chunk length compiles exactly once.

        The slot's committed length is pinned to the host-side ``start``
        cursor on entry: decode megasteps keep running over mid-prefill
        slots (garbage output, static batch shape), advancing the device
        length counter and scribbling entries at positions >= start — all
        of which the next chunk overwrites position-for-position before
        ``visible_mask`` could ever expose it (an entry is visible only
        below the committed length, and committing position p rewrites
        cache slot p in the same dispatch that makes it visible).
        """
        if self.verifier.cfg.is_encoder_decoder:
            raise NotImplementedError(
                "chunked prefill does not support encoder-decoder models")
        for m in (self.verifier, self.drafter):
            if any(m.cfg.layer_mixer(i) == "ssm"
                   for i in range(m.cfg.num_layers)):
                raise NotImplementedError(
                    "chunked prefill requires attention-only models: SSM "
                    "recurrent state is not position-addressed, so the "
                    "garbage decode megasteps interleaved between chunks "
                    "could not be overwritten by the next chunk")
            if m.cfg.sliding_window:
                raise NotImplementedError(
                    "chunked prefill does not support sliding-window "
                    "caches: a garbage decode entry at position g wraps "
                    "onto ring slot g %% S and destroys the committed "
                    "entry at g - S, which queries below g still attend")
        C = chunk_len
        depths = jnp.arange(C, dtype=jnp.int32)[None]          # [1, C] chain
        amask = jnp.tril(jnp.ones((C, C), bool))[None]         # causal
        node_idx = jnp.arange(C, dtype=jnp.int32)[None]

        def fn(d_params, v_params, dcache, vcache, root, h_last,
               chunk, start, valid, slot, is_final, key):
            d_params = dequant_params(d_params)
            v_params = dequant_params(v_params)
            vc1 = self.kv_v.slot_view(vcache, slot)
            dc1 = self.kv_d.slot_view(dcache, slot)
            start_b = jnp.reshape(start, (1,)).astype(jnp.int32)
            vc1 = {**vc1, "length": start_b}   # pin to the host cursor (see
            dc1 = {**dc1, "length": start_b}   # docstring: garbage decode)
            valid_b = jnp.reshape(valid, (1,)).astype(jnp.int32)
            v_logits, v_scratch, h_nodes = self.verifier.tree_verify(
                v_params, chunk, depths, amask, vc1)
            vc1 = self.verifier.commit(vc1, v_scratch, node_idx, valid_b)
            _, d_scratch, _ = self.drafter.tree_verify(
                d_params, chunk, depths, amask, dc1)
            dc1 = self.drafter.commit(dc1, d_scratch, node_idx, valid_b)
            vcache = self.kv_v.merge_slot(vcache, slot, vc1)
            dcache = self.kv_d.merge_slot(dcache, slot, dc1)
            # the final chunk samples the slot's first output token from the
            # last VALID node's logits (a partial tail chunk pads past it;
            # padded nodes never feed anything — causal mask) and lands it
            # in root/h_last; non-final chunks leave both untouched so the
            # same executable serves every chunk of the prompt
            last = jnp.clip(valid - 1, 0, C - 1)
            tok = self._sample(jnp.take(v_logits[0], last, axis=0)[None], key)
            h1 = jnp.take(h_nodes[0], last, axis=0)
            fin = jnp.reshape(is_final, ())
            root = jnp.where(
                fin, jax.lax.dynamic_update_index_in_dim(root, tok[0], slot, 0),
                root)
            h_last = jnp.where(
                fin, jax.lax.dynamic_update_index_in_dim(
                    h_last, h1.astype(h_last.dtype), slot, 0),
                h_last)
            return self._constrain_state(dcache, vcache, root, h_last)

        return jax.jit(fn, donate_argnums=(2, 3, 4, 5))

    def prefill_chunk_into_slot(self, state: DecodeState, slot: int,
                                chunk_tokens: np.ndarray, start: int,
                                valid: int, final: bool) -> DecodeState:
        """Advance slot ``slot``'s prefill by one chunk: commit
        ``chunk_tokens[:valid]`` at positions ``start..start+valid`` of both
        caches. ``final=True`` additionally samples the slot's first output
        token into ``state.root[slot]`` (and its hidden state into
        ``h_last``), exactly like the tail of ``prefill_into_slot``.

        The executable-cache key is ``(kind, chunk_len)`` ONLY — start,
        valid, slot, finality and the key are traced — so a serving loop
        that warms each chunk length once replays cached executables for
        any prompt length, chunk count or slot thereafter.
        """
        C = int(np.shape(chunk_tokens)[-1])
        if not 0 <= int(valid) <= C:
            raise ValueError(f"valid={valid} outside the chunk width {C}")
        if int(start) < 0 or int(start) + int(valid) > self.cfg.max_target_len:
            raise ValueError(f"chunk [{start}, {start}+{valid}) overflows "
                             f"max_target_len={self.cfg.max_target_len}")
        tr = self._tracer()
        if tr is not None:
            tr.begin("slot_prefill_chunk", track="engine", slot=int(slot),
                     chunk=C, start=int(start), final=bool(final))
        ck = ("slot_prefill_chunk", C)
        if ck not in self._step_cache:
            self._step_cache[ck] = self._build_slot_prefill_chunk(C)
            self._note_compile("slot_prefill_chunk")
        fn = self._step_cache[ck]
        if self.paged:
            ps = state.pages
            ps.ensure(slot, int(start) + int(valid))
            self._paged_sync(state)
        key, sk = jax.random.split(state.key)
        with self._ctx():
            dcache, vcache, root, h_last = fn(
                self.d_params, self.v_params, state.dcache, state.vcache,
                state.root, state.h_last,
                jnp.asarray(chunk_tokens, jnp.int32).reshape(1, C),
                jnp.asarray(start, jnp.int32), jnp.asarray(valid, jnp.int32),
                jnp.asarray(slot, jnp.int32), jnp.asarray(bool(final)), sk)
        if tr is not None:
            tr.end(track="engine")
        produced = state.produced.copy()
        if final:
            produced[slot] = 1  # the root token is the slot's first output
        if self.paged:
            ps.host_len[slot] = int(start) + int(valid)
            if final:
                ps.live[slot] = True
                toks = ps.pending_prompt.pop(slot, None)
                if toks is not None:
                    ps.store.register(slot, toks)
        return DecodeState(dcache, vcache, root, h_last, key, produced,
                           pages=state.pages)

    def reset_state_slot(self, state: DecodeState, slot: int) -> DecodeState:
        """Clear batch slot `slot` of both caches (length 0, positions -1,
        SSM state zeroed) without touching the other slots. The emptied slot
        keeps decoding harmlessly (tree nodes always see themselves, so no
        all-masked attention rows); its output is garbage until the next
        ``prefill_into_slot``. One compiled executable, slot index traced.

        Paged: the slot's pages are released on the host allocator first —
        pages whose refcount drops to zero (not shared via the prefix
        store) queue a device pos-clear that the same call drains — then
        the executable zeroes the length and points the slot's table row at
        the trash page."""
        ck = ("slot_reset",)
        if ck not in self._step_cache:
            def _reset(dc, vc, s):
                return (cache_lib.shard_cache(self.kv_d.reset_slot(dc, s)),
                        cache_lib.shard_cache(self.kv_v.reset_slot(vc, s)))
            self._step_cache[ck] = jax.jit(_reset, donate_argnums=(0, 1))
            self._note_compile("slot_reset")
        if self.paged:
            state.pages.release(slot)
            self._paged_sync(state)
        with self._ctx():
            dcache, vcache = self._step_cache[ck](
                state.dcache, state.vcache, jnp.asarray(slot, jnp.int32))
        produced = state.produced.copy()
        produced[slot] = 0
        return DecodeState(dcache, vcache, state.root, state.h_last,
                           state.key, produced, pages=state.pages)

    def warmup_buckets(self, state: DecodeState,
                       buckets: Tuple[Bucket, ...],
                       ) -> Tuple[DecodeState, Dict[Tuple[int, int, int], float]]:
        """Compile the megastep for EVERY ladder bucket on the live state
        (two steps each: the first traces, the second replays to measure a
        steady-state iteration time). This is what lets an adaptive serving
        loop switch buckets later without ever compiling on the decode
        path. Returns the advanced state and per-bucket replay times."""
        times: Dict[Tuple[int, int, int], float] = {}
        for b in buckets:
            spec = egt_spec(b.depth, b.width)
            state, _ = self.decode_step(state, spec=spec, verify_v=b.verify)
            state, res = self.decode_step(state, spec=spec, verify_v=b.verify)
            times[b.key()] = res.iter_time
        return state, times

    # one-shot fault injection: the next decode_step raises NumericalFault
    # exactly as if the verifier had emitted non-finite logits
    _poison_numerical = False

    def poison_next_step(self) -> None:
        """Arm a one-shot NumericalFault on the next decode_step (fault
        injection for the serving recovery path — no graph change)."""
        self._poison_numerical = True

    def decode_step(self, state: DecodeState,
                    spec: Optional[DraftSpec] = None,
                    verify_v: Optional[int] = None,
                    ) -> Tuple[DecodeState, StepResult]:
        """Run one speculation iteration over every slot and return the
        tokens each slot emitted. Shapes are static given the bucket, so
        repeated calls replay one compiled megastep regardless of slot
        churn. Input caches are donated — use the returned state."""
        cfg = self.cfg
        if spec is not None:
            use_spec, use_v = spec, (verify_v or spec.num_nodes)
        else:
            use_spec, use_v = self._select(state.h_last)
        if self.paged:
            # live slots may commit up to depth+1 tokens this step: map the
            # covering pages now (host allocator), clear recycled ones, and
            # mirror the table. Parked/mid-prefill slots stay unmapped —
            # their garbage writes land in the trash page.
            self._paged_sync(state, grow=use_spec.depth + 1)
        key, sk = jax.random.split(state.key)
        tr = self._tracer()
        if tr is not None:
            # opened before _get_step so a (contract-violating) compile's
            # instant nests inside the megastep it happened in
            tr.begin("megastep", track="engine", plan=cfg.plan,
                     bucket=f"{use_spec.depth}x{use_spec.width}x{use_v}")
        t0 = time.perf_counter()
        with self._ctx():
            if cfg.plan == "fused":
                step = self._get_step(use_spec, use_v)
                if tr is not None:
                    # fused has no host-visible stage boundaries by design:
                    # one span from dispatch to the accept-length sync
                    tr.begin("device", track="engine")
                (dcache, vcache, bonus, toks, alen, h_last, finite) = step(
                    self.d_params, self.v_params, state.dcache, state.vcache,
                    state.root, sk)
            else:
                parts = self._get_staged_parts(use_spec, use_v)
                (dcache, vcache, bonus, toks, alen, h_last,
                 finite) = self._run_staged(
                    parts, state.dcache, state.vcache, state.root, sk,
                    tracer=tr)
        alen_np = np.asarray(alen)
        if tr is not None and cfg.plan == "fused":
            tr.end(track="engine")  # device: closes at the accept-len sync
        t1 = time.perf_counter()
        if tr is not None:
            tr.begin("host", track="engine")
        toks_np, bonus_np = np.asarray(toks), np.asarray(bonus)
        B, a_max = toks_np.shape
        emit = np.full((B, a_max), -1, np.int64)
        for b in range(B):
            a = int(alen_np[b])
            emit[b, : a - 1] = toks_np[b, 1: a]
            emit[b, a - 1] = bonus_np[b]
        if self.paged:
            live = state.pages.live
            state.pages.host_len[live] += alen_np[live]
        new_state = DecodeState(dcache, vcache, bonus, h_last, key,
                                state.produced + alen_np, pages=state.pages)
        res = StepResult(tokens=emit, accept_len=alen_np,
                         bucket=(use_spec.depth, use_spec.width, use_v),
                         iter_time=t1 - t0)
        if tr is not None:
            tr.end(track="engine")  # host bookkeeping
            tr.end(track="engine", accept_mean=float(alen_np.mean()))
        finite_np = np.asarray(finite)
        if self._poison_numerical or not finite_np.all():
            self._poison_numerical = False
            bad = np.flatnonzero(~finite_np)
            slots = bad.tolist() if bad.size else list(range(B))
            # lazy import: errors lives above the engine in the package graph
            from repro.serving.errors import NumericalFault
            # carry the post-step state: the inputs were donated, so the
            # caller MUST reassign before touching its old buffers
            raise NumericalFault(
                f"non-finite verifier logits in slots {slots}",
                state=new_state, slots=slots)
        return new_state, res

    def slot_lengths(self, state: DecodeState) -> np.ndarray:
        """Committed verifier-cache length per slot (host sync)."""
        return np.asarray(state.vcache["length"])

    # ----------------------------------------------------------- megastep --
    def _build_step(self, spec: DraftSpec, verify_v: int):
        cfg = self.cfg
        accept_mode = cfg.resolve_accept()
        a_max = spec.depth + 1
        temp = cfg.temperature
        needs_paths = any(self.verifier.cfg.layer_mixer(i) == "ssm"
                          for i in range(self.verifier.cfg.num_layers))

        def step(d_params, v_params, dcache, vcache, root_token, key):
            # w8: int8 weights dequantize at the top of the compiled graph
            d_params = dequant_params(d_params)
            v_params = dequant_params(v_params)
            kd, ka = jax.random.split(key)
            res = draft_tree(self.drafter, d_params, dcache, root_token, spec,
                             temperature=temp,
                             sample_key=kd if (temp > 0 and cfg.sample_draft)
                             else None)
            if cfg.prune and verify_v < spec.num_nodes:
                sub, select_idx = pruning.topk_prune(res.tree, verify_v, a_max)
            else:
                sub, select_idx = res.tree, jnp.broadcast_to(
                    jnp.arange(spec.num_nodes)[None],
                    res.tree.tokens.shape)
            b_idx = jnp.arange(sub.tokens.shape[0])[:, None]
            sub_amask = (res.amask[b_idx[..., None], select_idx[:, :, None],
                                   select_idx[:, None, :]])
            paths = (ancestor_paths(sub.parents, a_max) if needs_paths else None)
            t_logits, scratch, h_nodes = self.verifier.tree_verify(
                v_params, sub.tokens, sub.depths, sub_amask, vcache,
                tree_paths=paths)

            if accept_mode == "greedy":
                acc = verify.greedy_accept(sub, t_logits, a_max)
            else:
                tp = jax.nn.softmax(t_logits.astype(jnp.float32) / max(temp, 1e-6),
                                    axis=-1)
                dp = res.draft_probs[b_idx, select_idx]
                acc = verify.stochastic_accept(sub, dp, tp, ka, a_max,
                                               max_children=spec.cand_k)

            vcache = self.verifier.commit(vcache, scratch, acc.node_idx,
                                          acc.accept_len)
            node_idx_orig = jnp.take_along_axis(select_idx, acc.node_idx, axis=1)
            dcache = self.drafter.commit_scratch(dcache, res.scratch,
                                                 node_idx_orig, acc.accept_len)

            # emitted tokens this iteration: accepted drafts (excl. root,
            # already emitted as last iter's bonus) + bonus
            out_tokens = jnp.take_along_axis(sub.tokens, acc.node_idx, axis=1)
            h_last = jnp.take_along_axis(
                h_nodes, acc.last_node[:, None, None].repeat(h_nodes.shape[-1], -1),
                axis=1)[:, 0]
            dcache, vcache, bonus, h_last = self._constrain_state(
                dcache, vcache, acc.bonus, h_last)
            # in-graph numerical health: any NaN/Inf in the verifier logits
            # marks the slot — the host boundary turns it into NumericalFault
            finite = jnp.all(jnp.isfinite(t_logits), axis=(1, 2))
            return (dcache, vcache, bonus, out_tokens, acc.accept_len,
                    h_last, finite)

        return jax.jit(step, donate_argnums=(2, 3))

    # ------------------------------------------------ staged plan pieces --
    def _build_staged_parts(self, spec: DraftSpec, verify_v: int):
        """Separate dispatches per stage (the naive pipeline of Fig. 9-a)."""
        cfg = self.cfg
        a_max = spec.depth + 1
        temp = cfg.temperature
        needs_paths = any(self.verifier.cfg.layer_mixer(i) == "ssm"
                          for i in range(self.verifier.cfg.num_layers))

        @jax.jit
        def draft_fn(d_params, dcache, root_token, key):
            return draft_tree(self.drafter, dequant_params(d_params), dcache,
                              root_token, spec, temperature=temp,
                              sample_key=key if (temp > 0 and cfg.sample_draft)
                              else None)

        @jax.jit
        def verify_fn(v_params, vcache, res):
            v_params = dequant_params(v_params)
            if cfg.prune and verify_v < spec.num_nodes:
                sub, select_idx = pruning.topk_prune(res.tree, verify_v, a_max)
            else:
                sub, select_idx = res.tree, jnp.broadcast_to(
                    jnp.arange(spec.num_nodes)[None], res.tree.tokens.shape)
            b_idx = jnp.arange(sub.tokens.shape[0])[:, None]
            sub_amask = res.amask[b_idx[..., None], select_idx[:, :, None],
                                  select_idx[:, None, :]]
            paths = (ancestor_paths(sub.parents, a_max) if needs_paths else None)
            t_logits, scratch, h_nodes = self.verifier.tree_verify(
                v_params, sub.tokens, sub.depths, sub_amask, vcache,
                tree_paths=paths)
            return sub, select_idx, t_logits, scratch, h_nodes

        @jax.jit
        def accept_fn(sub, t_logits, res, select_idx, key):
            if cfg.resolve_accept() == "greedy":
                return verify.greedy_accept(sub, t_logits, a_max)
            b_idx = jnp.arange(sub.tokens.shape[0])[:, None]
            tp = jax.nn.softmax(t_logits.astype(jnp.float32) / max(temp, 1e-6), -1)
            dp = res.draft_probs[b_idx, select_idx]
            return verify.stochastic_accept(sub, dp, tp, key, a_max,
                                            max_children=spec.cand_k)

        @jax.jit
        def commit_fn(dcache, vcache, res, scratch, sub, select_idx,
                      node_idx, accept_len, last_node, h_nodes):
            vc = self.verifier.commit(vcache, scratch, node_idx, accept_len)
            node_idx_orig = jnp.take_along_axis(select_idx, node_idx, axis=1)
            dc = self.drafter.commit_scratch(dcache, res.scratch,
                                             node_idx_orig, accept_len)
            out_tokens = jnp.take_along_axis(sub.tokens, node_idx, axis=1)
            h_last = jnp.take_along_axis(
                h_nodes, last_node[:, None, None].repeat(h_nodes.shape[-1], -1),
                axis=1)[:, 0]
            dc = cache_lib.shard_cache(dc)
            vc = cache_lib.shard_cache(vc)
            h_last = sharding.shard(h_last, "batch", None)
            return dc, vc, out_tokens, h_last

        return {"draft": draft_fn, "verify": verify_fn, "accept": accept_fn,
                "commit": commit_fn, "a_max": a_max}

    def _run_staged(self, parts, dcache, vcache, root, key, tracer=None):
        """One iteration under the staged plans, with the host boundary the
        paper identifies: acceptance management on CPU + conditional logic.
        With a tracer, each stage gets a span on the ``engine`` track — the
        spans bound the host-side dispatch windows (the accept span includes
        the readback sync, i.e. the CPU bubble the fused plan eliminates)."""
        from repro.core import scheduler as sched

        def _sp(name):
            return (tracer.span(name, track="engine") if tracer is not None
                    else nullcontext())

        kd, ka = jax.random.split(key)
        with _sp("draft"):
            res = parts["draft"](self.d_params, dcache, root, kd)
        with _sp("verify"):
            sub, select_idx, t_logits, scratch, h_nodes = parts["verify"](
                self.v_params, vcache, res)
            finite = jnp.all(jnp.isfinite(t_logits), axis=(1, 2))
        with _sp("accept"):
            if (self.cfg.plan == "staged"
                    and self.cfg.resolve_accept() == "greedy"):
                # host-side accept management (numpy) — the CPU bubble
                tgt = np.asarray(jnp.argmax(t_logits, -1))
                node_idx, accept_len, bonus, last = sched.greedy_accept_host(
                    np.asarray(sub.tokens), np.asarray(sub.parents),
                    np.asarray(sub.depths), np.asarray(sub.live), tgt,
                    parts["a_max"])
                # conditional tail-draft decision happens here on the host in
                # the naive pipeline; the fused plan eliminates this branch
                node_idx, accept_len = (jnp.asarray(node_idx),
                                        jnp.asarray(accept_len))
                bonus, last = jnp.asarray(bonus), jnp.asarray(last)
            else:  # staged_device: accept on device, sync to read the result
                acc = parts["accept"](sub, t_logits, res, select_idx, ka)
                node_idx, accept_len, bonus, last = acc
                jax.block_until_ready(accept_len)  # control readback boundary
        with _sp("commit"):
            dcache, vcache, out_tokens, h_last = parts["commit"](
                dcache, vcache, res, scratch, sub, select_idx, node_idx,
                accept_len, last, h_nodes)
            # `bonus` becomes next step's root: pin its placement so the
            # staged parts (and a later fused megastep) never see a drifting
            # sharding
            bonus = self._put(jnp.asarray(bonus), "batch")
        return dcache, vcache, bonus, out_tokens, accept_len, h_last, finite

    def _get_staged_parts(self, spec: DraftSpec, verify_v: int):
        key = ("staged", spec, verify_v, self._cfg_key)
        if key not in self._step_cache:
            self._step_cache[key] = self._build_staged_parts(spec, verify_v)
            self._note_compile("staged")
        return self._step_cache[key]

    def _get_step(self, spec: DraftSpec, verify_v: int):
        key = ("megastep", spec, verify_v, self.cfg.plan, self._cfg_key)
        if key not in self._step_cache:
            self._step_cache[key] = self._build_step(spec, verify_v)
            self._note_compile("megastep")
        return self._step_cache[key]

    # ----------------------------------------------------------- generate --
    def generate(self, prompt: jax.Array, lengths: jax.Array, max_new: int,
                 spec: Optional[DraftSpec] = None,
                 verify_v: Optional[int] = None,
                 key: Optional[jax.Array] = None,
                 enc_feats: Optional[jax.Array] = None,
                 dynamic_bucket: bool = False,
                 ) -> Tuple[np.ndarray, GenStats]:
        """Generate until EVERY sequence has at least max_new tokens (slower
        sequences keep the loop alive; fast ones over-generate and the caller
        truncates). Thin wrapper over the stepwise API: batched prefill, then
        `decode_step` until done. If `spec` is None, buckets are selected
        per-iteration (depth predictor + latency objective)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        B = prompt.shape[0]
        v_logits, vcache, dcache, h_last = self.prefill(prompt, lengths,
                                                        enc_feats=enc_feats)
        key, sk = jax.random.split(key)
        root = self._put(self._sample(v_logits, sk), "batch")
        state = DecodeState(dcache, vcache, root, h_last, key,
                            produced=np.ones((B,), np.int64))
        out = [np.asarray(root)[:, None]]
        stats = GenStats()
        base_compiles = self._compile_count

        # largest chain one iteration can commit (bounds cache growth/iter)
        if spec is not None:
            step_bound = spec.depth + 1
        elif self.buckets:
            step_bound = max(bk.depth for bk in self.buckets) + 1
        else:
            step_bound = max(self.depth_options) + 1
        L = self.cfg.max_target_len
        lengths_np = np.asarray(lengths)

        # per-sequence accounting: run until the SLOWEST sequence reaches
        # max_new (a batch-max counter would silently under-generate it) —
        # unless the fastest row is about to hit the cache cap, where a
        # further commit would be silently dropped (mode="drop" scatter)
        # and the output would diverge from the verifier.
        while int(state.produced.min()) < max_new:
            committed_max = int((lengths_np + state.produced).max()) - 1
            if committed_max + step_bound > L:
                stats.length_capped = True  # surfaced via summary()
                break
            state, res = self.decode_step(state, spec=spec, verify_v=verify_v)
            stats.iter_times.append(res.iter_time)
            stats.accept_lens.append(res.accept_len)
            stats.buckets.append(res.bucket)
            out.append(res.tokens)

        stats.compiles = self._compile_count - base_compiles
        seq = np.concatenate(out, axis=1)
        return seq, stats

    def _select(self, h_last) -> Tuple[DraftSpec, int]:
        if self.predictor_params is not None:
            d = int(np.asarray(predict_depth(self.predictor_params, h_last,
                                             self.depth_options)).max())
        else:
            d = self.depth_options[-1]
        bucket = select_bucket(self.buckets, d, self.profile,
                               objective=self.cfg.objective)
        return egt_spec(bucket.depth, bucket.width), bucket.verify

    def _sample(self, logits, key):
        if self.cfg.temperature == 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / self.cfg.temperature, -1
        ).astype(jnp.int32)


# --------------------------------------------------------------- baseline --
def generate_autoregressive(model: Model, params, prompt: jax.Array,
                            lengths: jax.Array, max_new: int,
                            temperature: float = 0.0,
                            key: Optional[jax.Array] = None,
                            max_target_len: int = 512,
                            enc_feats: Optional[jax.Array] = None,
                            ) -> Tuple[np.ndarray, Dict[str, float]]:
    """Plain AR decoding baseline (one jitted decode step, replayed)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    B = prompt.shape[0]
    kv = cache_lib.make_kv_cache(model.cfg)
    if kv.layout != "contiguous":
        raise NotImplementedError(
            "the AR baseline decodes on a contiguous cache — paged storage "
            "is a serving-runtime layout (stepwise slot API)")
    cache = kv.init(B, max_target_len)
    logits, cache, _ = model.prefill(params, prompt, lengths, cache,
                                     enc_feats=enc_feats)

    decode = jax.jit(lambda p, t, c: model.decode(p, t, c),
                     donate_argnums=(2,))

    def sample(lg, k):
        if temperature == 0.0:
            return jnp.argmax(lg, -1).astype(jnp.int32)
        return jax.random.categorical(
            k, lg.astype(jnp.float32) / temperature, -1).astype(jnp.int32)

    toks = []
    key, sk = jax.random.split(key)
    tok = sample(logits, sk)
    toks.append(np.asarray(tok))
    t0 = time.perf_counter()
    for _ in range(max_new - 1):
        logits, cache, _ = decode(params, tok, cache)
        key, sk = jax.random.split(key)
        tok = sample(logits, sk)
        toks.append(np.asarray(tok))
    dt = time.perf_counter() - t0
    seq = np.stack(toks, axis=1)
    return seq, {"time_s": dt, "tokens": seq.shape[1] * B,
                 "tpot_ms": 1e3 * dt / max(max_new - 1, 1)}
