"""Compilation buckets: the static ⟨D_draft, W_draft, W_verify⟩ registry.

Each bucket keys exactly one compiled speculation-step executable (the JAX
analogue of one captured CUDA graph). The runtime picks a bucket per
iteration — depth from the predictor, width/verify from the latency
objective — and replays the corresponding executable; shapes never change
inside a bucket, so there are no recompiles on the decode path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence, Tuple

from repro.core.objective import LatencyProfile, speedup_objective


@dataclass(frozen=True)
class Bucket:
    depth: int
    width: int
    verify: int

    @property
    def num_nodes(self) -> int:
        return 1 + self.depth * self.width

    def key(self) -> Tuple[int, int, int]:
        return (self.depth, self.width, self.verify)


DEFAULT_BUCKETS: Tuple[Bucket, ...] = (
    Bucket(2, 2, 4), Bucket(4, 2, 8), Bucket(4, 4, 8),
    Bucket(8, 4, 16), Bucket(8, 8, 32), Bucket(16, 8, 64),
)


def buckets_for_depths(depth_options: Sequence[int], width: int,
                       verify_frac: float = 0.5) -> Tuple[Bucket, ...]:
    out = []
    for d in depth_options:
        n = 1 + d * width
        out.append(Bucket(d, width, max(2, int(n * verify_frac))))
    return tuple(out)


def parse_buckets(text: str) -> Tuple[Bucket, ...]:
    """Parse a ladder flag like ``"2x2,4x2x6,8x4x16"``: each entry is DxW
    (verify defaults to 3/4 of the tree) or DxWxV."""
    out = []
    for part in text.split(","):
        dims = [int(x) for x in part.strip().split("x")]
        if len(dims) == 2:
            d, w = dims
            v = max(2, (3 * (1 + d * w)) // 4)
        elif len(dims) == 3:
            d, w, v = dims
        else:
            raise ValueError(f"bucket {part!r}: expected DxW or DxWxV")
        out.append(Bucket(d, w, v))
    return tuple(out)


def ladder_headroom(buckets: Sequence[Bucket]) -> int:
    """Max cache growth one megastep can commit under ANY ladder bucket
    (deepest chain + bonus + slack) — the admission budget must reserve
    this much, or a deep step near the cache cap would silently drop
    commits."""
    return max(b.depth for b in buckets) + 2


def validate_ladder(buckets: Sequence[Bucket], max_target_len: int,
                    prompt_pad: int = 0) -> Tuple[Bucket, ...]:
    """Sanity-check a bucket ladder for adaptive serving. Returns the ladder
    as a tuple (order preserved — earlier buckets win objective ties)."""
    ladder = tuple(buckets)
    if not ladder:
        raise ValueError("bucket ladder is empty")
    for b in ladder:
        if b.depth < 1 or b.width < 1:
            raise ValueError(f"bucket {b} has non-positive depth/width")
        if not 1 <= b.verify <= b.num_nodes:
            raise ValueError(f"bucket {b}: verify width {b.verify} outside "
                             f"[1, {b.num_nodes}]")
    if len(set(b.key() for b in ladder)) != len(ladder):
        raise ValueError("bucket ladder has duplicate buckets")
    # the DEEPEST bucket sets the per-step headroom: every admitted prompt
    # must still have positive generation budget under it
    need = prompt_pad + ladder_headroom(ladder) + 1
    if max_target_len < need:
        raise ValueError(
            f"max_target_len={max_target_len} leaves no headroom for the "
            f"deepest ladder bucket (need >= {need} with "
            f"prompt_pad={prompt_pad})")
    return ladder


def select_bucket(buckets: Sequence[Bucket], predicted_depth: int,
                  profile: LatencyProfile, aal_estimates: Dict = None,
                  objective: str = "speedup", batch: int = 1) -> Bucket:
    """Choose the bucket for this iteration: smallest depth >= prediction,
    ties broken by the latency objective with an optimistic AAL estimate.
    Ties on the objective keep the earliest candidate. ``batch`` feeds the
    occupancy-aware latency model (see objective.step_latency)."""
    cands = [b for b in buckets if b.depth >= predicted_depth] or list(buckets)
    best, best_v = None, -float("inf")
    for b in cands:
        aal = (aal_estimates or {}).get(b.key(),
                                        min(predicted_depth + 1, b.depth + 1))
        v = (speedup_objective(profile, aal, b.depth, b.width, b.verify,
                               batch=batch)
             if objective == "speedup" else aal)
        if v > best_v:
            best, best_v = b, v
    return best
