"""Compilation buckets: the static ⟨D_draft, W_draft, W_verify⟩ registry.

Each bucket keys exactly one compiled speculation-step executable (the JAX
analogue of one captured CUDA graph). The runtime picks a bucket per
iteration — depth from the predictor, width/verify from the latency
objective — and replays the corresponding executable; shapes never change
inside a bucket, so there are no recompiles on the decode path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence, Tuple

from repro.core.objective import LatencyProfile, speedup_objective


@dataclass(frozen=True)
class Bucket:
    depth: int
    width: int
    verify: int

    @property
    def num_nodes(self) -> int:
        return 1 + self.depth * self.width

    def key(self) -> Tuple[int, int, int]:
        return (self.depth, self.width, self.verify)


DEFAULT_BUCKETS: Tuple[Bucket, ...] = (
    Bucket(2, 2, 4), Bucket(4, 2, 8), Bucket(4, 4, 8),
    Bucket(8, 4, 16), Bucket(8, 8, 32), Bucket(16, 8, 64),
)


def buckets_for_depths(depth_options: Sequence[int], width: int,
                       verify_frac: float = 0.5) -> Tuple[Bucket, ...]:
    out = []
    for d in depth_options:
        n = 1 + d * width
        out.append(Bucket(d, width, max(2, int(n * verify_frac))))
    return tuple(out)


def select_bucket(buckets: Sequence[Bucket], predicted_depth: int,
                  profile: LatencyProfile, aal_estimates: Dict = None,
                  objective: str = "speedup") -> Bucket:
    """Choose the bucket for this iteration: smallest depth >= prediction,
    ties broken by the latency objective with an optimistic AAL estimate."""
    cands = [b for b in buckets if b.depth >= predicted_depth] or list(buckets)
    best, best_v = None, -float("inf")
    for b in cands:
        aal = (aal_estimates or {}).get(b.key(),
                                        min(predicted_depth + 1, b.depth + 1))
        v = (speedup_objective(profile, aal, b.depth, b.width, b.verify)
             if objective == "speedup" else aal)
        if v > best_v:
            best, best_v = b, v
    return best
