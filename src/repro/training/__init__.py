from repro.training.checkpoint import restore_checkpoint, save_checkpoint
from repro.training.optimizer import OptConfig, adamw_update, init_opt_state, lr_at
from repro.training.train_step import chunked_ce_loss, loss_fn, make_train_step
