"""Flat-npz checkpointing for arbitrary pytrees (no orbax dependency)."""
from __future__ import annotations

import os
from typing import Any, Dict

import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in sorted(tree.items()):
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def save_checkpoint(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def restore_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure of `like` (shapes must match)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")

    def rebuild(proto: Any, prefix: str = "") -> Any:
        if isinstance(proto, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in proto.items()}
        if isinstance(proto, (list, tuple)):
            t = type(proto)
            return t(rebuild(v, f"{prefix}{i}/") for i, v in enumerate(proto))
        key = prefix.rstrip("/")
        arr = data[key]
        assert arr.shape == tuple(proto.shape), (key, arr.shape, proto.shape)
        return jnp.asarray(arr)

    return rebuild(like)
