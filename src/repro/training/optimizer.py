"""AdamW + cosine schedule, pure JAX (no optax dependency)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    warmup_steps: int = 50
    total_steps: int = 1000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0


def lr_at(step: jax.Array, cfg: OptConfig) -> jax.Array:
    warm = cfg.lr * jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params: Any) -> Dict[str, Any]:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(params: Any, grads: Any, state: Dict[str, Any],
                 cfg: OptConfig) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)

    lr = lr_at(state["step"], cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh, vh = m / b1c, v / b2c
        new_p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        return new_p, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
