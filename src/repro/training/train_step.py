"""Training step: chunked cross-entropy (bounds the [B, S, V] logits peak),
gradients, AdamW update. Used by the end-to-end trainer, the drafter/verifier
alignment pipeline, and the train_4k dry-run shape."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training.optimizer import OptConfig, adamw_update


def chunked_ce_loss(model: Model, params, h: jax.Array, targets: jax.Array,
                    valid: Optional[jax.Array] = None) -> jax.Array:
    """Cross-entropy over vocab computed in sequence chunks.

    h: [B, S, d]; targets: [B, S]; valid: [B, S] bool. Each chunk's logits are
    rematerialized in the backward pass (jax.checkpoint), so the live logits
    tensor is [B, loss_chunk, V] instead of [B, S, V].
    """
    cfg = model.cfg
    B, S, d = h.shape
    c = min(cfg.loss_chunk, S)
    if S % c:
        c = S  # fall back to unchunked for awkward lengths
    n_chunks = S // c
    if valid is None:
        valid = jnp.ones((B, S), bool)

    hc = h.reshape(B, n_chunks, c, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, n_chunks, c).transpose(1, 0, 2)
    vc = valid.reshape(B, n_chunks, c).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(hx, tx, vx):
        logits = model.logits(params, hx).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tx[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * vx
        return nll.sum(), vx.sum()

    def body(carry, xs):
        tot, cnt = carry
        l, n = chunk_loss(*xs)
        return (tot + l, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (hc, tc, vc),
                                 unroll=n_chunks if cfg.scan_unroll else 1)
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(model: Model, params, tokens: jax.Array,
            valid: Optional[jax.Array] = None,
            enc_feats: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    """Next-token LM loss (inputs tokens[:, :-1] predict tokens[:, 1:])."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    v = None if valid is None else valid[:, 1:]
    h, aux = model.hidden_train(params, inp,
                                seq_valid=None if valid is None else valid[:, :-1],
                                enc_feats=enc_feats)
    ce = chunked_ce_loss(model, params, h, tgt, v)
    return ce + aux, {"ce": ce, "aux": aux}


def make_train_step(model: Model, opt_cfg: OptConfig):
    """Returns train_step(params, opt_state, batch) -> (params, state, metrics).

    `batch` is a dict with 'tokens' [B, S] (+ optional 'valid', 'enc_feats').
    """
    def train_step(params, opt_state, batch):
        def wrapped(p):
            return loss_fn(model, p, batch["tokens"], batch.get("valid"),
                           batch.get("enc_feats"))
        (loss, parts), grads = jax.value_and_grad(wrapped, has_aux=True)(params)
        params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, **parts, **om}

    return train_step
