"""Yi-6B [arXiv:2403.04652] — llama-architecture dense GQA (kv=4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    source="arXiv:2403.04652",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    mlp_act="silu",
    gated_mlp=True,
    rope_theta=5000000.0,
)
