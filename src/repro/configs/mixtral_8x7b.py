"""Mixtral-8x7B [arXiv:2401.04088] — MoE 8 experts top-2, sliding-window attn."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    source="arXiv:2401.04088",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    mlp_act="silu",
    gated_mlp=True,
    num_experts=8,
    num_experts_per_tok=2,
    moe_d_ff=14336,
    sliding_window=4096,
    rope_theta=1000000.0,
)
