"""Whisper-medium [arXiv:2212.04356] — encoder-decoder audio model.

The conv/mel frontend is STUBBED: ``input_specs`` provides precomputed frame
embeddings of shape (batch, encoder_seq_len, d_model); this config describes
the transformer backbone only.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=24,            # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    mlp_act="gelu",
    gated_mlp=False,
    norm_type="layernorm",
    pos_embedding="learned",
    is_encoder_decoder=True,
    num_encoder_layers=24,
    encoder_seq_len=1500,     # 30s audio at 50 frames/s (post conv stub)
    encoder_feature_dim=1024,
    tie_embeddings=True,
    max_seq_len=448,
)
