"""Jamba-v0.1 52B [arXiv:2403.19887] — hybrid Mamba+attention 1:7, MoE 16e top-2.

Layer pattern (period 8): attention at offset 4, Mamba elsewhere; MoE FFN on
every other layer (period 2, offset 1). Jamba v0.1 uses Mamba-1 layers; we
instantiate the SSD (Mamba-2) formulation of the same state-space block, which
shares the recurrence structure — noted in DESIGN.md.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    source="arXiv:2403.19887",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    mlp_act="silu",
    gated_mlp=True,
    num_experts=16,
    num_experts_per_tok=2,
    moe_d_ff=14336,
    attn_layer_period=8,
    attn_layer_offset=4,
    moe_layer_period=2,
    moe_layer_offset=1,
    ssm_state_size=128,       # SSD-form state
    ssm_head_dim=64,
    ssm_expand=2,             # d_inner = 8192 -> 128 ssm heads
    ssm_chunk=64,
    ssm_conv_width=4,
    ssm_num_groups=1,
    pos_embedding="none",     # jamba uses no positional encoding
)
