"""Mamba2-130M [arXiv:2405.21060] — attention-free SSD (state-space duality)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,                   # mamba2 blocks have no separate FFN
    vocab_size=50280,
    ssm_state_size=128,
    ssm_head_dim=64,
    ssm_expand=2,             # d_inner = 1536, 24 ssm heads
    ssm_chunk=64,
    ssm_conv_width=4,
    ssm_num_groups=1,
    pos_embedding="none",
    tie_embeddings=True,
)
