"""Nemotron-4 15B [arXiv:2402.16819] — dense GQA, squared-ReLU MLP."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    source="arXiv:2402.16819",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    mlp_act="sq_relu",
    gated_mlp=False,          # Nemotron-4 uses squared ReLU, non-gated MLP
    rope_theta=10000.0,
)
