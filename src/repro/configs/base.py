"""Model configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``. The config is
a frozen dataclass so it can key jit caches and compilation buckets.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | audio | vlm
    source: str = ""       # citation for the config values

    # trunk
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 512

    # mlp
    mlp_act: str = "silu"      # silu | sq_relu | gelu
    gated_mlp: bool = True     # SwiGLU-style gate

    # attention
    rope_theta: float = 10000.0
    sliding_window: int = 0    # 0 = full attention
    use_qk_norm: bool = False  # chameleon

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0                  # expert hidden dim (defaults to d_ff)
    capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.01

    # hybrid (jamba-style): an attention layer every `attn_layer_period`
    # layers at `attn_layer_offset`; MoE layer every `moe_layer_period`.
    attn_layer_period: int = 0
    attn_layer_offset: int = 0
    moe_layer_period: int = 0
    moe_layer_offset: int = 0

    # ssm (mamba2 / SSD)
    ssm_state_size: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_conv_width: int = 4
    ssm_num_groups: int = 1

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 0     # stubbed frontend sequence length (frames)
    encoder_feature_dim: int = 0  # dim of the precomputed frontend embeddings

    # norms / positions / embeddings
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm
    pos_embedding: str = "rope"    # rope | learned | none
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # perf-iteration knobs (§Perf hillclimbing; defaults = paper baseline)
    gqa_grouped: bool = False        # GQA attention without repeat_kv
    moe_batch_dispatch: bool = False  # data-shard-local MoE routing
    moe_combine_dtype: str = "float32"  # MoE combine/scatter accumulation
    cache_pad_to: int = 1            # pad cache len (enables seq-sharding)
    attn_score_seqshard: bool = False  # pin decode scores to the cache_seq
                                       # sharding (psum output, no V gather)

    # runtime
    max_seq_len: int = 32768
    dtype: str = "float32"         # compute dtype ("bfloat16" for dry-run)
    param_dtype: str = "float32"
    remat: bool = False
    use_pallas: bool = False       # route hot ops through Pallas kernels
    verify_kernel: str = "auto"    # cached/tree attention hot path:
                                   # "fused" = the GQA-native length-aware
                                   # Pallas kernel, "xla" = the einsum
                                   # oracle path, "auto" = fused on an
                                   # accelerator backend, xla on CPU (where
                                   # the kernel would run interpreted)
    cache_layout: str = "contiguous"  # decode-cache storage: "contiguous"
                                   # per-slot [B, max_len, KV, dh] rows, or
                                   # "paged" fixed page pool + per-slot page
                                   # table (see repro.models.cache)
    page_len: int = 64             # tokens per pool page (paged layout);
                                   # must divide the engine max_target_len
    attn_chunk: int = 512          # flash prefill query/kv block
    loss_chunk: int = 512          # chunked cross-entropy sequence block
    vocab_pad_to: int = 1          # pad vocab to a multiple (256 for dry-run)
    scan_unroll: bool = False      # unroll block scan (dry-run HLO parsing)

    def __post_init__(self):
        if self.moe_d_ff == 0 and self.num_experts:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- per-layer structure -------------------------------------------------
    def layer_mixer(self, i: int) -> str:
        """Return the sequence mixer for layer ``i``: 'attn' or 'ssm'."""
        if self.family == "ssm":
            return "ssm"
        if self.attn_layer_period:
            return ("attn" if i % self.attn_layer_period == self.attn_layer_offset
                    else "ssm")
        return "attn"

    def layer_ffn(self, i: int) -> str:
        """Return the FFN kind for layer ``i``: 'dense', 'moe' or 'none'."""
        if self.family == "ssm":
            return "none"  # mamba2 blocks have no separate FFN
        if self.moe_layer_period:
            return ("moe" if i % self.moe_layer_period == self.moe_layer_offset
                    else "dense")
        if self.num_experts:
            return "moe"
        return "dense"

    @property
    def layers_per_block(self) -> int:
        """Heterogeneous layers are grouped into a repeating block that is
        scanned over (compile-time efficiency). The block is the LCM of the
        layer-kind periods."""
        period = 1
        for p in (self.attn_layer_period, self.moe_layer_period):
            if p:
                period = _lcm(period, p)
        return period

    @property
    def num_blocks(self) -> int:
        assert self.num_layers % self.layers_per_block == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"block period {self.layers_per_block}")
        return self.num_layers // self.layers_per_block

    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def num_q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    # ---- size accounting (roofline) ------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embedding + trunk [+ encoder])."""
        d, V = self.d_model, self.vocab_size
        n = V * d  # token embedding
        if not self.tie_embeddings:
            n += d * V  # lm head
        for i in range(self.num_layers):
            n += self._layer_params(i)
        n += d  # final norm
        if self.is_encoder_decoder:
            for _ in range(self.num_encoder_layers):
                # self-attn + mlp + 2 norms (encoder heads == decoder heads)
                n += self._attn_params(cross=False) + self._dense_ffn_params() + 2 * d
            n += d  # encoder final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if not self.num_experts:
            return self.param_count()
        n = self.param_count()
        for i in range(self.num_layers):
            if self.layer_ffn(i) == "moe":
                per_expert = self._expert_params()
                n -= (self.num_experts - self.num_experts_per_tok) * per_expert
        return n

    def _attn_params(self, cross: bool = False) -> int:
        d, H, KV, dh = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        return d * H * dh + 2 * d * KV * dh + H * dh * d

    def _dense_ffn_params(self) -> int:
        mult = 3 if self.gated_mlp else 2
        return mult * self.d_model * self.d_ff

    def _expert_params(self) -> int:
        mult = 3 if self.gated_mlp else 2
        return mult * self.d_model * self.moe_d_ff

    def _ssm_params(self) -> int:
        d, di, ds = self.d_model, self.ssm_d_inner, self.ssm_state_size
        g = self.ssm_num_groups
        nh = self.ssm_num_heads
        conv_dim = di + 2 * g * ds
        n = d * (2 * di + 2 * g * ds + nh)        # in_proj (z,x,B,C,dt)
        n += self.ssm_conv_width * conv_dim       # conv
        n += nh * 2 + nh                          # A_log, D, dt_bias
        n += di                                   # ssm norm
        n += di * d                               # out_proj
        return n

    def _layer_params(self, i: int) -> int:
        n = 0
        if self.layer_mixer(i) == "attn":
            n += self._attn_params() + self.d_model
            if self.is_encoder_decoder:
                n += self._attn_params(cross=True) + self.d_model
        else:
            n += self._ssm_params() + self.d_model
        ffn = self.layer_ffn(i)
        if ffn == "dense":
            n += self._dense_ffn_params() + self.d_model
        elif ffn == "moe":
            n += self.num_experts * self._expert_params()
            n += self.d_model * self.num_experts  # router
            n += self.d_model
        return n

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def _lcm(a: int, b: int) -> int:
    import math
    return a * b // math.gcd(a, b)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests (<=2 layers, d<=512,
    <=4 experts)."""
    kw = dict(
        num_layers=cfg.layers_per_block * max(1, 2 // cfg.layers_per_block)
        if cfg.layers_per_block > 1 else 2,
        d_model=min(cfg.d_model, 256),
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=64,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        max_seq_len=256,
        remat=False,
    )
    if cfg.num_experts:
        kw["num_experts"] = min(cfg.num_experts, 4)
        kw["num_experts_per_tok"] = min(cfg.num_experts_per_tok, 2)
        kw["moe_d_ff"] = min(cfg.moe_d_ff, 256)
    if cfg.family in ("ssm", "hybrid"):
        kw["ssm_state_size"] = min(cfg.ssm_state_size, 64) or 64
        kw["ssm_head_dim"] = 32
        kw["ssm_chunk"] = 16
    if cfg.is_encoder_decoder:
        kw["num_encoder_layers"] = 2
        kw["encoder_seq_len"] = 32
        kw["encoder_feature_dim"] = min(cfg.d_model, 256)
    if cfg.attn_layer_period:
        # keep the hybrid interleave structure but at minimum depth
        kw["num_layers"] = cfg.layers_per_block
    if cfg.sliding_window:
        kw["sliding_window"] = 64
    kw.update(overrides)
    return cfg.replace(**kw)
