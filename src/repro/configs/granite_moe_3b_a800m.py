"""Granite-MoE 3B-a800m [hf:ibm-granite/granite-3.0-1b-a400m-base family]
— 40 experts, top-8, GQA kv=8 (per assignment: MoE 40e top-8)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    mlp_act="silu",
    gated_mlp=True,
    num_experts=40,
    num_experts_per_tok=8,
    moe_d_ff=512,
    rope_theta=10000.0,
)
