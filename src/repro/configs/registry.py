"""Architecture registry: ``--arch <id>`` lookup for launchers and tests."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, reduced

_MODULES = {
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "yi-6b": "repro.configs.yi_6b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "whisper-medium": "repro.configs.whisper_medium",
    "granite-20b": "repro.configs.granite_20b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    # the paper's own drafter/verifier pair
    "llama2-7b": "repro.configs.llama2_7b",
    "llama-68m": "repro.configs.llama_68m",
}

ASSIGNED: List[str] = list(_MODULES)[:10]


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_reduced_config(arch: str, **overrides) -> ModelConfig:
    return reduced(get_config(arch), **overrides)


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in _MODULES}
