"""Llama-68M — the paper's drafter model [SpecInfer, arXiv:2305.09781]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-68m",
    family="dense",
    source="SpecInfer drafter (JackFram/llama-68m)",
    num_layers=2,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=32000,
    mlp_act="silu",
    gated_mlp=True,
)
