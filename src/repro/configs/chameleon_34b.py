"""Chameleon-34B [arXiv:2405.09818] — early-fusion VLM over VQ image tokens.

Image tokens are ordinary entries in the 65536 vocab (VQ-VAE codebook occupies
a contiguous id range); the VQ image tokenizer is STUBBED — ``input_specs``
provides token ids that may include image-token ids. Chameleon uses QK-norm
for training stability.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    source="arXiv:2405.09818",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    mlp_act="silu",
    gated_mlp=True,
    use_qk_norm=True,
    rope_theta=10000.0,
)

# VQ codebook ids live in [IMAGE_TOKEN_START, IMAGE_TOKEN_START + 8192)
IMAGE_TOKEN_START = 4
IMAGE_TOKEN_COUNT = 8192
