from repro.configs.base import ModelConfig, reduced
from repro.configs.registry import ASSIGNED, all_configs, get_config, get_reduced_config
from repro.configs.shapes import SHAPES, InputShape
