"""Llama-2-7B — the paper's own target/verifier model [arXiv:2307.09288]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b",
    family="dense",
    source="arXiv:2307.09288 (paper's verifier)",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=32000,
    mlp_act="silu",
    gated_mlp=True,
)
