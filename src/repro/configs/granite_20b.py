"""Granite-20B code model [arXiv:2405.04324] — llama-arch, MQA (kv=1)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    source="arXiv:2405.04324",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,          # multi-query attention
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    mlp_act="gelu",
    gated_mlp=False,
    rope_theta=10000.0,
)
