"""Training launcher.

Two modes:
  * CPU end-to-end (default): train a REDUCED variant of ``--arch`` on the
    synthetic Markov corpus for ``--steps`` steps — the runnable driver.
  * ``--dryrun``: lower+compile the FULL config's train step on the
    production mesh instead (no allocation) — see dryrun.py for the matrix.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 100
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.data.pipeline import DataConfig, batches
from repro.models import Model
from repro.training import (OptConfig, init_opt_state, make_train_step,
                            save_checkpoint)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (assigned) config, not the reduced one")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = (get_config(args.arch) if args.full_config
           else get_reduced_config(args.arch))
    cfg = cfg.replace(max_seq_len=max(cfg.max_seq_len, args.seq))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={args.arch} family={cfg.family} params={n_params/1e6:.1f}M")

    opt_cfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                        total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
    state = init_opt_state(params)
    data = DataConfig(vocab=cfg.vocab_size, seq_len=args.seq,
                      batch=args.batch)
    enc = (jnp.zeros((args.batch, cfg.encoder_seq_len,
                      cfg.encoder_feature_dim)) if cfg.is_encoder_decoder
           else None)

    t0 = time.perf_counter()
    for i, batch in enumerate(batches(data, args.steps)):
        feed = {"tokens": jnp.asarray(batch["tokens"])}
        if enc is not None:
            feed["enc_feats"] = enc
        params, state, metrics = step_fn(params, state, feed)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}  "
                  f"({time.perf_counter() - t0:.1f}s)", flush=True)
    if args.checkpoint:
        save_checkpoint(args.checkpoint, params)
        print("saved", args.checkpoint)


if __name__ == "__main__":
    main()
