"""Lowerable step builders for the dry-run matrix.

For every (architecture × input shape × mesh) this module builds the jitted
step function plus fully-abstract (ShapeDtypeStruct) inputs and explicit
in/out shardings — so ``.lower().compile()`` proves the distribution config
is coherent without allocating anything.

Shape → step kind:
    train_4k     -> train_step   (fwd + chunked-CE + bwd + AdamW update)
    prefill_32k  -> prefill_step (prompt ingest, cache write, last logits)
    decode_32k   -> serve_step   (ONE token against a seq_len KV cache)
    long_500k    -> serve_step   (sub-quadratic archs; dense archs run an
                    explicit sliding-window serving variant, see DESIGN.md)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, InputShape, get_config
from repro.configs.base import ModelConfig
from repro.models.cache import cache_logical_axes, make_kv_cache
from repro.models.model import Model
from repro.sharding import specs as sh
from repro.training.optimizer import OptConfig
from repro.training.train_step import make_train_step

# dense full-attention archs run long_500k under an explicit sliding-window
# serving variant (window 8192) — recorded as `<arch>+swa` in the roofline.
SWA_FOR_LONG = 8192
LONG_NATIVE = {"mamba2-130m", "jamba-v0.1-52b", "mixtral-8x7b"}
LONG_SKIP = {"whisper-medium": "decoder spec'd to <=448 positions; a 500k "
                               "decoder cache is not meaningful for enc-dec"}


@dataclasses.dataclass(frozen=True)
class Case:
    arch: str
    shape: InputShape
    cfg: ModelConfig
    variant: str = ""          # "+swa" when the SWA serving variant is used

    @property
    def key(self) -> str:
        return f"{self.arch}{self.variant}__{self.shape.name}"


def dryrun_case(arch: str, shape_name: str,
                overrides: Optional[Dict[str, Any]] = None) -> Optional[Case]:
    """Resolve the dry-run config for (arch, shape); None if skipped."""
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    variant = ""
    kw: Dict[str, Any] = dict(dtype="bfloat16", param_dtype="bfloat16",
                              vocab_pad_to=256)
    if shape.kind == "train":
        kw["remat"] = True
        kw["max_seq_len"] = max(cfg.max_seq_len, shape.seq_len)
    else:
        kw["max_seq_len"] = max(cfg.max_seq_len, shape.seq_len + 8)
    if shape.name == "long_500k":
        if arch in LONG_SKIP:
            return None
        if arch not in LONG_NATIVE:
            kw["sliding_window"] = SWA_FOR_LONG
            variant = "+swa"
    kw.update(overrides or {})
    return Case(arch, shape, cfg.replace(**kw), variant)


def batch_spec(mesh, global_batch: int) -> P:
    """Shard the batch dim over (pod, data) where divisibility allows."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    while axes:
        extent = 1
        for a in axes:
            extent *= mesh.shape[a]
        if global_batch % extent == 0:
            return P(tuple(axes) if len(axes) > 1 else axes[0])
        axes = axes[1:]
    return P(None)


def _cache_shardings(cfg: ModelConfig, cache_abs, mesh, batch: int):
    """NamedSharding pytree for the KV cache, honoring batch divisibility."""
    bspec = batch_spec(mesh, batch)
    b_axes = bspec[0] if bspec else None

    def one(axes, leaf):
        entries = []
        used = set()
        if isinstance(b_axes, tuple):
            used.update(b_axes)
        elif b_axes:
            used.add(b_axes)
        for ax, dim in zip(axes, leaf.shape):
            if ax == "batch":
                entries.append(b_axes)
                continue
            entries.append(sh._resolve_entry(ax, dim, mesh,
                                             sh._state().rules, used))
        return NamedSharding(mesh, P(*entries))

    axes_tree = cache_logical_axes(cache_abs)
    return jax.tree.map(one, axes_tree, cache_abs,
                        is_leaf=lambda x: isinstance(x, tuple))


def _enc_feats_abs(cfg: ModelConfig, batch: int):
    if not cfg.is_encoder_decoder:
        return None
    return jax.ShapeDtypeStruct(
        (batch, cfg.encoder_seq_len, cfg.encoder_feature_dim),
        jnp.dtype(cfg.dtype))


# --------------------------------------------------------------- builders --
def build_train(case: Case, mesh):
    cfg, shape = case.cfg, case.shape
    model = Model(cfg)
    dtype = jnp.dtype(cfg.param_dtype)
    defs = model.param_defs()
    params_abs = model.abstract(dtype)
    pshard = sh.fsdp_shardings(defs, mesh)
    opt_abs = {"m": params_abs, "v": params_abs,
               "step": jax.ShapeDtypeStruct((), jnp.int32)}
    oshard = {"m": pshard, "v": pshard,
              "step": NamedSharding(mesh, P())}
    B, S = shape.global_batch, shape.seq_len
    bspec = batch_spec(mesh, B)
    # +1: the LM loss shifts by one, so the model processes exactly S tokens
    batch_abs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32)}
    bshard: Dict[str, Any] = {
        "tokens": NamedSharding(mesh, P(*bspec, None))}
    if cfg.is_encoder_decoder:
        batch_abs["enc_feats"] = _enc_feats_abs(cfg, B)
        bshard["enc_feats"] = NamedSharding(mesh, P(*bspec, None, None))

    step = make_train_step(model, OptConfig())
    jitted = jax.jit(step,
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
    return jitted, (params_abs, opt_abs, batch_abs)


def build_prefill(case: Case, mesh):
    cfg, shape = case.cfg, case.shape
    model = Model(cfg)
    dtype = jnp.dtype(cfg.dtype)
    B, S = shape.global_batch, shape.seq_len
    pshard = sh.param_shardings(model.param_defs(), mesh)
    params_abs = model.abstract(dtype)
    bspec = batch_spec(mesh, B)
    tokens_abs = jax.ShapeDtypeStruct((B, S), jnp.int32)
    lengths_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
    enc_abs = _enc_feats_abs(cfg, B)

    def prefill_step(params, tokens, lengths, enc_feats=None):
        cache = make_kv_cache(cfg).init(B, S + 8, dtype=dtype)
        from repro.models.cache import shard_cache
        cache = shard_cache(cache)
        logits, cache, h_last = model.prefill(params, tokens, lengths, cache,
                                              enc_feats=enc_feats)
        return logits, cache

    args = [params_abs, tokens_abs, lengths_abs]
    in_sh = [pshard, NamedSharding(mesh, P(*bspec, None)),
             NamedSharding(mesh, P(*bspec))]
    if enc_abs is not None:
        args.append(enc_abs)
        in_sh.append(NamedSharding(mesh, P(*bspec, None, None)))
    jitted = jax.jit(prefill_step, in_shardings=tuple(in_sh))
    return jitted, tuple(args)


def _cache_len(cfg: ModelConfig, n: int) -> int:
    m = max(cfg.cache_pad_to, 1)
    return ((n + m - 1) // m) * m


def build_decode(case: Case, mesh):
    cfg, shape = case.cfg, case.shape
    model = Model(cfg)
    dtype = jnp.dtype(cfg.dtype)
    B, S = shape.global_batch, shape.seq_len
    pshard = sh.param_shardings(model.param_defs(), mesh)
    params_abs = model.abstract(dtype)
    cache_abs = make_kv_cache(cfg).init(B, _cache_len(cfg, S + 8),
                                        dtype=dtype, abstract=True)
    cshard = _cache_shardings(cfg, cache_abs, mesh, B)
    bspec = batch_spec(mesh, B)
    token_abs = jax.ShapeDtypeStruct((B,), jnp.int32)

    def serve_step(params, token, cache):
        return model.decode(params, token, cache)

    jitted = jax.jit(serve_step,
                     in_shardings=(pshard, NamedSharding(mesh, P(*bspec)),
                                   cshard),
                     out_shardings=(None, cshard, None),
                     donate_argnums=(2,))
    return jitted, (params_abs, token_abs, cache_abs)


def build_tree_verify(case: Case, mesh, num_nodes: int = 64,
                      depth_max: int = 16):
    """Beyond-paper extra: the speculative tree-verify step itself, dry-run
    at production scale (W=64 tree against a seq_len cache)."""
    cfg, shape = case.cfg, case.shape
    model = Model(cfg)
    dtype = jnp.dtype(cfg.dtype)
    B, S = shape.global_batch, shape.seq_len
    pshard = sh.param_shardings(model.param_defs(), mesh)
    params_abs = model.abstract(dtype)
    cache_abs = make_kv_cache(cfg).init(
        B, _cache_len(cfg, S + num_nodes + 8), dtype=dtype, abstract=True)
    cshard = _cache_shardings(cfg, cache_abs, mesh, B)
    bspec = batch_spec(mesh, B)
    W = num_nodes
    toks = jax.ShapeDtypeStruct((B, W), jnp.int32)
    deps = jax.ShapeDtypeStruct((B, W), jnp.int32)
    mask = jax.ShapeDtypeStruct((B, W, W), jnp.bool_)
    needs_paths = any(cfg.layer_mixer(i) == "ssm"
                      for i in range(cfg.num_layers))
    paths = (jax.ShapeDtypeStruct((B, W, depth_max), jnp.int32)
             if needs_paths else None)

    def verify_step(params, tree_tokens, depths, tree_mask, cache,
                    tree_paths=None):
        return model.tree_verify(params, tree_tokens, depths, tree_mask,
                                 cache, tree_paths=tree_paths)

    args = [params_abs, toks, deps, mask, cache_abs]
    in_sh = [pshard, NamedSharding(mesh, P(*bspec, None)),
             NamedSharding(mesh, P(*bspec, None)),
             NamedSharding(mesh, P(*bspec, None, None)), cshard]
    if paths is not None:
        args.append(paths)
        in_sh.append(NamedSharding(mesh, P(*bspec, None, None)))
    jitted = jax.jit(verify_step, in_shardings=tuple(in_sh))
    return jitted, tuple(args)


BUILDERS = {"train": build_train, "prefill": build_prefill,
            "decode": build_decode, "tree_verify": build_tree_verify}


def build(case: Case, mesh, kind: Optional[str] = None):
    kind = kind or case.shape.kind
    return BUILDERS[kind](case, mesh)
