"""Production mesh + TPU v5e hardware model.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; callers (dryrun, the
launchers) decide when devices are enumerated.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """A 1×N mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))


@dataclass(frozen=True)
class Hardware:
    """TPU v5e per-chip peaks (the roofline denominators)."""
    name: str = "tpu_v5e"
    peak_flops_bf16: float = 197e12   # FLOP/s
    hbm_bw: float = 819e9             # bytes/s
    ici_bw: float = 50e9              # bytes/s per link
    hbm_bytes: float = 16e9           # capacity per chip


V5E = Hardware()


def mesh_chips(mesh) -> int:
    return mesh.devices.size
