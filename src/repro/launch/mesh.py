"""Production mesh + TPU v5e hardware model.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; callers (dryrun, the
launchers) decide when devices are enumerated.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """A 1×N mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))


def parse_mesh_shape(spec: str) -> Tuple[int, int]:
    """Parse a ``DxM`` mesh request ("4x2" -> (4, 2): data=4, model=2)."""
    try:
        parts = tuple(int(p) for p in spec.lower().split("x"))
    except ValueError:
        raise ValueError(f"--mesh wants DxM (e.g. 4x2), got {spec!r}") from None
    if len(parts) != 2 or any(p < 1 for p in parts):
        raise ValueError(f"--mesh wants two positive extents DxM, got {spec!r}")
    return parts


def make_serving_mesh(spec: Optional[str] = None):
    """Resolve a serving-CLI mesh request.

    ``None`` keeps the engine unsharded. ``"host"`` spans whatever devices
    exist via `make_host_mesh`. ``"DxM"`` builds a data×model mesh over
    exactly D*M devices; when the host has fewer, we warn and fall back to
    `make_host_mesh` rather than refuse to serve.
    """
    if spec is None:
        return None
    if spec == "host":
        return make_host_mesh()
    data, model = parse_mesh_shape(spec)
    n = len(jax.devices())
    if data * model > n:
        warnings.warn(
            f"--mesh {spec} wants {data * model} devices but only {n} exist; "
            f"falling back to make_host_mesh() (try "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={data * model} "
            f"to emulate devices on CPU)", stacklevel=2)
        return make_host_mesh()
    return jax.make_mesh((data, model), ("data", "model"))


@dataclass(frozen=True)
class Hardware:
    """TPU v5e per-chip peaks (the roofline denominators)."""
    name: str = "tpu_v5e"
    peak_flops_bf16: float = 197e12   # FLOP/s
    hbm_bw: float = 819e9             # bytes/s
    ici_bw: float = 50e9              # bytes/s per link
    hbm_bytes: float = 16e9           # capacity per chip


V5E = Hardware()


def mesh_chips(mesh) -> int:
    return mesh.devices.size
