"""Serving launcher: batched speculative decoding on the CPU testbed.

Builds (or restores) the aligned drafter/verifier pair, measures the
latency profile, and serves a queue of requests through the speculative
engine with dynamic bucket selection — the full Yggdrasil runtime at
laptop scale.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --requests 8 --max-new 48
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.buckets import buckets_for_depths
from repro.core.engine import EngineConfig, SpeculativeEngine
from repro.core.objective import LatencyProfile
from repro.data.pipeline import MarkovSource
from repro.serving.server import BatchedServer, Request
from repro.serving.testbed import TestbedSpec, build_testbed


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--plan", default="fused",
                    choices=["fused", "staged", "staged_device"])
    ap.add_argument("--profile", default=None,
                    help="LatencyProfile JSON (default: synthetic)")
    args = ap.parse_args()

    tb = build_testbed(TestbedSpec())
    prof = (LatencyProfile.load(args.profile) if args.profile
            else LatencyProfile.synthetic())
    engine = SpeculativeEngine(
        tb.drafter, tb.d_params, tb.verifier, tb.v_params, profile=prof,
        buckets=buckets_for_depths((2, 4, 8), width=2, verify_frac=0.75),
        depth_options=(2, 4, 8),
        config=EngineConfig(temperature=args.temperature, plan=args.plan))
    server = BatchedServer(engine, batch_size=args.batch, prompt_pad=24)

    src = MarkovSource(vocab=tb.spec.vocab,
                       concentration=tb.data_cfg.concentration)
    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        plen = int(rng.integers(8, 20))
        server.submit(Request(uid=uid, prompt=src.sample(rng, plen),
                              max_new=args.max_new))
    done = server.run()
    tot_tok, tot_t = 0, 0.0
    for uid, req in sorted(done.items()):
        s = req.stats
        print(f"req {uid}: {len(req.result)} tokens  "
              f"aal={s['aal']:.2f}  tpot={s['tpot_ms']:.1f}ms")
        tot_tok += s["tokens"]
        tot_t += s["time_s"]
    print(f"served {len(done)} requests; aggregate TPOT "
          f"{1e3 * tot_t / max(tot_tok, 1):.1f} ms/token")


if __name__ == "__main__":
    main()
