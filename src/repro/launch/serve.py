"""Serving launcher: speculative decoding on the CPU testbed.

Builds (or restores) the aligned drafter/verifier pair, measures the
latency profile, and serves a queue of requests through the speculative
engine — the full Yggdrasil runtime at laptop scale. Two serving modes:

  * ``--server batched``    — one padded batch to completion per step (the
    single-tenant latency-optimal regime of §9).
  * ``--server continuous`` — continuous batching: a fixed pool of decode
    slots, retired requests replaced mid-flight via single-slot prefill,
    one pinned megastep executable replayed across slot churn.

Both servers also run mesh-sharded: ``--mesh DxM`` (e.g. ``--mesh 4x2``)
places the engine on a data×model device mesh — verifier/drafter params
tensor-parallel over ``model``, decode slots data-parallel over ``data`` —
via the logical-axis rules in sharding/specs.py. ``--mesh host`` spans
whatever devices exist; an infeasible request falls back to the host mesh.
On a CPU-only box, emulate devices first:
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Observability: all output goes through a ``logging``-based event log —
one event per line, ``key=value`` text by default or JSON lines with
``--log-json`` — sharing the tracer's event schema (admission, park,
truncation, retirement, bucket_switch come from the server itself).
``--trace-dir DIR`` enables full telemetry and writes ``trace.json``
(Chrome trace — load it at https://ui.perfetto.dev), ``metrics.prom``
(Prometheus text) and ``metrics.json`` (registry snapshot) on exit;
``--jax-profile N`` additionally captures a ``jax.profiler`` device trace
around the first N continuous megasteps under ``DIR/jax``.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --requests 8 --max-new 48
  PYTHONPATH=src python -m repro.launch.serve --server continuous \
      --requests 16 --batch 4 --trace-dir /tmp/ygg-trace --log-json
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --server continuous --mesh 4x2
"""
from __future__ import annotations

import argparse
import json
import logging
import os

import numpy as np

from repro.core.buckets import buckets_for_depths, parse_buckets
from repro.core.egt import egt_spec
from repro.core.engine import EngineConfig, SpeculativeEngine
from repro.core.objective import LatencyProfile
from repro.data.pipeline import MarkovSource
from repro.launch.mesh import make_serving_mesh
from repro.quant import QuantConfig
from repro.serving.continuous import ContinuousServer
from repro.serving.controller import BucketController
from repro.serving.server import BatchedServer, Request
from repro.serving.testbed import TestbedSpec, build_testbed
from repro.telemetry import EventLog, Telemetry, configure_logging


def _write_artifacts(tel: Telemetry, trace_dir: str, ev: EventLog) -> None:
    os.makedirs(trace_dir, exist_ok=True)
    trace_p = os.path.join(trace_dir, "trace.json")
    tel.tracer.save(trace_p)
    with open(os.path.join(trace_dir, "metrics.prom"), "w") as f:
        f.write(tel.registry.to_prometheus())
    with open(os.path.join(trace_dir, "metrics.json"), "w") as f:
        json.dump(tel.registry.snapshot(), f, indent=1, default=float)
    ev.emit("artifacts_written", dir=trace_dir,
            overhead_s=round(tel.overhead_seconds(), 6))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--server", default="batched",
                    choices=["batched", "continuous"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--plan", default="fused",
                    choices=["fused", "staged", "staged_device"])
    ap.add_argument("--depth", type=int, default=4,
                    help="pinned speculation depth (continuous mode)")
    ap.add_argument("--width", type=int, default=2,
                    help="pinned speculation width (continuous mode)")
    ap.add_argument("--adaptive", action="store_true",
                    help="continuous mode: precompile a bucket ladder and "
                         "let the online controller re-pick the bucket each "
                         "megastep (zero recompiles after warmup)")
    ap.add_argument("--buckets", default="2x2x4,4x2x7,8x2x13",
                    help="adaptive bucket ladder, comma-separated DxW or "
                         "DxWxV entries (e.g. 2x2,4x2x7)")
    ap.add_argument("--hysteresis", type=float, default=0.1,
                    help="relative score margin a challenger bucket must "
                         "beat the incumbent by before switching")
    ap.add_argument("--profile", default=None,
                    help="LatencyProfile JSON (default: synthetic)")
    ap.add_argument("--train-steps", type=int, default=240,
                    help="testbed training steps (checkpoint-cached per "
                         "value; 160 matches the benchmark/CI testbed)")
    ap.add_argument("--mesh", default=None,
                    help="device mesh: DxM (data x model, e.g. 4x2) or "
                         "'host'; default unsharded")
    ap.add_argument("--quantize", default="none",
                    choices=["none", "int8-kv", "int8-kv+w8"],
                    help="int8-kv: both KV caches int8 with per-slot scales "
                         "(greedy decode stays token-exact on the testbed); "
                         "+w8 adds int8 weight-only params")
    ap.add_argument("--verify-kernel", default="auto",
                    choices=["auto", "fused", "xla"],
                    help="decode/verify attention hot path: 'fused' = the "
                         "GQA-native length-aware Pallas kernel (interpret "
                         "mode on CPU), 'xla' = the einsum oracle path, "
                         "'auto' = fused on accelerators, xla on CPU")
    ap.add_argument("--log-level", default="INFO",
                    help="logging level for the event log (DEBUG..ERROR)")
    ap.add_argument("--log-json", action="store_true",
                    help="emit the event log as JSON lines instead of "
                         "key=value text")
    ap.add_argument("--trace-dir", default=None,
                    help="enable full telemetry and write trace.json "
                         "(Chrome/Perfetto), metrics.prom and metrics.json "
                         "to this directory on exit")
    ap.add_argument("--jax-profile", type=int, default=0, metavar="N",
                    help="with --trace-dir and --server continuous: capture "
                         "a jax.profiler device trace around the first N "
                         "megasteps (written under TRACE_DIR/jax)")
    args = ap.parse_args()

    configure_logging(args.log_level, args.log_json)
    # tracing only when asked (--trace-dir); the event log always runs —
    # continuous-server lifecycle events route through the same Telemetry
    telemetry = Telemetry(trace=args.trace_dir is not None)
    ev = telemetry.log

    mesh = make_serving_mesh(args.mesh)
    tb = build_testbed(TestbedSpec(train_steps=args.train_steps))
    prof = (LatencyProfile.load(args.profile) if args.profile
            else LatencyProfile.synthetic())
    engine = SpeculativeEngine(
        tb.drafter, tb.d_params, tb.verifier, tb.v_params, profile=prof,
        buckets=buckets_for_depths((2, 4, 8), width=2, verify_frac=0.75),
        depth_options=(2, 4, 8),
        config=EngineConfig(temperature=args.temperature, plan=args.plan,
                            quant=QuantConfig.parse(args.quantize),
                            verify_kernel=args.verify_kernel),
        mesh=mesh)
    cfg_fields = {"server": args.server, "plan": args.plan,
                  "verify_path": engine.verify_path(),
                  "requests": args.requests, "batch": args.batch,
                  "max_new": args.max_new}
    if mesh is not None:
        info = engine.mesh_info()
        cfg_fields["mesh"] = f"{info['shape']} over {info['devices']} devices"
    if args.quantize != "none":
        bps = engine.cache_bytes_per_slot()
        cfg_fields.update(quantize=args.quantize,
                          cache_bytes_per_slot=bps["total"])
    ev.emit("serve_config", **cfg_fields)

    if args.server == "continuous" and args.adaptive:
        ladder = parse_buckets(args.buckets)
        controller = BucketController(ladder, profile=prof,
                                      hysteresis=args.hysteresis)
        server = ContinuousServer(engine, batch_size=args.batch,
                                  prompt_pad=24, buckets=ladder,
                                  controller=controller,
                                  telemetry=telemetry)
        ev.emit("adaptive_ladder",
                ladder=",".join("x".join(map(str, b.key())) for b in ladder))
    elif args.server == "continuous":
        spec = egt_spec(args.depth, args.width)
        server = ContinuousServer(engine, batch_size=args.batch,
                                  prompt_pad=24, spec=spec,
                                  verify_v=max(2, (3 * spec.num_nodes) // 4),
                                  telemetry=telemetry)
    else:
        server = BatchedServer(engine, batch_size=args.batch, prompt_pad=24)

    src = MarkovSource(vocab=tb.spec.vocab,
                       concentration=tb.data_cfg.concentration)
    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        plen = int(rng.integers(8, 20))
        server.submit(Request(uid=uid, prompt=src.sample(rng, plen),
                              max_new=args.max_new))

    if (args.jax_profile > 0 and args.trace_dir
            and args.server == "continuous"):
        import jax.profiler
        server.warmup()
        jax_dir = os.path.join(args.trace_dir, "jax")
        try:
            jax.profiler.start_trace(jax_dir)
            server.run(max_steps=args.jax_profile)
            jax.profiler.stop_trace()
            ev.emit("jax_profile_written", dir=jax_dir,
                    megasteps=args.jax_profile)
        except Exception as e:  # profiler backends vary; never kill serving
            ev.emit("jax_profile_failed", level=logging.WARNING, error=str(e))
        done = server.run()
    else:
        done = server.run()

    if args.server == "continuous":
        for uid, req in sorted(done.items()):
            ev.emit("request_done", uid=uid, tokens=len(req.result),
                    queue_ms=round(req.stats["queue_s"] * 1e3, 1),
                    latency_ms=round(req.stats["latency_s"] * 1e3, 1))
        m = server.metrics.summary()
        ev.emit("summary", completed=m["completed"], steps=m["steps"],
                throughput_tok_s=round(m["throughput_tok_s"], 1),
                tpot_ms=round(m["tpot_ms"], 2), aal=round(m["aal"], 3),
                occupancy=round(m["occupancy"], 3), refills=m["refills"],
                recompiles_after_warmup=m["recompiles_after_warmup"])
        if args.adaptive:
            ev.emit("bucket_summary", switches=m["bucket_switches"],
                    **{f"bucket_{bk}": f"{bs['steps']} steps "
                       f"aal={bs['aal']:.2f} iter={bs['iter_ms']:.1f}ms"
                       for bk, bs in m["buckets"].items()})
    else:
        tot_tok, tot_t = 0, 0.0
        for uid, req in sorted(done.items()):
            s = req.stats
            ev.emit("request_done", uid=uid, tokens=len(req.result),
                    aal=round(s["aal"], 3), tpot_ms=round(s["tpot_ms"], 2))
            tot_tok += s["tokens"]
            tot_t += s["time_s"]
        ev.emit("summary", completed=len(done),
                tpot_ms=round(1e3 * tot_t / max(tot_tok, 1), 2))

    if args.trace_dir:
        _write_artifacts(telemetry, args.trace_dir, ev)


if __name__ == "__main__":
    main()
