"""Serving launcher: speculative decoding on the CPU testbed.

Builds (or restores) the aligned drafter/verifier pair, measures the
latency profile, and serves a queue of requests through the speculative
engine — the full Yggdrasil runtime at laptop scale. Two serving modes:

  * ``--server batched``    — one padded batch to completion per step (the
    single-tenant latency-optimal regime of §9).
  * ``--server continuous`` — continuous batching: a fixed pool of decode
    slots, retired requests replaced mid-flight via single-slot prefill,
    one pinned megastep executable replayed across slot churn.

Both servers also run mesh-sharded: ``--mesh DxM`` (e.g. ``--mesh 4x2``)
places the engine on a data×model device mesh — verifier/drafter params
tensor-parallel over ``model``, decode slots data-parallel over ``data`` —
via the logical-axis rules in sharding/specs.py. ``--mesh host`` spans
whatever devices exist; an infeasible request falls back to the host mesh.
On a CPU-only box, emulate devices first:
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --requests 8 --max-new 48
  PYTHONPATH=src python -m repro.launch.serve --server continuous \
      --requests 16 --batch 4
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --server continuous --mesh 4x2
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.buckets import buckets_for_depths, parse_buckets
from repro.core.egt import egt_spec
from repro.core.engine import EngineConfig, SpeculativeEngine
from repro.core.objective import LatencyProfile
from repro.data.pipeline import MarkovSource
from repro.launch.mesh import make_serving_mesh
from repro.quant import QuantConfig
from repro.serving.continuous import ContinuousServer
from repro.serving.controller import BucketController
from repro.serving.server import BatchedServer, Request
from repro.serving.testbed import TestbedSpec, build_testbed


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--server", default="batched",
                    choices=["batched", "continuous"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--plan", default="fused",
                    choices=["fused", "staged", "staged_device"])
    ap.add_argument("--depth", type=int, default=4,
                    help="pinned speculation depth (continuous mode)")
    ap.add_argument("--width", type=int, default=2,
                    help="pinned speculation width (continuous mode)")
    ap.add_argument("--adaptive", action="store_true",
                    help="continuous mode: precompile a bucket ladder and "
                         "let the online controller re-pick the bucket each "
                         "megastep (zero recompiles after warmup)")
    ap.add_argument("--buckets", default="2x2x4,4x2x7,8x2x13",
                    help="adaptive bucket ladder, comma-separated DxW or "
                         "DxWxV entries (e.g. 2x2,4x2x7)")
    ap.add_argument("--hysteresis", type=float, default=0.1,
                    help="relative score margin a challenger bucket must "
                         "beat the incumbent by before switching")
    ap.add_argument("--profile", default=None,
                    help="LatencyProfile JSON (default: synthetic)")
    ap.add_argument("--mesh", default=None,
                    help="device mesh: DxM (data x model, e.g. 4x2) or "
                         "'host'; default unsharded")
    ap.add_argument("--quantize", default="none",
                    choices=["none", "int8-kv", "int8-kv+w8"],
                    help="int8-kv: both KV caches int8 with per-slot scales "
                         "(greedy decode stays token-exact on the testbed); "
                         "+w8 adds int8 weight-only params")
    ap.add_argument("--verify-kernel", default="auto",
                    choices=["auto", "fused", "xla"],
                    help="decode/verify attention hot path: 'fused' = the "
                         "GQA-native length-aware Pallas kernel (interpret "
                         "mode on CPU), 'xla' = the einsum oracle path, "
                         "'auto' = fused on accelerators, xla on CPU")
    args = ap.parse_args()

    mesh = make_serving_mesh(args.mesh)
    tb = build_testbed(TestbedSpec())
    prof = (LatencyProfile.load(args.profile) if args.profile
            else LatencyProfile.synthetic())
    engine = SpeculativeEngine(
        tb.drafter, tb.d_params, tb.verifier, tb.v_params, profile=prof,
        buckets=buckets_for_depths((2, 4, 8), width=2, verify_frac=0.75),
        depth_options=(2, 4, 8),
        config=EngineConfig(temperature=args.temperature, plan=args.plan,
                            quant=QuantConfig.parse(args.quantize),
                            verify_kernel=args.verify_kernel),
        mesh=mesh)
    print(f"verify path: {engine.verify_path()}")
    if mesh is not None:
        info = engine.mesh_info()
        print(f"mesh: {info['shape']} over {info['devices']} devices")
    if args.quantize != "none":
        bps = engine.cache_bytes_per_slot()
        print(f"quantize: {args.quantize}  "
              f"cache bytes/slot={bps['total']}  "
              f"(verifier {bps['verifier']}, drafter {bps['drafter']})")

    if args.server == "continuous" and args.adaptive:
        ladder = parse_buckets(args.buckets)
        controller = BucketController(ladder, profile=prof,
                                      hysteresis=args.hysteresis)
        server = ContinuousServer(engine, batch_size=args.batch,
                                  prompt_pad=24, buckets=ladder,
                                  controller=controller)
        print("adaptive ladder: "
              + ", ".join("x".join(map(str, b.key())) for b in ladder))
    elif args.server == "continuous":
        spec = egt_spec(args.depth, args.width)
        server = ContinuousServer(engine, batch_size=args.batch,
                                  prompt_pad=24, spec=spec,
                                  verify_v=max(2, (3 * spec.num_nodes) // 4))
    else:
        server = BatchedServer(engine, batch_size=args.batch, prompt_pad=24)

    src = MarkovSource(vocab=tb.spec.vocab,
                       concentration=tb.data_cfg.concentration)
    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        plen = int(rng.integers(8, 20))
        server.submit(Request(uid=uid, prompt=src.sample(rng, plen),
                              max_new=args.max_new))
    done = server.run()

    if args.server == "continuous":
        for uid, req in sorted(done.items()):
            print(f"req {uid}: {len(req.result)} tokens  "
                  f"queue={req.stats['queue_s'] * 1e3:.0f}ms  "
                  f"latency={req.stats['latency_s'] * 1e3:.0f}ms")
        m = server.metrics.summary()
        print(f"served {m['completed']} requests in {m['steps']} steps; "
              f"{m['throughput_tok_s']:.0f} tok/s  "
              f"tpot={m['tpot_ms']:.1f}ms  aal={m['aal']:.2f}  "
              f"occupancy={m['occupancy']:.2f}  refills={m['refills']}  "
              f"recompiles_after_warmup={m['recompiles_after_warmup']}")
        if args.adaptive:
            print(f"bucket switches: {m['bucket_switches']}")
            for bk, bs in m["buckets"].items():
                print(f"  bucket {bk}: {bs['steps']} steps  "
                      f"aal={bs['aal']:.2f}  iter={bs['iter_ms']:.1f}ms")
    else:
        tot_tok, tot_t = 0, 0.0
        for uid, req in sorted(done.items()):
            s = req.stats
            print(f"req {uid}: {len(req.result)} tokens  "
                  f"aal={s['aal']:.2f}  tpot={s['tpot_ms']:.1f}ms")
            tot_tok += s["tokens"]
            tot_t += s["time_s"]
        print(f"served {len(done)} requests; aggregate TPOT "
              f"{1e3 * tot_t / max(tot_tok, 1):.1f} ms/token")


if __name__ == "__main__":
    main()
