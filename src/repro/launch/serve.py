"""Serving launcher: speculative decoding on the CPU testbed.

Builds (or restores) the aligned drafter/verifier pair, measures the
latency profile, and serves a queue of requests through the speculative
engine — the full Yggdrasil runtime at laptop scale. Three serving modes:

  * ``--server batched``    — one padded batch to completion per step (the
    single-tenant latency-optimal regime of §9).
  * ``--server continuous`` — continuous batching: a fixed pool of decode
    slots, retired requests replaced mid-flight via single-slot prefill,
    one pinned megastep executable replayed across slot churn.
  * ``--server frontend``   — the async serving front-end: ``--replicas N``
    continuous engines behind a session-affine SLO-aware router, each
    replica stepping in its own executor lane of one asyncio event loop.

Every flag is a field of :class:`repro.serving.ServeConfig` — the CLI is
generated from the dataclass, and ``benchmarks/fig_serving.py`` builds its
engines through the same ``ServeConfig.build_*`` helpers, so the launcher
and the benchmark cannot drift apart.

Both single-server modes also run mesh-sharded: ``--mesh DxM`` (e.g.
``--mesh 4x2``) places the engine on a data×model device mesh —
verifier/drafter params tensor-parallel over ``model``, decode slots
data-parallel over ``data`` — via the logical-axis rules in
sharding/specs.py. On a CPU-only box, emulate devices first:
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Observability: all output goes through a ``logging``-based event log —
one event per line, ``key=value`` text by default or JSON lines with
``--log-json``. ``--trace-dir DIR`` enables full telemetry and writes
``trace.json`` (Chrome trace — load it at https://ui.perfetto.dev),
``metrics.prom`` (Prometheus text) and ``metrics.json`` on exit;
``--jax-profile N`` additionally captures a ``jax.profiler`` device trace
around the first N continuous megasteps under ``DIR/jax``.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --requests 8 --max-new 48
  PYTHONPATH=src python -m repro.launch.serve --server continuous \
      --requests 16 --batch 4 --trace-dir /tmp/ygg-trace --log-json
  PYTHONPATH=src python -m repro.launch.serve --server frontend \
      --replicas 2 --batch 2 --requests 12 --slo-s 30
"""
from __future__ import annotations

import argparse
import asyncio
import json
import logging
import os

import numpy as np

from repro.core.objective import LatencyProfile
from repro.data.pipeline import MarkovSource
from repro.launch.mesh import make_serving_mesh
from repro.serving.config import ServeConfig
from repro.serving.server import Request
from repro.serving.testbed import TestbedSpec, build_testbed
from repro.telemetry import EventLog, Telemetry, configure_logging


def _write_artifacts(tel: Telemetry, trace_dir: str, ev: EventLog) -> None:
    os.makedirs(trace_dir, exist_ok=True)
    trace_p = os.path.join(trace_dir, "trace.json")
    tel.tracer.save(trace_p)
    with open(os.path.join(trace_dir, "metrics.prom"), "w") as f:
        f.write(tel.registry.to_prometheus())
    with open(os.path.join(trace_dir, "metrics.json"), "w") as f:
        json.dump(tel.registry.snapshot(), f, indent=1, default=float)
    ev.emit("artifacts_written", dir=trace_dir,
            overhead_s=round(tel.overhead_seconds(), 6))


def _requests(cfg: ServeConfig, tb) -> list:
    src = MarkovSource(vocab=tb.spec.vocab,
                       concentration=tb.data_cfg.concentration)
    rng = np.random.default_rng(0)
    return [Request(uid=uid, prompt=src.sample(rng, int(rng.integers(8, 20))),
                    max_new=cfg.max_new)
            for uid in range(cfg.requests)]


def _serve_frontend(cfg: ServeConfig, tb, prof, mesh, ev: EventLog) -> None:
    """Async multi-replica path: wall-clock event loop, executor lanes."""
    fe = cfg.build_frontend(tb, profile=prof, mesh=mesh)
    sessions = max(1, cfg.replicas)
    handles = [fe.submit(req, session=f"sess-{req.uid % sessions}",
                         deadline_s=cfg.slo_s or None)
               for req in _requests(cfg, tb)]
    asyncio.run(fe.run_until_drained())
    for h in handles:
        ev.emit("request_done", uid=h.uid, tokens=len(h.tokens),
                replica=h.replica, session=h.session, shed=h.shed)
    s = fe.summary()
    ev.emit("summary", completed=s["completed"], sheds=s["sheds"],
            goodput_under_slo=round(s["goodput_under_slo"], 4),
            tokens_delivered=s["tokens_delivered"],
            affinity_hits=s["router"]["affinity_hits"],
            routed=json.dumps(s["router"]["routed"]))
    for idx, rs in sorted(s["router"]["replicas"].items()):
        ev.emit("replica_summary", replica=idx, state=rs["state"],
                routed=rs["routed"], steps=rs["steps"],
                tokens=rs["tokens"],
                recompiles_after_warmup=rs["recompiles_after_warmup"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ServeConfig.add_args(ap)
    ap.add_argument("--config", default=None,
                    help="load a ServeConfig JSON; explicit flags override")
    args = ap.parse_args()
    if args.config:
        with open(args.config) as f:
            cfg = ServeConfig.from_json(json.load(f))
        # flags given on the command line win over the JSON file
        sentinel = argparse.ArgumentParser()
        ServeConfig.add_args(sentinel)
        defaults = vars(sentinel.parse_args([]))
        for k, v in vars(args).items():
            if k != "config" and v != defaults.get(k):
                setattr(cfg, k, v)
    else:
        cfg = ServeConfig.from_args(args)

    configure_logging(cfg.log_level, cfg.log_json)
    # tracing only when asked (--trace-dir); the event log always runs —
    # continuous-server lifecycle events route through the same Telemetry
    telemetry = Telemetry(trace=cfg.trace_dir is not None)
    ev = telemetry.log

    mesh = make_serving_mesh(cfg.mesh)
    tb = build_testbed(TestbedSpec(train_steps=cfg.train_steps))
    prof = (LatencyProfile.load(cfg.profile) if cfg.profile
            else LatencyProfile.synthetic())

    ev.emit("serve_config", **{k: v for k, v in cfg.to_json().items()
                               if v is not None})

    if cfg.server == "frontend":
        _serve_frontend(cfg, tb, prof, mesh, ev)
        if cfg.trace_dir:
            _write_artifacts(telemetry, cfg.trace_dir, ev)
        return

    engine = cfg.build_engine(tb, profile=prof, mesh=mesh)
    extra = {"verify_path": engine.verify_path()}
    if mesh is not None:
        info = engine.mesh_info()
        extra["mesh_placement"] = (f"{info['shape']} over "
                                   f"{info['devices']} devices")
    if cfg.quantize != "none":
        extra["cache_bytes_per_slot"] = engine.cache_bytes_per_slot()["total"]
    ev.emit("engine_built", **extra)

    server = cfg.build_server(engine, telemetry=telemetry)
    if cfg.server == "continuous" and cfg.adaptive:
        ev.emit("adaptive_ladder",
                ladder=",".join("x".join(map(str, b.key()))
                                for b in cfg.ladder()))

    for req in _requests(cfg, tb):
        server.submit(req)

    if (cfg.jax_profile > 0 and cfg.trace_dir
            and cfg.server == "continuous"):
        import jax.profiler
        server.warmup()
        jax_dir = os.path.join(cfg.trace_dir, "jax")
        try:
            jax.profiler.start_trace(jax_dir)
            server.serve(max_steps=cfg.jax_profile)
            jax.profiler.stop_trace()
            ev.emit("jax_profile_written", dir=jax_dir,
                    megasteps=cfg.jax_profile)
        except Exception as e:  # profiler backends vary; never kill serving
            ev.emit("jax_profile_failed", level=logging.WARNING, error=str(e))

    if cfg.server == "continuous":
        handles = server.serve()
        for uid, h in sorted(handles.items()):
            req = h.request
            ev.emit("request_done", uid=uid, tokens=len(req.result),
                    queue_ms=round(req.stats["queue_s"] * 1e3, 1),
                    latency_ms=round(req.stats["latency_s"] * 1e3, 1))
        m = server.metrics.summary()
        ev.emit("summary", completed=m["completed"], steps=m["steps"],
                throughput_tok_s=round(m["throughput_tok_s"], 1),
                tpot_ms=round(m["tpot_ms"], 2), aal=round(m["aal"], 3),
                occupancy=round(m["occupancy"], 3), refills=m["refills"],
                recompiles_after_warmup=m["recompiles_after_warmup"])
        if cfg.adaptive:
            ev.emit("bucket_summary", switches=m["bucket_switches"],
                    **{f"bucket_{bk}": f"{bs['steps']} steps "
                       f"aal={bs['aal']:.2f} iter={bs['iter_ms']:.1f}ms"
                       for bk, bs in m["buckets"].items()})
    else:
        done = server.run()
        tot_tok, tot_t = 0, 0.0
        for uid, req in sorted(done.items()):
            s = req.stats
            ev.emit("request_done", uid=uid, tokens=len(req.result),
                    aal=round(s["aal"], 3), tpot_ms=round(s["tpot_ms"], 2))
            tot_tok += s["tokens"]
            tot_t += s["time_s"]
        ev.emit("summary", completed=len(done),
                tpot_ms=round(1e3 * tot_t / max(tot_tok, 1), 2))

    if cfg.trace_dir:
        _write_artifacts(telemetry, cfg.trace_dir, ev)


if __name__ == "__main__":
    main()
