"""Post-compile HLO accounting for the roofline.

``compiled.cost_analysis()`` gives FLOPs and bytes-accessed, but (a) XLA's
HloCostAnalysis counts while-loop bodies ONCE (the block scan runs
``num_blocks`` times), and (b) collective bytes are not reported at all.
This module parses the optimized HLO text:

  * builds the computation call graph, with while-loop bodies weighted by
    their inferred trip count (parsed from the loop condition's comparison
    constant);
  * sums operand bytes of every all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute, scaled by the enclosing computation's
    execution multiplier;
  * reports the same multiplier table so flops/bytes from cost_analysis can
    be trip-count-corrected.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_COMP_NAME = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)")
_WHILE_RE = re.compile(
    r"=\s*\(?.*?while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALL_REF = re.compile(
    r"(?:to_apply|calls|true_computation|false_computation)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


@dataclass
class Collective:
    kind: str
    comp: str
    out_bytes: int
    group_size: int = 1
    multiplier: float = 1.0
    op_name: str = ""          # jax-level origin from HLO metadata

    @property
    def operand_bytes(self) -> float:
        """Input-buffer size (the 'operand size' roofline accounting)."""
        g = max(self.group_size, 1)
        if self.kind == "all-gather":
            return self.out_bytes / g
        if self.kind == "reduce-scatter":
            return self.out_bytes * g
        return float(self.out_bytes)

    @property
    def wire_bytes(self) -> float:
        """Ring-algorithm bytes actually crossing links, per device."""
        g = max(self.group_size, 1)
        if g == 1 and self.kind != "collective-permute":
            return 0.0
        if self.kind == "all-reduce":
            return 2.0 * self.out_bytes * (g - 1) / g
        if self.kind == "all-gather":
            return self.out_bytes * (g - 1) / g
        if self.kind == "reduce-scatter":
            return float(self.out_bytes * (g - 1))
        if self.kind == "all-to-all":
            return self.out_bytes * (g - 1) / g
        return float(self.out_bytes)  # collective-permute

    @property
    def total_bytes(self) -> float:
        return self.operand_bytes * self.multiplier

    @property
    def total_wire_bytes(self) -> float:
        return self.wire_bytes * self.multiplier


@dataclass
class HloReport:
    collectives: List[Collective] = field(default_factory=list)
    multipliers: Dict[str, float] = field(default_factory=dict)
    while_trips: Dict[str, int] = field(default_factory=dict)

    @property
    def collective_bytes(self) -> float:
        return float(sum(c.total_bytes for c in self.collectives))

    @property
    def collective_wire_bytes(self) -> float:
        return float(sum(c.total_wire_bytes for c in self.collectives))

    def bytes_by_kind(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for c in self.collectives:
            out[c.kind] = out.get(c.kind, 0.0) + c.total_bytes
        return out

    @property
    def loop_multiplier(self) -> float:
        """Largest execution multiplier (≈ the block-scan trip count) —
        used to trip-count-correct cost_analysis flops."""
        return max(self.multipliers.values(), default=1.0)


def split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    entry_name = None
    for line in hlo.splitlines():
        is_header = (line and not line[0].isspace()
                     and line.rstrip().endswith("{")
                     and (line.startswith("ENTRY") or line.startswith("%")))
        if is_header:
            m = _COMP_NAME.match(line)
            cur = m.group(1) if m else None
            if cur is not None:
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry_name = cur
        elif line.strip() == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(line)
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """The loop condition compares the induction var against a constant."""
    consts = []
    for ln in cond_lines:
        consts += [int(x) for x in _CONST_RE.findall(ln)]
    return max(consts) if consts else 1


def build_multipliers(comps: Dict[str, List[str]]) -> Tuple[Dict[str, float],
                                                            Dict[str, int]]:
    entry = comps.get("__entry__")
    mult: Dict[str, float] = {}
    trips: Dict[str, int] = {}
    if entry is None:
        return {name: 1.0 for name in comps}, trips

    # edges: comp -> [(callee, weight)]
    edges: Dict[str, List[Tuple[str, float]]] = {}
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        es: List[Tuple[str, float]] = []
        for ln in lines:
            wm = _WHILE_RE.search(ln)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                t = _trip_count(comps.get(cond, []))
                trips[body] = t
                es.append((body, float(t)))
                es.append((cond, float(t)))
                continue
            for ref in _CALL_REF.findall(ln):
                es.append((ref, 1.0))
            bm = _BRANCHES.search(ln)
            if bm:
                for ref in bm.group(1).split(","):
                    es.append((ref.strip().lstrip("%"), 1.0))
        edges[name] = es

    # find the true entry (computation whose lines == entry's)
    entry_names = [n for n, l in comps.items()
                   if n != "__entry__" and l is entry]
    roots = entry_names or [next(iter(edges))]
    for r in roots:
        mult[r] = 1.0
    stack = list(roots)
    while stack:
        c = stack.pop()
        for callee, w in edges.get(c, []):
            nm = mult[c] * w
            if mult.get(callee, 0.0) < nm:
                mult[callee] = nm
                stack.append(callee)
    for name in comps:
        mult.setdefault(name, 1.0)
    return mult, trips


_IOTA_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_LIST_GROUPS = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(s: str) -> int:
    m = _IOTA_GROUPS.search(s)
    if m:
        return int(m.group(2))  # [num_groups, group_size]<=[N]
    m = _LIST_GROUPS.search(s)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(len(ids), 1)
    return 1


def analyze(hlo: str) -> HloReport:
    comps = split_computations(hlo)
    mult, trips = build_multipliers(comps)
    rep = HloReport(multipliers=mult, while_trips=trips)
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        for ln in lines:
            s = ln.strip()
            if s.startswith("//") or "=" not in s:
                continue
            kind = None
            for k in COLLECTIVES:
                if re.search(rf"\b{k}(?:-start)?\(", s):
                    kind = k
                    break
            if kind is None or re.search(rf"\b{kind}-done\(", s):
                continue
            # output shapes: everything between '=' and the op name
            lhs_rhs = s.split("=", 1)[1]
            head = re.split(rf"\b{kind}(?:-start)?\(", lhs_rhs)[0]
            out_b = sum(_shape_bytes(d, dims)
                        for d, dims in _SHAPE_RE.findall(head))
            op = ""
            om = re.search(r'op_name="([^"]*)"', s)
            if om:
                op = om.group(1)
            rep.collectives.append(Collective(
                kind=kind, comp=name, out_bytes=out_b,
                group_size=_group_size(s), multiplier=mult.get(name, 1.0),
                op_name=op))
    return rep


def top_ops(hlo: str, n: int = 25) -> List[Dict]:
    """Largest instructions by output bytes × execution multiplier — the
    first-order 'where do the HBM bytes go' attribution for §Perf."""
    comps = split_computations(hlo)
    mult, _ = build_multipliers(comps)
    rows: List[Dict] = []
    for name, lines in comps.items():
        # fusion bodies don't touch HBM — only fusion boundaries count
        if name == "__entry__" or "fused_computation" in name:
            continue
        m = mult.get(name, 1.0)
        for ln in lines:
            s = ln.strip()
            if "=" not in s or s.startswith("//"):
                continue
            head = s.split("=", 1)[1]
            opk = re.search(r"\)?\s*([a-z][a-z0-9\-]*)\(", head)
            kind = opk.group(1) if opk else "?"
            if kind in ("parameter", "constant", "get-tuple-element", "tuple"):
                continue
            out_b = sum(_shape_bytes(d, dims) for d, dims in
                        _SHAPE_RE.findall(head.split(kind + "(")[0]))
            if out_b < (1 << 20):
                continue
            op = ""
            om = re.search(r'op_name="([^"]*)"', s)
            if om:
                op = om.group(1)
            rows.append({"kind": kind, "bytes": out_b * m, "mult": m,
                         "comp": name, "op": op[-120:]})
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:n]


def agg_ops(hlo: str, n: int = 20) -> List[Dict]:
    """top_ops aggregated over repeated instances (unrolled layers) by the
    normalized jax op_name — total output bytes per source op."""
    raw = top_ops(hlo, n=10 ** 6)
    agg: Dict[str, Dict] = {}
    for r in raw:
        key = re.sub(r"\d+", "#", f"{r['kind']}|{r['op']}")
        a = agg.setdefault(key, {"kind": r["kind"], "op": r["op"],
                                 "bytes": 0.0, "count": 0})
        a["bytes"] += r["bytes"]
        a["count"] += 1
    rows = sorted(agg.values(), key=lambda r: -r["bytes"])
    return rows[:n]


def top_collectives(rep: HloReport, n: int = 20) -> List[Dict]:
    """Largest collectives by total bytes, with jax-op attribution."""
    out = []
    for c in sorted(rep.collectives, key=lambda c: -c.total_bytes)[:n]:
        out.append({"kind": c.kind, "bytes": c.total_bytes,
                    "out_bytes": c.out_bytes, "group": c.group_size,
                    "mult": c.multiplier, "op": c.op_name[-120:]})
    return out
