import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512"))

"""§Perf hillclimbing driver (see EXPERIMENTS.md §Perf).

Each iteration is a named variant of one of the three chosen
(arch × shape) pairs: a ModelConfig override, a sharding-rule override, or
a custom mesh. Variants re-lower + re-compile and land as tagged JSONs next
to the baselines; the before/after table prints at the end.

  PYTHONPATH=src python -m repro.launch.hillclimb --pair mixtral_prefill
  PYTHONPATH=src python -m repro.launch.hillclimb --pair all --inspect
"""
import argparse      # noqa: E402
from typing import Dict, Optional  # noqa: E402

from repro.launch.dryrun import run_case  # noqa: E402

# iteration ladders: applied CUMULATIVELY in order (hillclimbing)
PAIRS: Dict[str, Dict] = {
    "mixtral_prefill": {
        "arch": "mixtral-8x7b", "shape": "prefill_32k",
        "iters": [
            ("it1_moe_batch_dispatch", dict(moe_batch_dispatch=True), None),
            ("it2_bf16_combine", dict(moe_combine_dtype="bfloat16"), None),
            ("it3_gqa_grouped", dict(gqa_grouped=True), None),
            # it4 is a CODE change: drop the out_e sharding constraint so
            # the w_out all-reduce commutes past the linear gather-combine
            # ([B,E,C,d] capacity-inflated -> [B,S,d]).
            ("it4_ar_after_combine", dict(), None),
        ],
    },
    "nemotron_decode": {
        "arch": "nemotron-4-15b", "shape": "decode_32k",
        "iters": [
            ("it1_gqa_grouped", dict(gqa_grouped=True), None),
            ("it2_cache_pad_seqshard", dict(cache_pad_to=256), None),
            ("it3_score_seqshard", dict(attn_score_seqshard=True), None),
            # it4 is a CODE change (mixed-precision P·V einsum instead of
            # materialized f32 cast, which XLA hoists above the per-layer
            # slice converting the whole stacked cache) — same overrides.
            ("it4_no_f32_v_cast", dict(), None),
        ],
    },
    "yi_train": {
        "arch": "yi-6b", "shape": "train_4k",
        "iters": [
            ("it1_gqa_grouped", dict(gqa_grouped=True), None),
            ("it2_bigger_attn_chunk", dict(attn_chunk=1024), None),
            ("it3_loss_chunk_256", dict(loss_chunk=256), None),
            ("it4_no_remat", dict(remat=False, attn_chunk=512,
                                  loss_chunk=512), None),
        ],
    },
}


def show(rec: Optional[Dict], label: str) -> None:
    if not rec or not rec.get("ok"):
        print(f"  {label:<28} FAILED: {(rec or {}).get('error')}")
        return
    r = rec["roofline"]
    print(f"  {label:<28} compute={r['compute_s']:.3e} "
          f"memory={r['memory_s']:.3e} collective={r['collective_s']:.3e} "
          f"dom={r['dominant']} useful={rec['useful_flops_ratio']:.2f}")


def run_pair(name: str, inspect: bool = False, force: bool = False) -> None:
    p = PAIRS[name]
    print(f"== {name}: {p['arch']} × {p['shape']} ==")
    base = run_case(p["arch"], p["shape"], multi_pod=False, verbose=False)
    show(base, "baseline")
    if inspect and base and base.get("ok"):
        for c in base.get("top_collectives", [])[:8]:
            print(f"    COLL {c['kind']:<18} {c['bytes']:.3e}B g={c['group']}"
                  f" {c['op'][-80:]}")
    overrides: Dict = {}
    for tag, conf, rules in p["iters"]:
        overrides.update(conf)
        rec = run_case(p["arch"], p["shape"], multi_pod=False,
                       overrides=dict(overrides), rules=rules,
                       tag_suffix="__" + tag, force=force, verbose=False)
        show(rec, tag)
        if inspect and rec and rec.get("ok"):
            for c in rec.get("top_collectives", [])[:5]:
                print(f"    COLL {c['kind']:<18} {c['bytes']:.3e}B "
                      f"{c['op'][-80:]}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default="all",
                    choices=list(PAIRS) + ["all"])
    ap.add_argument("--inspect", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    for name in (PAIRS if args.pair == "all" else [args.pair]):
        run_pair(name, inspect=args.inspect, force=args.force)


if __name__ == "__main__":
    main()
