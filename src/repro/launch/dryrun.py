import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512"))

import argparse            # noqa: E402
import json                # noqa: E402
import time                # noqa: E402
import traceback           # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402


from repro.configs import ASSIGNED, SHAPES  # noqa: E402
from repro.launch import hlo_analysis       # noqa: E402
from repro.launch import steps as steps_mod  # noqa: E402
from repro.launch.mesh import V5E, make_production_mesh, mesh_chips  # noqa: E402
from repro.sharding import specs as sh       # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")


def model_flops(cfg, shape, kind: str) -> float:
    """Analytic 'useful' FLOPs: 6·N_active·tokens (train), 2·N_active·tokens
    (inference)."""
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per request


def run_case(arch: str, shape_name: str, multi_pod: bool,
             kind: Optional[str] = None, unroll: bool = True,
             out_dir: str = RESULTS_DIR, force: bool = False,
             verbose: bool = True,
             overrides: Optional[Dict[str, Any]] = None,
             rules: Optional[Dict[str, Any]] = None,
             mesh=None, tag_suffix: str = "") -> Optional[Dict[str, Any]]:
    """Lower+compile one (arch, shape, mesh) case and record the roofline.

    `overrides` (ModelConfig fields), `rules` (sharding-rule overrides) and
    `mesh` (a custom jax Mesh) support §Perf hillclimb variants; tagged
    records land next to the baselines with `tag_suffix`.
    """
    case = steps_mod.dryrun_case(arch, shape_name,
                                 overrides={"scan_unroll": unroll,
                                            **(overrides or {})})
    mesh_name = ("pod2x16x16" if multi_pod else "pod16x16")
    if mesh is not None:
        mesh_name = "x".join(map(str, mesh.devices.shape))
    if case is None:
        if verbose:
            print(f"SKIP {arch} × {shape_name}: "
                  f"{steps_mod.LONG_SKIP.get(arch, 'n/a')}")
        return None
    kind = kind or case.shape.kind
    tag = f"{case.key}__{kind}__{mesh_name}" if kind != case.shape.kind \
        else f"{case.key}__{mesh_name}"
    tag += tag_suffix
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("ok"):
            if verbose:
                print(f"CACHED {tag}")
            return rec

    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    rec: Dict[str, Any] = {
        "arch": arch, "variant": case.variant, "shape": shape_name,
        "kind": kind, "mesh": mesh_name, "chips": chips,
        "params": case.cfg.param_count(),
        "active_params": case.cfg.active_param_count(),
        "unrolled": unroll, "ok": False,
    }
    if rules:
        rec["rules"] = {k: list(v) for k, v in rules.items()}
    t0 = time.perf_counter()
    try:
        with sh.use_mesh(mesh, rules=rules), mesh:
            jitted, args = steps_mod.build(case, mesh, kind=kind)
            lowered = jitted.lower(*args)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()
            cost = compiled.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
            try:
                ma = compiled.memory_analysis()
                mem = {k: int(getattr(ma, k)) for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "alias_size_in_bytes",
                    "generated_code_size_in_bytes") if hasattr(ma, k)}
            except Exception as e:  # CPU backend may not implement it
                mem = {"error": str(e)}
            hlo = compiled.as_text()
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if verbose:
            print(f"FAIL {tag}: {rec['error']}")
        return rec

    rep = hlo_analysis.analyze(hlo)
    if os.environ.get("REPRO_DUMP_OPS"):
        rec["agg_ops"] = hlo_analysis.agg_ops(hlo, 15)
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    if not unroll:  # trip-count correction (HloCostAnalysis counts bodies once)
        m = rep.loop_multiplier
        flops_dev *= m
        bytes_dev *= m
    coll_dev = rep.collective_bytes
    wire_dev = rep.collective_wire_bytes

    mf = model_flops(case.cfg, case.shape, kind)
    hw = V5E
    compute_s = flops_dev / hw.peak_flops_bf16
    memory_s = bytes_dev / hw.hbm_bw
    collective_s = coll_dev / hw.ici_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    rec.update({
        "ok": True,
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collective_wire_bytes_per_device": wire_dev,
        "collective_wire_s": wire_dev / hw.ici_bw,
        "collective_by_kind": rep.bytes_by_kind(),
        "top_collectives": hlo_analysis.top_collectives(rep, 12),
        "num_collectives": len(rep.collectives),
        "loop_multiplier": rep.loop_multiplier,
        "memory_analysis": mem,
        "model_flops_global": mf,
        "hlo_flops_global": flops_dev * chips,
        "useful_flops_ratio": mf / max(flops_dev * chips, 1.0),
        "roofline": {**terms, "dominant": dominant.replace("_s", "")},
    })
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        r = rec["roofline"]
        print(f"OK {tag}: compile={rec['compile_s']}s "
              f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
              f"collective={r['collective_s']:.3e}s dom={r['dominant']} "
              f"useful={rec['useful_flops_ratio']:.2f}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="Multi-pod dry-run matrix")
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES),
                    help="one shape (default: all)")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--kind", default=None,
                    choices=[None, "train", "prefill", "decode", "tree_verify"])
    ap.add_argument("--no-unroll", action="store_true",
                    help="keep lax.scan rolled (trip-count-corrected costs)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_case(arch, shape, mp, kind=args.kind,
                               unroll=not args.no_unroll, out_dir=args.out,
                               force=args.force)
                if rec is not None and not rec.get("ok"):
                    n_fail += 1
    if n_fail:
        raise SystemExit(f"{n_fail} case(s) failed")
    print("dry-run matrix complete")


if __name__ == "__main__":
    main()
