from repro.launch.mesh import (V5E, Hardware, make_host_mesh,
                               make_production_mesh, mesh_chips)
