"""Weight-only int8 quantization as a pytree transform.

`quantize_params` walks a parameter pytree and replaces every large
floating matmul weight with a `QTensor`: symmetric per-channel int8 with
fp32 absmax scales over the trailing axis (one scale per contraction row,
uniform across the heterogeneous einsum layouts in this codebase — stacked
block leaves keep their leading layer axis untouched). Small leaves (norm
scales, biases, SSM A/D/dt vectors) stay fp32: quantizing them saves
nothing and costs accuracy.

`QTensor` is a registered pytree node, so the quantized params pass
through jit, donation, `jax.device_put` and `tree_map` unchanged — every
compiled step simply calls `dequant_params` at the top of its graph and
traces against the dequantized fp32 view while HBM holds int8.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.quant.kv import EPS

# leaves smaller than this stay fp32 (norms, biases, rope tables, ...)
MIN_QUANT_SIZE = 2048


@jax.tree_util.register_pytree_node_class
class QTensor:
    """int8 payload + per-channel fp32 scales (trailing-axis groups)."""

    def __init__(self, q: jax.Array, scale: jax.Array, dtype=jnp.float32):
        self.q, self.scale = q, scale
        self.dtype = jnp.dtype(dtype)

    def tree_flatten(self):
        return (self.q, self.scale), self.dtype

    @classmethod
    def tree_unflatten(cls, dtype, children):
        return cls(*children, dtype=dtype)

    @property
    def shape(self):
        return self.q.shape

    def dequant(self) -> jax.Array:
        return (self.q.astype(jnp.float32)
                * self.scale[..., None]).astype(self.dtype)

    def __repr__(self):
        return f"QTensor(shape={tuple(self.q.shape)}, dtype={self.dtype})"


def _quantize_leaf(x: jax.Array) -> QTensor:
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.maximum(amax, EPS) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return QTensor(q, scale, dtype=x.dtype)


def _eligible(x: Any, min_size: int) -> bool:
    return (hasattr(x, "ndim") and x.ndim >= 2
            and jnp.issubdtype(x.dtype, jnp.floating)
            and x.size >= min_size)


def quantize_params(params: Any, min_size: int = MIN_QUANT_SIZE) -> Any:
    """Quantize every eligible leaf of a parameter pytree to `QTensor`."""

    def one(x):
        if isinstance(x, QTensor):      # idempotent
            return x
        if _eligible(x, min_size):
            return _quantize_leaf(x)
        return x

    return jax.tree.map(one, params,
                        is_leaf=lambda x: isinstance(x, QTensor))


def dequant_params(params: Any) -> Any:
    """fp view of a (possibly) quantized parameter pytree; identity when no
    leaf is a `QTensor`, so compiled steps can call it unconditionally."""
    return jax.tree.map(
        lambda x: x.dequant() if isinstance(x, QTensor) else x, params,
        is_leaf=lambda x: isinstance(x, QTensor))


def param_nbytes(params: Any) -> int:
    """Device bytes held by a parameter pytree (QTensor = payload + scales,
    since both are ordinary pytree leaves)."""
    return int(sum(x.size * jnp.dtype(x.dtype).itemsize
                   for x in jax.tree.leaves(params)))
