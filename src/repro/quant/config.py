"""QuantConfig: the one object the serving stack threads around.

Frozen (hashable) so it can sit inside `EngineConfig` and key jit caches.
The CLI surface is the mode string: ``none`` | ``int8-kv`` | ``int8-kv+w8``.
"""
from __future__ import annotations

from dataclasses import dataclass

MODES = ("none", "int8-kv", "int8-kv+w8")


@dataclass(frozen=True)
class QuantConfig:
    kv_dtype: str = "float32"   # "float32" | "int8"
    weights: bool = False       # int8 weight-only quantization of params

    @classmethod
    def parse(cls, mode: str) -> "QuantConfig":
        if mode in (None, "none"):
            return cls()
        if mode == "int8-kv":
            return cls(kv_dtype="int8")
        if mode == "int8-kv+w8":
            return cls(kv_dtype="int8", weights=True)
        raise ValueError(f"unknown quantize mode {mode!r}; pick one of {MODES}")

    @property
    def kv_int8(self) -> bool:
        return self.kv_dtype == "int8"

    @property
    def enabled(self) -> bool:
        return self.kv_int8 or self.weights

    @property
    def mode(self) -> str:
        if self.kv_int8:
            return "int8-kv+w8" if self.weights else "int8-kv"
        return "w8" if self.weights else "none"
