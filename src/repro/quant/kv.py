"""Symmetric int8 quantization of K/V tokens.

fp32 absmax scales per (batch, slot, kv-head) — sub-grouped along the head
dim (`KV_GROUP` channels per scale) so the worst-case dequant error is
small enough that greedy decode stays token-exact against fp32 on the
testbed (asserted in tests/test_quant.py). A token written once
dequantizes to the same values on every later read: the only rounding
happens at write time. Scales live alongside the int8 payload in the
cache entry and reset to 1.0 (not 0) so an empty slot dequantizes to
exact zeros.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

# channels per scale group along the head dim; head dims not divisible by
# this fall back to one scale per head (the coarsest group)
KV_GROUP = 16

# smallest representable group absmax; keeps scale > 0 so dequant of an
# all-zero group stays exact zero instead of 0/0
EPS = 1e-8


def kv_scale_groups(dh: int) -> int:
    """Scale groups per head: dh/KV_GROUP when divisible, else 1."""
    return dh // KV_GROUP if dh % KV_GROUP == 0 and dh >= KV_GROUP else 1


def quantize_kv(x: jax.Array, eps: float = EPS) -> Tuple[jax.Array, jax.Array]:
    """x: [..., Dh] fp -> (int8 [..., Dh], fp32 scales [..., G])."""
    dh = x.shape[-1]
    g = kv_scale_groups(dh)
    xf = x.astype(jnp.float32).reshape(*x.shape[:-1], g, dh // g)
    amax = jnp.max(jnp.abs(xf), axis=-1)               # [..., G]
    scale = jnp.maximum(amax, eps) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127)
    return q.astype(jnp.int8).reshape(x.shape), scale


def dequant_kv(q: jax.Array, scale: jax.Array,
               dtype=jnp.float32) -> jax.Array:
    """int8 payload [..., Dh] + group scales [..., G] -> values in `dtype`."""
    g = scale.shape[-1]
    dh = q.shape[-1]
    qf = q.astype(jnp.float32).reshape(*q.shape[:-1], g, dh // g)
    return (qf * scale[..., None]).reshape(q.shape).astype(dtype)
