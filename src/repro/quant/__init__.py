"""Quantized inference: int8 weight-only params and int8 KV caches.

Two independent knobs, combined by `QuantConfig`:

  * weight-only int8 — drafter/verifier params stored as `QTensor`
    (symmetric per-channel int8 + fp32 absmax scales) and dequantized
    in-graph at the top of every compiled step (`dequant_params`), so the
    HBM-resident weights are ~4x smaller while compute stays fp32.
  * int8 KV cache — both decode caches hold int8 K/V payloads with
    per-slot, per-head fp32 scales (see models/cache.py), quantized at
    write time and dequantized at read time; scales ride the same pytree
    so sharding, donation and the per-slot ops all keep working.
"""
from repro.quant.config import QuantConfig
from repro.quant.kv import dequant_kv, kv_scale_groups, quantize_kv
from repro.quant.weights import (QTensor, dequant_params, param_nbytes,
                                 quantize_params)

__all__ = ["QuantConfig", "QTensor", "quantize_params", "dequant_params",
           "param_nbytes", "quantize_kv", "dequant_kv", "kv_scale_groups"]
