"""Request lifecycle handle — the redesigned submission API.

``ContinuousServer.submit()`` (and ``ServingFrontend.submit()``) return a
:class:`RequestHandle` instead of asking the caller to hold onto a mutable
``Request`` and poll ``server.done``. The handle is the one object a client
needs: completion (`done()`), the final sequence (`result()`), everything
streamed so far (`tokens`), and token streaming — a sync iterator that
drives the owning server forward on demand, and an async iterator fed by
the serving front-end's event loop.

The handle never copies token flow out of band: the server's ``_credit``
path streams chunks into the handle (chained with any user ``stream``
callback), so sync and async consumers observe the exact committed tokens
in commit order.
"""
from __future__ import annotations

from typing import Callable, Iterator, List, Optional

import numpy as np


class RequestHandle:
    """Lifecycle view of one submitted request.

    * ``done()``    — has the request retired (or been shed)?
    * ``result()``  — final token array; on a sync server this PUMPS the
      server (``step()`` under the hood) until the request retires.
    * ``tokens``    — all tokens streamed so far, as a list of ints.
    * ``iter(handle)``  — sync streaming: yields tokens as they commit,
      pumping the server between chunks.
    * ``async for``     — async streaming under a ``ServingFrontend``; the
      front-end feeds the handle's queue from its event loop.
    """

    def __init__(self, request, pump: Optional[Callable[[], None]] = None):
        self.request = request
        self._pump = pump
        self._chunks: List[np.ndarray] = []
        self._shed = False
        self.shed_reason: Optional[str] = None
        # front-end attachments (set by ServingFrontend when routed)
        self.replica: Optional[int] = None
        self.session: Optional[str] = None
        self.priority: int = 0
        self.deadline: Optional[float] = None
        self._aqueue = None  # asyncio.Queue, attached by the front-end
        # failure recovery (managed by ServingFrontend)
        self.retries: int = 0        # replays consumed from the retry budget
        self.error: Optional[Exception] = None  # typed terminal failure
        self._replay_base = 0        # tokens delivered as of the last replay

    # ------------------------------------------------------------- state --
    @property
    def uid(self) -> int:
        return self.request.uid

    def done(self) -> bool:
        """True once the request retired (EOS / budget) or was shed."""
        return self._shed or self.request.result is not None

    @property
    def shed(self) -> bool:
        """True if admission control rejected the request before decode."""
        return self._shed

    @property
    def tokens(self) -> List[int]:
        """Every token streamed so far (commit order)."""
        return [int(t) for c in self._chunks for t in c]

    # ------------------------------------------------------------ results --
    def result(self) -> np.ndarray:
        """The final emitted sequence. If the request is still in flight and
        the handle is bound to a sync server, steps that server until the
        request retires; under a front-end (no pump), raises instead — await
        the async iterator or poll ``done()`` there."""
        while not self.done():
            if self._pump is None:
                raise RuntimeError(
                    "request is still in flight and this handle has no "
                    "server to pump — consume it via the front-end instead")
            self._pump()
        return self.request.result

    # ---------------------------------------------------------- streaming --
    def __iter__(self) -> Iterator[int]:
        """Sync streaming: yield committed tokens, pumping the server
        whenever the buffer runs dry and the request is still in flight."""
        sent = 0
        while True:
            toks = self.tokens
            while sent < len(toks):
                yield toks[sent]
                sent += 1
            if self.done():
                return
            if self._pump is None:
                raise RuntimeError(
                    "sync iteration needs a server-bound handle — under a "
                    "front-end, use `async for` instead")
            self._pump()

    def __aiter__(self):
        if self._aqueue is None:
            raise RuntimeError(
                "async streaming requires a ServingFrontend-managed handle")
        return self._astream()

    async def _astream(self):
        while True:
            chunk = await self._aqueue.get()
            if chunk is None:     # completion sentinel from the front-end
                return
            for t in chunk:
                yield int(t)

    # -------------------------------------------- server/front-end hooks --
    def _on_tokens(self, toks: np.ndarray) -> None:
        """Called from the owning server's commit path with each chunk."""
        if len(toks):
            self._chunks.append(np.asarray(toks, np.int64))

    def _mark_shed(self, reason: str) -> None:
        """Admission control rejected this request: terminal, empty result."""
        self._shed = True
        self.shed_reason = reason
        self.request.result = np.zeros(0, np.int64)
        self.request.stats = {"tokens": 0, "shed": True, "reason": reason}

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        state = ("shed" if self._shed else
                 "done" if self.done() else "in-flight")
        return (f"RequestHandle(uid={self.uid}, {state}, "
                f"tokens={sum(len(c) for c in self._chunks)})")
