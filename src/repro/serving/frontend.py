"""Async serving front-end: admission, priorities, deadlines, backpressure.

``ContinuousServer`` is an engine loop driven by a synchronous caller. The
:class:`ServingFrontend` is the production topology above it — an asyncio
event-loop orchestrator that multiplexes request submission over N engine
replicas (routed by :class:`~repro.serving.router.Router`), streams tokens
back through async iterators, and owns the request-level scheduling the
paper's latency-optimal megastep cannot see:

* **admission control** — a bounded priority queue in front of the
  replica pool; requests are released into a replica only when the pool
  has capacity, ordered by (priority, deadline, arrival);
* **backpressure** — load beyond the bound is *parked* (held, served
  when capacity frees) or *shed* (rejected with a terminal handle),
  and a request whose deadline is provably unmeetable at the modeled
  time-to-slot (``objective.step_latency`` priced, via
  ``Router.est_wait``) can be shed at admission instead of burning slots
  on tokens that will miss their SLO;
* **replica stepping** — each replica's blocking ``step()`` runs in an
  executor lane while the event loop keeps accepting submissions; on the
  emulated testbed the same code path is driven deterministically
  (sequential executor awaits, one shared ``EmulatedClock`` advanced by
  the max of concurrent replica step costs), so two identical drives are
  byte-identical.

The service-level number this layer optimizes is **goodput under SLO** —
the fraction of tokens delivered within their request's deadline (tokens
a shed request never got count against it) — not raw throughput: a
saturated pool generating late tokens is wasted work.
"""
from __future__ import annotations

import asyncio
import functools
import hashlib
import heapq
import json
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.objective import LatencyProfile
from repro.serving.continuous import ContinuousServer
from repro.serving.emulation import charged_step, fault_step_cost
from repro.serving.errors import (NoReplicaAvailable, NumericalFault,
                                  ReplicaError, ServingError, StepTimeout)
from repro.serving.faults import FaultEvent, FaultPlan
from repro.serving.handle import RequestHandle
from repro.serving.router import FAILED, Replica, Router
from repro.serving.server import Request
from repro.telemetry import Clock, EmulatedClock, WallClock


@dataclass
class AdmissionConfig:
    """Admission-control knobs for the front-end."""
    max_pending: int = 64          # front-queue bound before overload policy
    on_overload: str = "park"      # "park" (hold + backpressure) | "shed"
    shed_infeasible: bool = False  # shed when the deadline cannot be met
    queue_allowance: int = 0       # per-replica queued requests beyond free
    #                                slots before the pool counts as full
    slo_s: float = 0.0             # default deadline (s after submit); 0=none


@dataclass
class RecoveryConfig:
    """Failure-recovery knobs for the front-end's fault boundary."""
    retry_budget: int = 2          # replays per request before a terminal shed
    step_timeout_s: float = 0.0    # wall watchdog per step() (0 = disabled);
    #                                emulated hangs are charged this budget
    watchdog: int = 3              # consecutive transient errors -> FAILED
    backoff_s: float = 2.0         # first FAILED->RECOVERING backoff
    backoff_max_s: float = 60.0    # exponential backoff ceiling
    no_replica_timeout_s: float = 30.0  # queue-and-wait bound with no
    #                                     active replica before shedding


@dataclass
class FrontendMetrics:
    """Request- and token-level service counters (SLO accounting)."""
    submitted: int = 0
    dispatched: int = 0
    completed: int = 0
    parks: int = 0                # submissions that had to wait in the front
    sheds: int = 0
    shed_overload: int = 0
    shed_infeasible: int = 0
    shed_retry: int = 0           # replay budget exhausted
    shed_no_replica: int = 0      # waited out no_replica_timeout_s
    faults: int = 0               # typed step errors absorbed at the boundary
    replica_failures: int = 0     # replicas driven to FAILED
    replays: int = 0              # evacuated requests re-admitted elsewhere
    deadline_misses: int = 0      # completed, but last token was late
    tokens_delivered: int = 0
    tokens_in_slo: int = 0
    tokens_late: int = 0
    tokens_lost: int = 0          # requested tokens of shed requests
    latencies: List[float] = field(default_factory=list)

    @property
    def goodput_under_slo(self) -> float:
        """In-SLO tokens over every token the trace asked for — delivered
        (on time or late) plus the ones shed requests never got."""
        denom = self.tokens_delivered + self.tokens_lost
        return self.tokens_in_slo / max(1, denom)

    def summary(self) -> Dict:
        lat = np.asarray(self.latencies) if self.latencies else np.zeros(1)
        return {"submitted": self.submitted, "dispatched": self.dispatched,
                "completed": self.completed, "parks": self.parks,
                "sheds": self.sheds, "shed_overload": self.shed_overload,
                "shed_infeasible": self.shed_infeasible,
                "shed_retry": self.shed_retry,
                "shed_no_replica": self.shed_no_replica,
                "faults": self.faults,
                "replica_failures": self.replica_failures,
                "replays": self.replays,
                "deadline_misses": self.deadline_misses,
                "tokens_delivered": self.tokens_delivered,
                "tokens_in_slo": self.tokens_in_slo,
                "tokens_late": self.tokens_late,
                "tokens_lost": self.tokens_lost,
                "goodput_under_slo": self.goodput_under_slo,
                "latency_p50_s": float(np.percentile(lat, 50)),
                "latency_p95_s": float(np.percentile(lat, 95))}


class _Live:
    """Front-end-side delivery cursor for one in-flight handle."""

    __slots__ = ("handle", "chunks_seen", "deadline", "finished")

    def __init__(self, handle: RequestHandle):
        self.handle = handle
        self.chunks_seen = 0
        self.deadline = handle.deadline
        self.finished = False


class ServingFrontend:
    """Asyncio front-end multiplexing requests over N engine replicas."""

    def __init__(self, servers: Sequence[ContinuousServer],
                 profile: Optional[LatencyProfile] = None,
                 admission: Optional[AdmissionConfig] = None,
                 router: Optional[Router] = None,
                 clock: Optional[Clock] = None,
                 recovery: Optional[RecoveryConfig] = None):
        self.router = router if router is not None else Router(
            servers, profile=profile)
        self.profile = profile
        self.admission = admission or AdmissionConfig()
        self.recovery = recovery or RecoveryConfig()
        self.clock: Clock = clock or WallClock()
        self.metrics = FrontendMetrics()
        # front queue: (-priority, deadline-or-inf, seq) -> handle
        self._pending: List[Tuple[float, float, int, RequestHandle]] = []
        self._seq = 0
        self._live: Dict[int, _Live] = {}
        self._all: Dict[int, RequestHandle] = {}   # every handle ever issued
        self._no_active_since: Optional[float] = None
        # emulated pool_exhaust faults: replica idx -> [(restore_at, pages)]
        self._stolen: Dict[int, List[Tuple[float, List[int]]]] = {}

    # ---------------------------------------------------------- admission --
    def submit(self, req: Request, session: Optional[str] = None,
               priority: int = 0,
               deadline_s: Optional[float] = None) -> RequestHandle:
        """Admit one request. Returns a handle immediately — possibly
        already terminal (``handle.shed``) if admission control rejected
        it. Higher ``priority`` dispatches first; ``deadline_s`` is seconds
        from now (defaults to the admission config's SLO, 0 = none)."""
        now = self.clock.now()
        if req.t_submit is None:    # preserved across recovery resubmissions
            req.t_submit = now
        handle = RequestHandle(req)
        handle.session = session
        handle.priority = priority
        slo = deadline_s if deadline_s is not None else (
            self.admission.slo_s or None)
        handle.deadline = (now + slo) if slo else None
        handle._aqueue = asyncio.Queue()
        self._all[req.uid] = handle
        self.metrics.submitted += 1

        if len(self._pending) >= self.admission.max_pending:
            if self.admission.on_overload == "shed":
                # shed by PRIORITY, not by arrival: if the newcomer outranks
                # the worst parked entry (lowest priority, then latest
                # deadline, then latest arrival — exactly the heap order
                # reversed), evict that victim and admit the newcomer
                victim = max(self._pending) if self._pending else None
                if victim is not None and -float(priority) < victim[0]:
                    self._pending.remove(victim)
                    heapq.heapify(self._pending)
                    self._shed(victim[3], "overload")
                    self.metrics.shed_overload += 1
                else:
                    self._shed(handle, "overload")
                    self.metrics.shed_overload += 1
                    return handle
            else:
                self.metrics.parks += 1  # park: hold it, count backpressure
        heapq.heappush(self._pending,
                       (-float(priority),
                        handle.deadline if handle.deadline is not None
                        else float("inf"),
                        self._seq, handle))
        self._seq += 1
        self._dispatch()
        return handle

    def _shed(self, handle: RequestHandle, reason: str) -> None:
        handle._mark_shed(reason)
        self.metrics.sheds += 1
        self.metrics.tokens_lost += int(handle.request.max_new)
        live = self._live.get(handle.uid)
        if live is not None:        # shed after dispatch (retry budget,
            live.finished = True    # no-replica): close the delivery cursor
        if handle._aqueue is not None:
            handle._aqueue.put_nowait(None)

    def _has_capacity(self) -> bool:
        allow = self.admission.queue_allowance
        return any(r.free_slots() + allow - r.queued() > 0
                   for r in self.router.active())

    def _dispatch(self) -> int:
        """Release front-queued requests into replicas while the pool has
        capacity; shed provably-infeasible deadlines when configured.
        Returns how many requests were dispatched."""
        n = 0
        while self._pending and self.router.active():
            if not self._has_capacity():
                break
            _, _, _, handle = heapq.heappop(self._pending)
            if handle.shed:      # shed while parked (overload race) — skip
                continue
            if (handle.deadline is not None
                    and self.admission.shed_infeasible):
                best = min(self.router.est_wait(r)
                           for r in self.router.active())
                if self.clock.now() + best > handle.deadline:
                    self._shed(handle, "deadline-infeasible")
                    self.metrics.shed_infeasible += 1
                    continue
            rep, _ = self.router.submit(handle.request, handle=handle,
                                        session=handle.session)
            tr = rep.server._tr
            if tr is not None:   # span edge: this request -> its replica
                tr.instant(f"routed→replica:{rep.idx}",
                           track=f"req:{handle.uid}", replica=rep.idx)
            if handle.uid not in self._live:
                # replayed handles keep their _Live: the chunks_seen cursor
                # is what guarantees already-delivered tokens are never
                # re-delivered after a token-exact replay
                self._live[handle.uid] = _Live(handle)
            self.metrics.dispatched += 1
            n += 1
        return n

    # ----------------------------------------------------------- delivery --
    def _drain_handles(self, rep: Replica) -> None:
        """Move newly committed chunks from this replica's handles to their
        async consumers and do the SLO token accounting. Delivery time is
        the front-end clock NOW — after the step (and, emulated, its
        charged cost), which is when a real client would see the bytes."""
        t = self.clock.now()
        for uid in list(rep.server.handles):
            live = self._live.get(uid)
            if live is None or live.finished:
                continue
            h = live.handle
            while live.chunks_seen < len(h._chunks):
                chunk = h._chunks[live.chunks_seen]
                live.chunks_seen += 1
                k = len(chunk)
                self.metrics.tokens_delivered += k
                if live.deadline is None or t <= live.deadline:
                    self.metrics.tokens_in_slo += k
                else:
                    self.metrics.tokens_late += k
                if h._aqueue is not None:
                    h._aqueue.put_nowait(chunk)
            if h.done():
                live.finished = True
                if h.retries and not h.shed:
                    # the finishing server only saw the replayed tail; the
                    # handle's chunk log is the full stream — patch the
                    # request result so digests cover every delivered token
                    h.request.result = np.asarray(h.tokens, np.int64)
                self.metrics.completed += 1
                self.metrics.latencies.append(t - h.request.t_submit)
                if live.deadline is not None and t > live.deadline:
                    self.metrics.deadline_misses += 1
                if h._aqueue is not None:
                    h._aqueue.put_nowait(None)

    def _drained(self) -> bool:
        return (not self._pending
                and not any(r.has_work() for r in self.router.live()))

    # ------------------------------------------------------ fault boundary --
    def _on_step_error(self, rep: Replica, exc: Exception,
                       now: float) -> None:
        """Typed exception boundary around one replica step. Fatal faults
        (crash, watchdog timeout, numerical corruption) fail the replica
        immediately; transient ones count against the consecutive-error
        watchdog and fail it once the budget is burned."""
        rep.faults_seen += 1
        self.metrics.faults += 1
        fatal = isinstance(exc, (StepTimeout, NumericalFault)) or (
            isinstance(exc, ReplicaError) and exc.fatal)
        if not fatal:
            rep.consecutive_errors += 1
            if rep.consecutive_errors >= self.recovery.watchdog:
                fatal = True
        if fatal:
            self._fail_replica(rep, now, reason=type(exc).__name__)

    def _fail_replica(self, rep: Replica, now: float,
                      reason: str = "") -> None:
        """FAIL a replica: evacuate every queued/in-flight request and
        replay each one (token-exact) on the surviving pool; schedule the
        exponential-backoff recovery. The replica's executable cache stays
        warm, so rejoining later costs zero compiles."""
        self.router.fail(rep.idx)
        self.metrics.replica_failures += 1
        rep.failed_at = now
        rep.consecutive_errors = 0
        back = min(self.recovery.backoff_s * (2 ** max(0, rep.failures - 1)),
                   self.recovery.backoff_max_s)
        rep.recover_at = now + back
        tr = rep.server._tr
        if tr is not None:   # MTTR span: closed by _maybe_recover
            tr.begin("failed", track=f"replica:{rep.idx}", reason=reason)
        for req, handle in rep.server.evacuate():
            self._replay(req, handle, rep)
        self._dispatch()

    def _replay(self, req: Request, handle: Optional[RequestHandle],
                rep: Replica) -> None:
        """Re-admit one evacuated request with token-exact replay: the
        effective prompt becomes original-prompt + already-delivered tokens
        (re-prefilled through the chunk lane, adopting resident prefix
        pages where shared), and ``max_new`` shrinks by exactly the tokens
        delivered since the last replay — so the continuation the verifier
        commits is byte-identical to the fault-free run."""
        if handle is None or handle.shed or handle.done():
            return
        if handle.retries >= self.recovery.retry_budget:
            handle.error = ReplicaError(
                f"retry budget ({self.recovery.retry_budget}) exhausted "
                f"after replica {rep.idx} failed")
            self._shed(handle, "retry-budget")
            self.metrics.shed_retry += 1
            return
        handle.retries += 1
        rep.replays += 1
        self.metrics.replays += 1
        delivered = handle.tokens
        if delivered:
            pad = rep.server.prompt_pad
            prompt = np.asarray(req.prompt, np.int32).reshape(-1)[:pad]
            req.replay_prefix = np.concatenate(
                [prompt, np.asarray(delivered, np.int32)])
            d = len(delivered)
            req.max_new = int(req.max_new) - (d - handle._replay_base)
            handle._replay_base = d
        heapq.heappush(self._pending,
                       (-float(handle.priority or 0),
                        handle.deadline if handle.deadline is not None
                        else float("inf"),
                        self._seq, handle))
        self._seq += 1

    def _maybe_recover(self, now: float) -> None:
        """Readmit FAILED replicas whose backoff has elapsed."""
        for rep in self.router.replicas:
            if (rep.state == FAILED and rep.recover_at is not None
                    and now >= rep.recover_at):
                self.router.recover(rep.idx)
                if rep.failed_at is not None:
                    rep.mttr_total += now - rep.failed_at
                    rep.failed_at = None
                tr = rep.server._tr
                if tr is not None:
                    tr.end(track=f"replica:{rep.idx}")
                self._no_active_since = None   # capacity is back

    def _check_no_replica(self, now: float) -> None:
        """Queue-and-wait when no replica is active, bounded by
        ``no_replica_timeout_s`` — then shed the front queue with a typed
        :class:`NoReplicaAvailable` on each handle."""
        if self.router.active():
            self._no_active_since = None
            return
        if not self._pending:
            return
        if self._no_active_since is None:
            self._no_active_since = now
            return
        waited = now - self._no_active_since
        if waited < self.recovery.no_replica_timeout_s:
            return
        while self._pending:
            _, _, _, handle = heapq.heappop(self._pending)
            if handle.shed:
                continue
            handle.error = NoReplicaAvailable(waited_s=waited)
            self._shed(handle, "no-replica")
            self.metrics.shed_no_replica += 1
        self._no_active_since = None

    def _update_degraded(self) -> None:
        """Graceful degradation: with a replica down or the pool past the
        overload knee, pin every live controller to its shallowest warmed
        bucket (the cheapest compiled step — cannot recompile)."""
        flag = (any(r.state == FAILED for r in self.router.replicas)
                or self.router.occupancy() > 1.0)
        for rep in self.router.live():
            rep.server.set_degraded(flag)

    # ---- emulated pool_exhaust faults: steal/restore free pages ----------
    @staticmethod
    def _page_state(rep: Replica):
        return getattr(getattr(rep.server, "state", None), "pages", None)

    def _steal_pages(self, rep: Replica, ev: FaultEvent,
                     now: float) -> None:
        ps = self._page_state(rep)
        if ps is None:
            return
        take = ev.pages or len(ps.free)
        stolen = [ps.free.pop() for _ in range(min(take, len(ps.free)))]
        self._stolen.setdefault(rep.idx, []).append(
            (now + (ev.duration_s or 1.0), stolen))

    def _restore_stolen(self, now: float) -> None:
        for idx, windows in list(self._stolen.items()):
            keep = []
            for until, pages in windows:
                if now >= until:
                    ps = self._page_state(self.router.replicas[idx])
                    if ps is not None:
                        ps.free.extend(pages)
                else:
                    keep.append((until, pages))
            if keep:
                self._stolen[idx] = keep
            else:
                self._stolen.pop(idx)

    def _emulated_step(self, rep: Replica, profile: LatencyProfile,
                       fault: Optional[FaultEvent]
                       ) -> Tuple[float, Optional[Exception]]:
        """One profile-charged replica step with optional fault injection.
        Returns ``(emulated cost, error-or-None)`` — a failed step still
        costs emulated time (a crash is instant, a hang burns the watchdog
        budget, a mid-step fault burns the nominal step latency)."""
        if fault is not None:
            now = self.clock.now()
            if fault.kind == "crash":
                return 0.0, ReplicaError(
                    f"injected crash on replica {rep.idx}")
            if fault.kind == "hang":
                budget = (self.recovery.step_timeout_s
                          or fault.duration_s or 1.0)
                return budget, StepTimeout(
                    f"injected hang on replica {rep.idx}", timeout_s=budget)
            if fault.kind == "error":
                return fault.duration_s, ReplicaError(
                    f"injected transient error on replica {rep.idx}",
                    fatal=False)
            if fault.kind == "nan":
                poison = getattr(rep.server.engine, "poison_next_step", None)
                if callable(poison):
                    poison()
            elif fault.kind == "pool_exhaust":
                self._steal_pages(rep, fault, now)
        try:
            cost, _ = charged_step(rep.server, profile, advance_clock=False)
            return cost, None
        except ServingError as e:
            return fault_step_cost(rep.server, profile), e

    # ---------------------------------------------------- wall-clock mode --
    async def run_until_drained(self, poll_s: float = 0.001) -> Dict:
        """Serve until every submitted request completes (live wall-clock
        mode): one executor lane per replica runs the blocking ``step()``
        off the event loop while submissions keep landing. Every step runs
        inside the typed fault boundary — a raising or watchdog-late step
        fails its replica, evacuates + replays its work, and the lane keeps
        polling until the replica's backoff readmits it."""
        loop = asyncio.get_running_loop()
        pool = ThreadPoolExecutor(
            max_workers=max(1, len(self.router.replicas)),
            thread_name_prefix="replica-step")
        try:
            for rep in self.router.replicas:   # compile before serving
                if rep.server._compile_base is None:
                    await loop.run_in_executor(pool, rep.server.warmup)

            async def wall_step(rep: Replica):
                fut = loop.run_in_executor(pool, rep.server.step)
                timeout = self.recovery.step_timeout_s or None
                if timeout is None:
                    await fut
                    return
                try:
                    await asyncio.wait_for(asyncio.shield(fut), timeout)
                except asyncio.TimeoutError:
                    # the blocking thread cannot be killed: wait it out so
                    # its committed chunks are kept, then declare the
                    # replica wedged — the watchdog verdict stands even
                    # though the step eventually returned
                    try:
                        await fut
                    except Exception:
                        pass
                    raise StepTimeout(
                        f"step on replica {rep.idx} exceeded the "
                        f"{timeout:.3g}s watchdog", timeout_s=timeout)

            async def lane(rep: Replica):
                while True:
                    now = self.clock.now()
                    self._maybe_recover(now)
                    self._check_no_replica(now)
                    self._dispatch()
                    self._update_degraded()
                    if rep.steppable() and rep.has_work():
                        try:
                            await wall_step(rep)
                        except ServingError as e:
                            self._drain_handles(rep)   # committed chunks
                            self._on_step_error(rep, e, self.clock.now())
                            continue
                        except Exception as e:  # untyped: same boundary
                            self._drain_handles(rep)
                            self._on_step_error(rep, ReplicaError(repr(e)),
                                                self.clock.now())
                            continue
                        rep.consecutive_errors = 0
                        self._drain_handles(rep)
                        self.router.reap()
                    elif self._drained():
                        return
                    else:
                        await asyncio.sleep(poll_s)

            await asyncio.gather(*(lane(r) for r in self.router.replicas))
        finally:
            pool.shutdown(wait=True)
        return self.summary()

    # ------------------------------------------------------ emulated mode --
    async def serve_trace(self, trace, profile: LatencyProfile,
                          events: Sequence[Tuple[float, str, int]] = (),
                          faults: Optional[FaultPlan] = None) -> Dict:
        """Deterministic emulated drive: replay ``trace`` (arrival-sorted
        ``(t, Request)`` or ``(t, Request, extras)`` rows, extras =
        ``{"deadline_s", "session", "priority"}``) against the replica
        pool on ONE shared ``EmulatedClock``. Per round every steppable
        replica with work runs one profile-charged step in the executor
        lane; the clock advances by the MAX of the concurrent step costs
        (replicas run in parallel in the topology this emulates).
        ``events`` injects ``(t, "drain"|"scale_down"|"scale_up"|"fail"|
        "recover", replica_idx)`` lifecycle transitions at emulated times;
        ``faults`` is a :class:`FaultPlan` whose events fire at each target
        replica's first step at-or-after their timestamps — the same plan
        against the same trace is byte-deterministic."""
        clock = (self.clock if isinstance(self.clock, EmulatedClock)
                 else EmulatedClock())
        self.clock = clock
        for rep in self.router.replicas:
            rep.server.set_clock(clock)
            rep.server.warmup()            # uncharged, off the traced path
        loop = asyncio.get_running_loop()
        arrivals = [(row[0], row[1], row[2] if len(row) > 2 else {})
                    for row in trace]
        arrivals.sort(key=lambda r: r[0])
        todo = sorted(events, key=lambda e: e[0])
        busy = {rep.idx: 0.0 for rep in self.router.replicas}

        while (arrivals or todo or self._pending
               or any(r.has_work() for r in self.router.live())):
            now = clock.now()
            self._restore_stolen(now)
            self._maybe_recover(now)
            while todo and todo[0][0] <= now:
                _, kind, idx = todo.pop(0)
                getattr(self.router, kind)(idx)
            while arrivals and arrivals[0][0] <= now:
                _, req, extra = arrivals.pop(0)
                self.submit(req, session=extra.get("session"),
                            priority=extra.get("priority", 0),
                            deadline_s=extra.get("deadline_s"))
            self._check_no_replica(now)
            self._dispatch()
            self._update_degraded()
            workers = [r for r in self.router.replicas
                       if r.steppable() and r.has_work()]
            if not workers:
                # idle: jump to whichever state change comes first — the
                # next arrival/event, a FAILED replica's backoff expiry, a
                # stolen-page restore, or the no-replica shed deadline
                horizon = [t for t, *_ in arrivals[:1]] + \
                          [t for t, *_ in todo[:1]]
                horizon += [r.recover_at for r in self.router.replicas
                            if r.state == FAILED and r.recover_at is not None]
                horizon += [until for ws in self._stolen.values()
                            for until, _ in ws]
                if self._no_active_since is not None:
                    horizon.append(self._no_active_since
                                   + self.recovery.no_replica_timeout_s)
                if not horizon:
                    break
                clock.advance_to(max(min(horizon), now + 1e-9))
                continue
            costs, stepped = [], []
            for rep in workers:      # sequential awaits: deterministic
                fault = (faults.pop_due(rep.idx, now)
                         if faults is not None else None)
                cost, err = await loop.run_in_executor(
                    None, functools.partial(self._emulated_step, rep,
                                            profile, fault))
                busy[rep.idx] += cost
                costs.append(cost)
                stepped.append((rep, err))
            clock.advance(max(costs))
            for rep, err in stepped:
                # deliver committed chunks BEFORE any evacuation — a fault
                # must never claw back tokens the step already committed
                self._drain_handles(rep)
                if err is None:
                    rep.consecutive_errors = 0
                else:
                    self._on_step_error(rep, err, clock.now())
            self.router.reap()
        out = self.summary()
        out["makespan_s"] = clock.now()
        out["busy_s"] = {str(k): v for k, v in busy.items()}
        out["throughput_tok_s"] = (self.metrics.tokens_delivered
                                   / max(out["makespan_s"], 1e-9))
        if faults is not None:
            out["faults"] = faults.summary()
        return out

    # ------------------------------------------------------------ results --
    def handles(self) -> Dict[int, RequestHandle]:
        return dict(self._all)

    def results_digest(self) -> str:
        """SHA-1 over every request's uid -> emitted tokens (shed included,
        empty) — the byte-determinism witness two identical emulated drives
        must agree on."""
        blob = {str(u): h.tokens for u, h in self._all.items()}
        return hashlib.sha1(
            json.dumps(blob, sort_keys=True).encode()).hexdigest()

    def summary(self) -> Dict:
        return {**self.metrics.summary(),
                "goodput_under_slo": self.metrics.goodput_under_slo,
                "router": self.router.summary(),
                "results_digest": self.results_digest()}


def drive_frontend_trace(frontend: ServingFrontend, trace,
                         profile: LatencyProfile,
                         events: Sequence[Tuple[float, str, int]] = (),
                         faults: Optional[FaultPlan] = None) -> Dict:
    """Sync entry point for benchmarks/tests: run the front-end's emulated
    drive to completion on a private event loop."""
    return asyncio.run(frontend.serve_trace(trace, profile, events=events,
                                            faults=faults))
