"""Async serving front-end: admission, priorities, deadlines, backpressure.

``ContinuousServer`` is an engine loop driven by a synchronous caller. The
:class:`ServingFrontend` is the production topology above it — an asyncio
event-loop orchestrator that multiplexes request submission over N engine
replicas (routed by :class:`~repro.serving.router.Router`), streams tokens
back through async iterators, and owns the request-level scheduling the
paper's latency-optimal megastep cannot see:

* **admission control** — a bounded priority queue in front of the
  replica pool; requests are released into a replica only when the pool
  has capacity, ordered by (priority, deadline, arrival);
* **backpressure** — load beyond the bound is *parked* (held, served
  when capacity frees) or *shed* (rejected with a terminal handle),
  and a request whose deadline is provably unmeetable at the modeled
  time-to-slot (``objective.step_latency`` priced, via
  ``Router.est_wait``) can be shed at admission instead of burning slots
  on tokens that will miss their SLO;
* **replica stepping** — each replica's blocking ``step()`` runs in an
  executor lane while the event loop keeps accepting submissions; on the
  emulated testbed the same code path is driven deterministically
  (sequential executor awaits, one shared ``EmulatedClock`` advanced by
  the max of concurrent replica step costs), so two identical drives are
  byte-identical.

The service-level number this layer optimizes is **goodput under SLO** —
the fraction of tokens delivered within their request's deadline (tokens
a shed request never got count against it) — not raw throughput: a
saturated pool generating late tokens is wasted work.
"""
from __future__ import annotations

import asyncio
import functools
import hashlib
import heapq
import json
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.objective import LatencyProfile
from repro.serving.continuous import ContinuousServer
from repro.serving.emulation import charged_step
from repro.serving.handle import RequestHandle
from repro.serving.router import RETIRED, Replica, Router
from repro.serving.server import Request
from repro.telemetry import Clock, EmulatedClock, WallClock


@dataclass
class AdmissionConfig:
    """Admission-control knobs for the front-end."""
    max_pending: int = 64          # front-queue bound before overload policy
    on_overload: str = "park"      # "park" (hold + backpressure) | "shed"
    shed_infeasible: bool = False  # shed when the deadline cannot be met
    queue_allowance: int = 0       # per-replica queued requests beyond free
    #                                slots before the pool counts as full
    slo_s: float = 0.0             # default deadline (s after submit); 0=none


@dataclass
class FrontendMetrics:
    """Request- and token-level service counters (SLO accounting)."""
    submitted: int = 0
    dispatched: int = 0
    completed: int = 0
    parks: int = 0                # submissions that had to wait in the front
    sheds: int = 0
    shed_overload: int = 0
    shed_infeasible: int = 0
    deadline_misses: int = 0      # completed, but last token was late
    tokens_delivered: int = 0
    tokens_in_slo: int = 0
    tokens_late: int = 0
    tokens_lost: int = 0          # requested tokens of shed requests
    latencies: List[float] = field(default_factory=list)

    @property
    def goodput_under_slo(self) -> float:
        """In-SLO tokens over every token the trace asked for — delivered
        (on time or late) plus the ones shed requests never got."""
        denom = self.tokens_delivered + self.tokens_lost
        return self.tokens_in_slo / max(1, denom)

    def summary(self) -> Dict:
        lat = np.asarray(self.latencies) if self.latencies else np.zeros(1)
        return {"submitted": self.submitted, "dispatched": self.dispatched,
                "completed": self.completed, "parks": self.parks,
                "sheds": self.sheds, "shed_overload": self.shed_overload,
                "shed_infeasible": self.shed_infeasible,
                "deadline_misses": self.deadline_misses,
                "tokens_delivered": self.tokens_delivered,
                "tokens_in_slo": self.tokens_in_slo,
                "tokens_late": self.tokens_late,
                "tokens_lost": self.tokens_lost,
                "goodput_under_slo": self.goodput_under_slo,
                "latency_p50_s": float(np.percentile(lat, 50)),
                "latency_p95_s": float(np.percentile(lat, 95))}


class _Live:
    """Front-end-side delivery cursor for one in-flight handle."""

    __slots__ = ("handle", "chunks_seen", "deadline", "finished")

    def __init__(self, handle: RequestHandle):
        self.handle = handle
        self.chunks_seen = 0
        self.deadline = handle.deadline
        self.finished = False


class ServingFrontend:
    """Asyncio front-end multiplexing requests over N engine replicas."""

    def __init__(self, servers: Sequence[ContinuousServer],
                 profile: Optional[LatencyProfile] = None,
                 admission: Optional[AdmissionConfig] = None,
                 router: Optional[Router] = None,
                 clock: Optional[Clock] = None):
        self.router = router if router is not None else Router(
            servers, profile=profile)
        self.profile = profile
        self.admission = admission or AdmissionConfig()
        self.clock: Clock = clock or WallClock()
        self.metrics = FrontendMetrics()
        # front queue: (-priority, deadline-or-inf, seq) -> handle
        self._pending: List[Tuple[float, float, int, RequestHandle]] = []
        self._seq = 0
        self._live: Dict[int, _Live] = {}
        self._all: Dict[int, RequestHandle] = {}   # every handle ever issued

    # ---------------------------------------------------------- admission --
    def submit(self, req: Request, session: Optional[str] = None,
               priority: int = 0,
               deadline_s: Optional[float] = None) -> RequestHandle:
        """Admit one request. Returns a handle immediately — possibly
        already terminal (``handle.shed``) if admission control rejected
        it. Higher ``priority`` dispatches first; ``deadline_s`` is seconds
        from now (defaults to the admission config's SLO, 0 = none)."""
        now = self.clock.now()
        req.t_submit = req.t_submit or now
        handle = RequestHandle(req)
        handle.session = session
        handle.priority = priority
        slo = deadline_s if deadline_s is not None else (
            self.admission.slo_s or None)
        handle.deadline = (now + slo) if slo else None
        handle._aqueue = asyncio.Queue()
        self._all[req.uid] = handle
        self.metrics.submitted += 1

        if len(self._pending) >= self.admission.max_pending:
            if self.admission.on_overload == "shed":
                self._shed(handle, "overload")
                self.metrics.shed_overload += 1
                return handle
            self.metrics.parks += 1     # park: hold it, count backpressure
        heapq.heappush(self._pending,
                       (-float(priority),
                        handle.deadline if handle.deadline is not None
                        else float("inf"),
                        self._seq, handle))
        self._seq += 1
        self._dispatch()
        return handle

    def _shed(self, handle: RequestHandle, reason: str) -> None:
        handle._mark_shed(reason)
        self.metrics.sheds += 1
        self.metrics.tokens_lost += int(handle.request.max_new)
        if handle._aqueue is not None:
            handle._aqueue.put_nowait(None)

    def _has_capacity(self) -> bool:
        allow = self.admission.queue_allowance
        return any(r.free_slots() + allow - r.queued() > 0
                   for r in self.router.active())

    def _dispatch(self) -> int:
        """Release front-queued requests into replicas while the pool has
        capacity; shed provably-infeasible deadlines when configured.
        Returns how many requests were dispatched."""
        n = 0
        while self._pending and self.router.active():
            if not self._has_capacity():
                break
            _, _, _, handle = heapq.heappop(self._pending)
            if handle.shed:      # shed while parked (overload race) — skip
                continue
            if (handle.deadline is not None
                    and self.admission.shed_infeasible):
                best = min(self.router.est_wait(r)
                           for r in self.router.active())
                if self.clock.now() + best > handle.deadline:
                    self._shed(handle, "deadline-infeasible")
                    self.metrics.shed_infeasible += 1
                    continue
            rep, _ = self.router.submit(handle.request, handle=handle,
                                        session=handle.session)
            tr = rep.server._tr
            if tr is not None:   # span edge: this request -> its replica
                tr.instant(f"routed→replica:{rep.idx}",
                           track=f"req:{handle.uid}", replica=rep.idx)
            self._live[handle.uid] = _Live(handle)
            self.metrics.dispatched += 1
            n += 1
        return n

    # ----------------------------------------------------------- delivery --
    def _drain_handles(self, rep: Replica) -> None:
        """Move newly committed chunks from this replica's handles to their
        async consumers and do the SLO token accounting. Delivery time is
        the front-end clock NOW — after the step (and, emulated, its
        charged cost), which is when a real client would see the bytes."""
        t = self.clock.now()
        for uid in list(rep.server.handles):
            live = self._live.get(uid)
            if live is None or live.finished:
                continue
            h = live.handle
            while live.chunks_seen < len(h._chunks):
                chunk = h._chunks[live.chunks_seen]
                live.chunks_seen += 1
                k = len(chunk)
                self.metrics.tokens_delivered += k
                if live.deadline is None or t <= live.deadline:
                    self.metrics.tokens_in_slo += k
                else:
                    self.metrics.tokens_late += k
                if h._aqueue is not None:
                    h._aqueue.put_nowait(chunk)
            if h.done():
                live.finished = True
                self.metrics.completed += 1
                self.metrics.latencies.append(t - h.request.t_submit)
                if live.deadline is not None and t > live.deadline:
                    self.metrics.deadline_misses += 1
                if h._aqueue is not None:
                    h._aqueue.put_nowait(None)

    def _drained(self) -> bool:
        return (not self._pending
                and not any(r.has_work() for r in self.router.live()))

    # ---------------------------------------------------- wall-clock mode --
    async def run_until_drained(self, poll_s: float = 0.001) -> Dict:
        """Serve until every submitted request completes (live wall-clock
        mode): one executor lane per replica runs the blocking ``step()``
        off the event loop while submissions keep landing."""
        loop = asyncio.get_running_loop()
        pool = ThreadPoolExecutor(
            max_workers=max(1, len(self.router.replicas)),
            thread_name_prefix="replica-step")
        try:
            for rep in self.router.replicas:   # compile before serving
                if rep.server._compile_base is None:
                    await loop.run_in_executor(pool, rep.server.warmup)

            async def lane(rep: Replica):
                while True:
                    self._dispatch()
                    if rep.state != RETIRED and rep.has_work():
                        await loop.run_in_executor(pool, rep.server.step)
                        self._drain_handles(rep)
                        self.router.reap()
                    elif self._drained():
                        return
                    else:
                        await asyncio.sleep(poll_s)

            await asyncio.gather(*(lane(r) for r in self.router.replicas))
        finally:
            pool.shutdown(wait=True)
        return self.summary()

    # ------------------------------------------------------ emulated mode --
    async def serve_trace(self, trace, profile: LatencyProfile,
                          events: Sequence[Tuple[float, str, int]] = ()
                          ) -> Dict:
        """Deterministic emulated drive: replay ``trace`` (arrival-sorted
        ``(t, Request)`` or ``(t, Request, extras)`` rows, extras =
        ``{"deadline_s", "session", "priority"}``) against the replica
        pool on ONE shared ``EmulatedClock``. Per round every replica with
        work runs one profile-charged step in the executor lane; the clock
        advances by the MAX of the concurrent step costs (replicas run in
        parallel in the topology this emulates). ``events`` injects
        ``(t, "drain"|"scale_down"|"scale_up", replica_idx)`` lifecycle
        transitions at emulated times."""
        clock = (self.clock if isinstance(self.clock, EmulatedClock)
                 else EmulatedClock())
        self.clock = clock
        for rep in self.router.replicas:
            rep.server.set_clock(clock)
            rep.server.warmup()            # uncharged, off the traced path
        loop = asyncio.get_running_loop()
        arrivals = [(row[0], row[1], row[2] if len(row) > 2 else {})
                    for row in trace]
        arrivals.sort(key=lambda r: r[0])
        todo = sorted(events, key=lambda e: e[0])
        busy = {rep.idx: 0.0 for rep in self.router.replicas}

        while (arrivals or todo or self._pending
               or any(r.has_work() for r in self.router.live())):
            now = clock.now()
            while todo and todo[0][0] <= now:
                _, kind, idx = todo.pop(0)
                getattr(self.router, kind)(idx)
            while arrivals and arrivals[0][0] <= now:
                _, req, extra = arrivals.pop(0)
                self.submit(req, session=extra.get("session"),
                            priority=extra.get("priority", 0),
                            deadline_s=extra.get("deadline_s"))
            self._dispatch()
            workers = [r for r in self.router.replicas
                       if r.state != RETIRED and r.has_work()]
            if not workers:
                horizon = [t for t, *_ in arrivals[:1]] + \
                          [t for t, *_ in todo[:1]]
                if not horizon:
                    break
                clock.advance_to(min(horizon))
                continue
            costs = []
            for rep in workers:      # sequential awaits: deterministic
                cost, _ = await loop.run_in_executor(
                    None, functools.partial(charged_step, rep.server,
                                            profile, advance_clock=False))
                busy[rep.idx] += cost
                costs.append(cost)
            clock.advance(max(costs))
            for rep in workers:
                self._drain_handles(rep)
            self.router.reap()
        out = self.summary()
        out["makespan_s"] = clock.now()
        out["busy_s"] = {str(k): v for k, v in busy.items()}
        out["throughput_tok_s"] = (self.metrics.tokens_delivered
                                   / max(out["makespan_s"], 1e-9))
        return out

    # ------------------------------------------------------------ results --
    def handles(self) -> Dict[int, RequestHandle]:
        return dict(self._all)

    def results_digest(self) -> str:
        """SHA-1 over every request's uid -> emitted tokens (shed included,
        empty) — the byte-determinism witness two identical emulated drives
        must agree on."""
        blob = {str(u): h.tokens for u, h in self._all.items()}
        return hashlib.sha1(
            json.dumps(blob, sort_keys=True).encode()).hexdigest()

    def summary(self) -> Dict:
        return {**self.metrics.summary(),
                "goodput_under_slo": self.metrics.goodput_under_slo,
                "router": self.router.summary(),
                "results_digest": self.results_digest()}


def drive_frontend_trace(frontend: ServingFrontend, trace,
                         profile: LatencyProfile,
                         events: Sequence[Tuple[float, str, int]] = ()
                         ) -> Dict:
    """Sync entry point for benchmarks/tests: run the front-end's emulated
    drive to completion on a private event loop."""
    return asyncio.run(frontend.serve_trace(trace, profile, events=events))
