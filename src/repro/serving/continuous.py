"""Continuous-batching serving on the stepwise speculative engine.

`BatchedServer` runs one padded batch to completion per call — the single-
tenant regime of the paper (§9). This module serves sustained multi-user
traffic instead: a fixed pool of `batch_size` decode slots advances one
speculation megastep at a time, and whenever a slot's request retires (EOS
or length), the slot is refilled from the admission queue via a single-slot
prefill while the other slots keep decoding.

Compile stability is the design constraint: the decode loop replays
warmup-compiled ⟨B, D, W, V⟩ megastep executables and one B=1 slot-prefill
executable (slot index traced), so slot churn never triggers a recompile —
the megastep cache stays hot for the whole serving run. `warmup()` compiles
everything up front; `metrics.recompiles_after_warmup` must stay 0 and is
asserted in tests/test_continuous_serving.py.

Two scheduling modes share that contract:

  * pinned   — one bucket ⟨spec, verify_v⟩ fixed at construction (default).
  * adaptive — pass ``buckets=`` (a ladder): warmup precompiles ONE megastep
    per ladder bucket, and a `BucketController` re-picks the bucket every
    megastep from per-bucket AAL EMAs, the latency profile (or online
    iter-time EMAs) and pool occupancy, with hysteresis. Switching buckets
    replays a different warmup-compiled executable — it never compiles, so
    `recompiles_after_warmup == 0` holds across switches too (asserted in
    tests/test_adaptive_serving.py).

Idle slots (no request waiting) keep decoding garbage — discarding their
output is cheaper than breaking the static batch shape. Their cache growth
is tracked host-side and they are re-parked (dummy 1-token prefill) before
they could overflow the cache.

Chunked prefill (``prefill_chunks=``) removes the remaining head-of-line
stall: instead of one monolithic prompt-width prefill blocking every decode
slot behind each admission, prompts advance through a budgeted **prefill
lane** of fixed-width chunk executables (each chunk length compiled once at
warmup, key ``("slot_prefill_chunk", C)`` — the zero-recompile contract
survives chunk-count churn by construction) interleaved with the decode
megasteps. Mid-prefill slots keep riding the batched megastep producing
garbage; each chunk re-pins the slot's committed length to the host-side
cursor, so the garbage is never visible and is overwritten position-for-
position as the real prompt lands (see ``engine._build_slot_prefill_chunk``
for the soundness argument). The lane is round-robin across mid-prefill
slots under a per-step token budget — explicit (``prefill_budget=``) or
priced by the controller against pool occupancy via ``objective.
step_latency`` — and the controller's bucket choice sees the lane's cost,
leaning deeper when prefill taxes every step.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.buckets import Bucket, ladder_headroom, validate_ladder
from repro.core.egt import DraftSpec, egt_spec
from repro.core.engine import DecodeState, SpeculativeEngine
from repro.serving.controller import BucketController
from repro.serving.errors import NumericalFault, PoolExhausted
from repro.serving.handle import RequestHandle
from repro.serving.server import Request, cut_at_eos, pad_prompt
from repro.telemetry import (BoundedSeries, Clock, EmulatedClock, Histogram,
                             Registry, RunningMean, Telemetry, WallClock,
                             linear_buckets)

# raw-sample window per series; running aggregates stay exact past this
SERIES_WINDOW = 4096


def _series(name: str, help: str, bounds=None) -> Callable[[], BoundedSeries]:
    """Dataclass default factory: a bounded window backed by a histogram so
    quantiles survive the window wrapping."""
    def make() -> BoundedSeries:
        return BoundedSeries(maxlen=SERIES_WINDOW,
                             hist=Histogram(name, help, bounds=bounds))
    return make


@dataclass
class ServingMetrics:
    """Live counters for a continuous serving run.

    Memory-bounded by construction: every per-step/per-request series is a
    ``BoundedSeries`` (exact running aggregates over the FULL run + a
    bounded window of recent raw samples + a fixed-bucket histogram for
    quantiles once the window wraps), per-bucket rollups are ``RunningMean``
    and the step-by-step ``bucket_history`` is a bounded deque — nothing
    here grows with the number of requests served. ``summary()`` keys are
    unchanged from the list-backed version and numerically identical while
    a run fits the window (which every test and benchmark does).
    """
    steps: int = 0
    iter_times: BoundedSeries = field(default_factory=_series(
        "serving_iter_seconds", "decode megastep duration"))
    prefill_times: BoundedSeries = field(default_factory=_series(
        "serving_prefill_seconds", "slot prefill/park duration"))
    occupancy: BoundedSeries = field(default_factory=_series(
        "serving_occupancy", "active slots / pool size, per step",
        bounds=linear_buckets(0.05, 0.05, 20)))
    accept_lens: BoundedSeries = field(default_factory=_series(
        "serving_accept_len", "accepted chain length, per active slot-step",
        bounds=linear_buckets(1.0, 1.0, 16)))
    tokens_out: int = 0          # tokens credited to real requests
    admissions: int = 0
    refills: int = 0             # admissions into a previously-used slot
    parks: int = 0               # idle-slot dummy prefills (overflow guard)
    completed: int = 0
    truncated_prompts: int = 0
    prefill_chunks: int = 0      # chunk executables dispatched by the lane
    prefill_chunk_tokens: int = 0  # chunk widths summed (incl. tail padding)
    recompiles_after_warmup: int = 0
    # fault tolerance: typed-failure outcomes at this server's boundaries
    pool_parks: int = 0          # admissions/chunks parked on PoolExhausted
    numerical_faults: int = 0    # NumericalFault raised through step()
    evacuations: int = 0         # incomplete requests pulled by evacuate()
    degraded_steps: int = 0      # steps run with degradation forced on
    mesh_devices: int = 1        # devices the engine's mesh spans (1 = unsharded)
    quant_mode: str = "none"     # engine QuantConfig mode string
    kv_bytes_per_slot: int = 0   # both caches' bytes ONE slot pins
    # paged layout: prefix-store admission outcomes (0 under contiguous)
    prefix_lookups: int = 0
    prefix_hits: int = 0
    prefix_hit_tokens: int = 0   # prompt tokens whose prefill was skipped
    prefix_prompt_tokens: int = 0
    peak_pages_in_use: int = 0   # high-water pool occupancy (pages)
    latencies: BoundedSeries = field(default_factory=_series(
        "serving_request_latency_seconds", "request submit -> finish"))
    # adaptive scheduling: the bucket each step ran, and per-bucket rollups
    bucket_history: Deque[Tuple[int, int, int]] = field(
        default_factory=lambda: deque(maxlen=SERIES_WINDOW))
    bucket_steps: Dict[Tuple[int, int, int], int] = field(default_factory=dict)
    bucket_accept: Dict[Tuple[int, int, int], RunningMean] = field(
        default_factory=dict)
    bucket_iter: Dict[Tuple[int, int, int], RunningMean] = field(
        default_factory=dict)
    bucket_switches: int = 0

    @property
    def aal(self) -> float:
        # BoundedSeries counts array appends element-wise, so this is the
        # same number the old concatenate-then-mean produced
        return self.accept_lens.mean

    @property
    def total_time(self) -> float:
        # decode megasteps AND slot prefills: throughput/TPOT must charge
        # the refill overhead, or continuous wins by metric definition
        return self.iter_times.total + self.prefill_times.total

    def bind(self, registry: Registry) -> None:
        """Expose these counters through a telemetry registry: the series'
        backing histograms register directly (shared objects — one
        observation feeds both views) and the scalar counters become
        callback gauges read lazily at collection time."""
        for s in (self.iter_times, self.prefill_times, self.occupancy,
                  self.accept_lens, self.latencies):
            s.hist = registry.register(s.hist)  # type: ignore[assignment]
        for name in ("tokens_out", "admissions", "refills", "parks",
                     "completed", "truncated_prompts", "prefill_chunks",
                     "prefill_chunk_tokens", "prefix_lookups", "prefix_hits",
                     "prefix_hit_tokens", "peak_pages_in_use",
                     "recompiles_after_warmup", "bucket_switches", "steps",
                     "pool_parks", "numerical_faults", "evacuations",
                     "degraded_steps"):
            registry.callback_gauge(
                f"serving_{name}", lambda n=name: float(getattr(self, n)),
                f"ServingMetrics.{name}")

    def summary(self) -> Dict[str, float]:
        return {
            "steps": self.steps,
            "completed": self.completed,
            "tokens": self.tokens_out,
            "time_s": self.total_time,
            "throughput_tok_s": self.tokens_out / max(self.total_time, 1e-9),
            "tpot_ms": 1e3 * self.total_time / max(self.tokens_out, 1),
            "aal": self.aal,
            "occupancy": self.occupancy.mean,
            "admissions": self.admissions,
            "refills": self.refills,
            "parks": self.parks,
            "truncated_prompts": self.truncated_prompts,
            "prefill_chunks": self.prefill_chunks,
            "prefill_chunk_tokens": self.prefill_chunk_tokens,
            "recompiles_after_warmup": self.recompiles_after_warmup,
            "pool_parks": self.pool_parks,
            "numerical_faults": self.numerical_faults,
            "evacuations": self.evacuations,
            "degraded_steps": self.degraded_steps,
            "prefix_lookups": self.prefix_lookups,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefix_hit_rate": (self.prefix_hit_tokens
                                / max(self.prefix_prompt_tokens, 1)),
            "peak_pages_in_use": self.peak_pages_in_use,
            "mesh_devices": self.mesh_devices,
            "quant_mode": self.quant_mode,
            "kv_bytes_per_slot": self.kv_bytes_per_slot,
            "latency_p50_s": self.latencies.quantile(0.50),
            "latency_p95_s": self.latencies.quantile(0.95),
            "bucket_switches": self.bucket_switches,
            "buckets": {
                "x".join(map(str, k)): {
                    "steps": self.bucket_steps[k],
                    "aal": self.bucket_accept[k].mean
                    if k in self.bucket_accept else 0.0,
                    "iter_ms": 1e3 * self.bucket_iter[k].mean
                    if k in self.bucket_iter else 0.0,
                } for k in self.bucket_steps},
        }


def slots_at_budget(engine: SpeculativeEngine, cache_byte_budget: int,
                    live_tokens: Optional[int] = None) -> int:
    """Max concurrent decode slots a fixed cache-byte budget sustains on
    this engine — HBM capacity planning for the slot pool. An int8-KV
    engine fits ~2-4x the slots of its fp32 twin at the same budget (the
    headline of the quantized path; asserted in the quant_sweep bench).

    ``live_tokens`` reprices a slot by its OCCUPANCY rather than capacity:
    a contiguous slot pins its full ``max_target_len`` stripe regardless,
    but a paged slot pins only ceil(live_tokens / page_len) pages — this is
    where the paged layout's slots-per-HBM-byte advantage shows up (the
    ``slots_at_fixed_hbm_ratio`` metric in the paged_sweep bench)."""
    per_slot = (engine.cache_bytes_per_slot(live_tokens)["total"]
                if live_tokens is not None
                else engine.cache_bytes_per_slot()["total"])
    return int(cache_byte_budget) // max(per_slot, 1)


class ContinuousServer:
    """Slot scheduler over the engine's stepwise API.

    Pinned mode fixes one bucket ⟨spec, verify_v⟩ at construction. Adaptive
    mode (``buckets=``) precompiles the whole ladder at warmup and lets a
    `BucketController` re-pick the bucket each megastep — scheduling freedom
    WITHOUT giving up compile stability, because a switch replays a
    different warmup-compiled executable instead of tracing a new one.
    """

    def __init__(self, engine: SpeculativeEngine, batch_size: int,
                 prompt_pad: int, eos_id: Optional[int] = None,
                 spec: Optional[DraftSpec] = None,
                 verify_v: Optional[int] = None,
                 buckets: Optional[Sequence[Bucket]] = None,
                 controller: Optional[BucketController] = None,
                 clock: Optional[Clock] = None,
                 telemetry: Optional[Telemetry] = None,
                 prefill_chunks: Optional[Sequence[int]] = None,
                 prefill_budget: int = 0):
        self.engine = engine
        self.batch_size = batch_size
        self.prompt_pad = prompt_pad
        self.eos_id = eos_id
        # ONE clock for every timestamp this server takes (request stamps,
        # prefill timing): wall by default, the telemetry bundle's when one
        # is attached, or an EmulatedClock under an emulation driver — which
        # flips the server into deferred-timing mode (see set_clock)
        self.telemetry = telemetry
        self.clock: Clock = clock or (telemetry.clock if telemetry is not None
                                      else WallClock())
        self._defer_timing = isinstance(self.clock, EmulatedClock)
        self._tr = telemetry.tracer if telemetry is not None else None
        self._ev = telemetry.log if telemetry is not None else None
        self.ladder: Optional[Tuple[Bucket, ...]] = None
        self.controller: Optional[BucketController] = None
        if buckets is not None:
            if spec is not None or verify_v is not None:
                raise ValueError("pass either a pinned spec/verify_v or an "
                                 "adaptive bucket ladder, not both")
            self.ladder = validate_ladder(buckets, engine.cfg.max_target_len,
                                          prompt_pad)
            if (controller is not None
                    and tuple(controller.ladder) != self.ladder):
                # a controller over different buckets could pick one warmup
                # never compiled — a compile on the decode path
                raise ValueError("controller ladder does not match the "
                                 "server's bucket ladder")
            self.controller = controller or BucketController(
                self.ladder, profile=engine.profile)
            first = self.ladder[0]
            self.spec = egt_spec(first.depth, first.width)
            self.verify_v = first.verify
        else:
            if controller is not None:
                raise ValueError("a controller needs a bucket ladder")
            self.spec = spec if spec is not None else egt_spec(4, 2)
            self.verify_v = verify_v or self.spec.num_nodes
        # chunked-prefill lane: a sorted set of static chunk widths (each
        # compiled once at warmup) and an optional explicit per-step token
        # budget (0 = let the controller price it from occupancy; without a
        # controller, drain-fast-while-idle / trickle-while-busy)
        self.chunked = bool(prefill_chunks)
        if self.chunked:
            self.prefill_chunks: Tuple[int, ...] = tuple(
                sorted({int(c) for c in prefill_chunks}))
            if self.prefill_chunks[0] < 1:
                raise ValueError("prefill chunk lengths must be >= 1")
        else:
            self.prefill_chunks = ()
        if prefill_budget < 0:
            raise ValueError("prefill_budget must be >= 0")
        self.prefill_budget = int(prefill_budget)
        # slot -> {"toks": padded prompt, "plen": int, "pos": cursor}
        self._prefill: Dict[int, Dict] = {}
        self._prefill_order: Deque[int] = deque()   # round-robin lane order
        self._last_chunks: List[int] = []  # chunk widths issued this step
        self.queue: Deque[Request] = deque()
        self.done: Dict[int, Request] = {}
        self.handles: Dict[int, RequestHandle] = {}
        self.metrics = ServingMetrics()
        self.metrics.mesh_devices = engine.mesh_info()["devices"]
        # getattr-guarded: the host-side scheduler tests drive a fake engine
        # that has neither a QuantConfig nor cache byte accounting
        qc = getattr(engine.cfg, "quant", None)
        self.metrics.quant_mode = qc.mode if qc is not None else "none"
        bytes_fn = getattr(engine, "cache_bytes_per_slot", None)
        self.metrics.kv_bytes_per_slot = (bytes_fn()["total"]
                                          if callable(bytes_fn) else 0)
        if telemetry is not None:
            self.metrics.bind(telemetry.registry)
            # getattr-guarded like the quant fields above: fake engines in
            # the scheduler tests have no telemetry hooks
            attach = getattr(engine, "attach_telemetry", None)
            if callable(attach):
                attach(telemetry)
            reg = telemetry.registry
            self._h_spec_ratio = reg.histogram(
                "spec_accept_ratio",
                "per-slot accepted/(depth+1) chain-utilisation ratio",
                bounds=linear_buckets(0.05, 0.05, 20))
            self._c_wasted = reg.counter(
                "spec_wasted_draft_tokens_total",
                "verified tree nodes not committed (verify_v - accept_len), "
                "summed over active slot-steps")
            self._g_bucket_aal = reg.gauge(
                "controller_bucket_aal",
                "controller per-bucket AAL EMA estimate")
        else:
            self._h_spec_ratio = None
            self._c_wasted = None
            self._g_bucket_aal = None

        self.state: DecodeState = engine.init_decode_state(batch_size)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self._buffers: List[List[int]] = [[] for _ in range(batch_size)]
        self._budget = np.zeros(batch_size, np.int64)   # max tokens this slot
        self._used = [False] * batch_size               # slot ever held a req
        # host-side mirror of each slot's committed cache length: prompt at
        # admission, +accept_len per step (exact — no device sync needed)
        self._slot_len = np.zeros(batch_size, np.int64)
        # max cache growth per step: under a ladder the DEEPEST bucket binds
        # (any step may run it), not whichever bucket is currently selected
        self._headroom = (ladder_headroom(self.ladder) if self.ladder
                          else self.spec.depth + 2)
        self._compile_base: Optional[int] = None
        self._exec_base: int = 0
        self._just_finished: List[Request] = []
        self.warmed_buckets: set = set()  # bucket keys compiled at warmup
        self._degraded = False  # graceful-degradation flag (front-end set)

    # ---------------------------------------------------------- lifecycle --
    def set_clock(self, clock: Clock) -> None:
        """Swap the timestamp source (an emulation driver installs its
        EmulatedClock here before replaying a trace). Under an emulated
        clock the server defers all duration metrics to the driver — wall
        time on the testbed is interpreter noise, so the driver charges
        profile costs via ``observe_prefill``/``charge_step`` instead and
        the exported numbers become bit-reproducible."""
        self.clock = clock
        self._defer_timing = isinstance(clock, EmulatedClock)

    def observe_prefill(self, dt: float) -> None:
        """Driver-charged cost of one slot prefill (deferred-timing mode)."""
        self.metrics.prefill_times.append(float(dt))

    def charge_step(self, iter_time: float) -> None:
        """Driver-charged cost of the decode step that just ran (deferred-
        timing mode): lands in the same series/rollups/controller EMA the
        wall measurement would have fed."""
        key = self.metrics.bucket_history[-1]
        self.metrics.iter_times.append(float(iter_time))
        self.metrics.bucket_iter.setdefault(key, RunningMean()).add(iter_time)
        if self.controller is not None:
            self.controller.observe_iter(key, iter_time)

    def submit(self, req: Request,
               handle: Optional[RequestHandle] = None) -> RequestHandle:
        """Queue a request and return its :class:`RequestHandle` — the
        redesigned lifecycle API (``done()``/``result()``/``tokens``/token
        streaming). ``handle`` lets a front-end that created the handle at
        admission time (before routing picked this server) reuse it."""
        if req.t_submit is None:    # preserved across recovery resubmissions
            req.t_submit = self.clock.now()
        h = handle if handle is not None else RequestHandle(req)
        h._pump = self._pump_once
        self.handles[req.uid] = h
        # remember the TRUE user callback across resubmissions: a replayed
        # request arrives with req.stream already set to a previous server's
        # _chain, and chaining on top of that would double-deliver every
        # chunk into the handle
        user_stream = getattr(req, "_user_stream", req.stream)
        req._user_stream = user_stream

        def _chain(uid, toks, _h=h, _user=user_stream):
            _h._on_tokens(toks)
            if _user is not None:
                _user(uid, toks)

        req.stream = _chain
        if self._tr is not None:
            self._tr.begin("queued", track=f"req:{req.uid}", uid=req.uid)
        self.queue.append(req)
        return h

    def _pump_once(self) -> None:
        """One unit of forward progress for handle-driven consumption
        (``RequestHandle.result()`` / sync iteration): warm up on first use,
        then run one scheduler step."""
        if self._compile_base is None:
            self.warmup()
        self.step()

    def warmup(self):
        """Compile the steady-state executables (slot prefill, slot reset,
        one megastep per bucket — the whole ladder in adaptive mode) on
        dummy traffic, then snapshot the compile counter: any later compile
        counts as a recompile-after-warmup."""
        if self.chunked:
            # compile every static chunk width once; the lane only ever
            # replays these, so chunk-count churn can never trace
            for c in self.prefill_chunks:
                self.state = self.engine.prefill_chunk_into_slot(
                    self.state, 0, np.zeros(c, np.int32),
                    start=0, valid=1, final=True)
        else:
            dummy = np.zeros(self.prompt_pad, np.int32)
            self.state = self.engine.prefill_into_slot(self.state, 0, dummy, 1)
        for i in range(self.batch_size):
            self._park(i)
        if self.ladder is not None:
            self.state, iter_times = self.engine.warmup_buckets(
                self.state, self.ladder)
            self.controller.seed_iter_times(iter_times)
            self.warmed_buckets = {b.key() for b in self.ladder}
            # warmup ran 2·len(ladder) garbage decode steps: re-sync the
            # host-side length mirror once (off the hot path)
            self._slot_len = np.asarray(
                self.engine.slot_lengths(self.state), np.int64)
        else:
            self.state, res = self.engine.decode_step(
                self.state, spec=self.spec, verify_v=self.verify_v)
            self._slot_len += res.accept_len
            self.warmed_buckets = {
                (self.spec.depth, self.spec.width, self.verify_v)}
        self._compile_base = self.engine._compile_count
        self._exec_base = self.engine.executable_count()

    def set_degraded(self, flag: bool) -> None:
        """Force graceful degradation on or off: an adaptive server floors
        its controller at the shallowest warmed bucket (the cheapest
        compiled step); pinned servers just count degraded steps."""
        self._degraded = bool(flag)
        if self.controller is not None:
            self.controller.degraded = bool(flag)

    def evacuate(self) -> List[Tuple[Request, Optional[RequestHandle]]]:
        """Pull every incomplete request off this server for re-admission
        elsewhere: queued requests first (FIFO), then occupied slots in slot
        order — a deterministic order, so emulated fault drives replay
        byte-identically. Each occupied slot is parked (its pages release,
        its cache entries become invisible); mid-prefill cursors are
        dropped. Completed requests stay in ``done``/``handles`` for the
        front-end to drain."""
        out: List[Tuple[Request, Optional[RequestHandle]]] = []
        for req in list(self.queue):
            out.append((req, self.handles.pop(req.uid, None)))
        self.queue.clear()
        for i in range(self.batch_size):
            req = self.slots[i]
            if req is None:
                continue
            out.append((req, self.handles.pop(req.uid, None)))
            self._park(i)
            self._buffers[i] = []
        self._prefill.clear()
        self._prefill_order.clear()
        self.metrics.evacuations += len(out)
        if self._ev is not None and out:
            self._ev.emit("evacuation", requests=len(out))
        return out

    def _park(self, slot: int):
        """Empty an idle slot (length 0, stale entries invisible); it keeps
        decoding garbage, which is cheaper than breaking the batch shape."""
        t0 = self.clock.now()
        self.state = self.engine.reset_state_slot(self.state, slot)
        if not self._defer_timing:   # emulated runs: driver charges costs
            self.metrics.prefill_times.append(self.clock.now() - t0)
        self._slot_len[slot] = 0
        self.slots[slot] = None

    # ---------------------------------------------------------- admission --
    def _admit(self) -> List[int]:
        """Fill idle slots from the queue; park idle slots about to overflow.
        Returns the slot indices admitted this call."""
        L = self.engine.cfg.max_target_len
        newly = []
        for i in range(self.batch_size):
            if self.slots[i] is not None:
                continue
            if self.queue:
                req = self.queue.popleft()
                if req.replay_prefix is not None:
                    # token-exact replay after a replica failure: prefill the
                    # effective prompt + already-delivered tokens; greedy
                    # decode then reproduces the original continuation. The
                    # chunk lane handles any prefix length with the warmed
                    # chunk executables; the monolithic path reuses its
                    # prompt_pad executable whenever the prefix still fits.
                    full = np.asarray(req.replay_prefix, np.int32).reshape(-1)
                    plen = len(full)
                    if not self.chunked and plen <= self.prompt_pad:
                        toks = np.zeros(self.prompt_pad, np.int32)
                        toks[:plen] = full
                    else:
                        toks = full
                else:
                    toks, plen = pad_prompt(req, self.prompt_pad)
                    if req.truncated:
                        self.metrics.truncated_prompts += 1
                        if self._ev is not None:
                            self._ev.emit("truncation", uid=req.uid,
                                          prompt_pad=self.prompt_pad)
                req.t_start = self.clock.now()     # before engine work, like
                t0 = req.t_start                   # BatchedServer.step
                if self._tr is not None:
                    self._tr.end(track=f"req:{req.uid}")  # queued ends
                    self._tr.begin("active", track=f"req:{req.uid}",
                                   uid=req.uid, slot=i)
                if self.chunked:
                    # the prompt enters the prefill lane instead of running
                    # monolithically here; clear the slot so the lane's
                    # first chunk starts from committed length 0
                    self.state = self.engine.reset_state_slot(self.state, i)
                    self._slot_len[i] = 0
                else:
                    try:
                        self.state = self.engine.prefill_into_slot(
                            self.state, i, toks, plen)
                    except PoolExhausted:
                        # park the admission: requeue at the front and stop
                        # admitting this step — slots retiring later free
                        # pages, and the next step retries in arrival order
                        self.metrics.pool_parks += 1
                        if self._tr is not None:
                            self._tr.end(track=f"req:{req.uid}")
                            self._tr.begin("queued", track=f"req:{req.uid}",
                                           uid=req.uid)
                        self.queue.appendleft(req)
                        break
                    if not self._defer_timing:
                        self.metrics.prefill_times.append(
                            self.clock.now() - t0)
                    self._slot_len[i] = plen
                if self._ev is not None:
                    self._ev.emit("admission", uid=req.uid, slot=i,
                                  prompt_len=plen,
                                  refill=self._used[i],
                                  queue_s=req.t_start - req.t_submit)
                # cap generation so commits can never run past the cache;
                # clamp at 0 so a prompt with no headroom left retires
                # immediately (a negative budget would slip tokens through
                # _credit's front-slice)
                self._budget[i] = max(
                    0, min(req.max_new, L - plen - self._headroom))
                self.slots[i] = req
                self._buffers[i] = []
                self.metrics.admissions += 1
                if self._used[i]:
                    self.metrics.refills += 1
                self._used[i] = True
                if self.chunked:
                    if self._budget[i] == 0:
                        # no headroom: retire with 0 tokens, exactly like
                        # the monolithic path (whose root token _credit's
                        # zero-room slice drops) — skip the prefill work
                        self._credit(i, np.empty(0, np.int64))
                    else:
                        self._prefill[i] = {"toks": toks, "plen": plen,
                                            "pos": 0}
                        self._prefill_order.append(i)
                else:
                    newly.append(i)
            elif self._slot_len[i] > L - 2 * self._headroom:
                self._park(i)  # idle slot drifting toward the cache cap
                self.metrics.parks += 1
                if self._ev is not None:
                    self._ev.emit("park", slot=i)
        if newly:
            # one host sync: each admitted slot's first token is its root
            roots = np.asarray(self.state.root)
            for i in newly:
                self._credit(i, np.asarray([roots[i]], np.int64))
        return newly

    # ------------------------------------------------------- prefill lane --
    def _pick_chunk(self, remaining: int) -> int:
        """Widest configured chunk that `remaining` prompt tokens fill;
        the narrowest chunk (right-padded) covers the tail."""
        fit = [c for c in self.prefill_chunks if c <= remaining]
        return fit[-1] if fit else self.prefill_chunks[0]

    def _lane_budget(self, n_active: int) -> int:
        """Prompt-token budget for this step's prefill lane: the explicit
        ``prefill_budget`` when set, else controller-priced from occupancy,
        else drain-fast-while-idle / trickle-while-busy."""
        if self.prefill_budget > 0:
            return self.prefill_budget
        if self.controller is not None:
            return self.controller.prefill_budget(
                n_active, self.batch_size, self.prefill_chunks)
        return (self.prefill_chunks[-1] if n_active < self.batch_size
                else self.prefill_chunks[0])

    def _run_prefill_lane(self, n_active: int) -> List[int]:
        """Advance mid-prefill slots round-robin under the step budget;
        returns the slots whose prompt finished (root token credited).

        Budget semantics: at least one chunk is always issued while any
        prefill is pending (the lane must not stall), further chunks issue
        while their width still fits the remaining budget."""
        self._last_chunks = []
        if not self._prefill_order:
            return []
        budget = self._lane_budget(n_active)
        t0 = self.clock.now()
        spent = 0
        finished: List[int] = []
        while self._prefill_order:
            slot = self._prefill_order[0]
            cur = self._prefill[slot]
            remaining = cur["plen"] - cur["pos"]
            c = self._pick_chunk(remaining)
            if spent and spent + c > budget:
                break
            if (cur["pos"] == 0 and not cur.get("adopted")
                    and getattr(self.engine, "paged", False)):
                # paged prefix sharing: adopt resident prompt pages NOW —
                # after the budget check, immediately before the slot's
                # FIRST chunk dispatches. Adopting any earlier would let a
                # garbage megastep run between adoption and the length pin,
                # scribbling over shared pages (see engine.adopt_prefix).
                cur["adopted"] = True
                hit = self.engine.adopt_prefix(
                    self.state, slot, cur["toks"], cur["plen"])
                if hit:
                    cur["pos"] = hit
                    remaining = cur["plen"] - cur["pos"]
                    c = self._pick_chunk(remaining)
            valid = min(remaining, c)
            chunk = np.zeros(c, np.int32)
            chunk[:valid] = cur["toks"][cur["pos"]:cur["pos"] + valid]
            final = cur["pos"] + valid >= cur["plen"]
            try:
                self.state = self.engine.prefill_chunk_into_slot(
                    self.state, slot, chunk, cur["pos"], valid, final)
            except PoolExhausted:
                # the page allocator raises BEFORE the chunk dispatches, so
                # state and cursors are untouched: park the lane for this
                # step (decode keeps running) and retry when pages free up
                self.metrics.pool_parks += 1
                break
            self._last_chunks.append(c)
            spent += c
            cur["pos"] += valid
            # the host cursor IS the slot's committed length: each chunk
            # re-pins the device counter to it, erasing garbage-decode drift
            self._slot_len[slot] = cur["pos"]
            self.metrics.prefill_chunks += 1
            self.metrics.prefill_chunk_tokens += c
            self._prefill_order.popleft()
            if final:
                del self._prefill[slot]
                finished.append(slot)
            else:
                self._prefill_order.append(slot)
        if self._last_chunks and not self._defer_timing:
            self.metrics.prefill_times.append(self.clock.now() - t0)
        if finished:
            # one host sync: each finished prompt's first token is its root
            roots = np.asarray(self.state.root)
            for i in finished:
                self._credit(i, np.asarray([roots[i]], np.int64))
        return finished

    # --------------------------------------------------------- token flow --
    def _credit(self, slot: int, tokens: np.ndarray):
        """Append emitted tokens to the slot's request, honouring EOS and the
        length budget; retire the request when either trips."""
        req = self.slots[slot]
        if req is None:
            return
        buf = self._buffers[slot]
        take = tokens
        finished = False
        # clamp: with the budget exhausted (or 0 at admission) room goes
        # non-positive, and a negative slice take[:room] would KEEP tokens
        # from the front instead of dropping them all
        room = max(0, int(self._budget[slot]) - len(buf))
        if len(take) >= room:
            take, finished = take[:room], True
        take, hit_eos = cut_at_eos(take, self.eos_id)
        finished = finished or hit_eos
        if len(take):
            buf.extend(int(t) for t in take)
            self.metrics.tokens_out += len(take)
            if req.stream is not None:
                req.stream(req.uid, np.asarray(take, np.int64))
        if finished:
            self._retire(slot)

    def _retire(self, slot: int):
        req = self.slots[slot]
        req.result = np.asarray(self._buffers[slot], np.int64)
        req.t_finish = self.clock.now()
        req.stats = {"tokens": len(req.result),
                     "latency_s": req.t_finish - req.t_submit,
                     "queue_s": req.t_start - req.t_submit,
                     "prompt_truncated": req.truncated,
                     "length_capped": bool(self._budget[slot] < req.max_new)}
        self.done[req.uid] = req
        self._just_finished.append(req)
        self.slots[slot] = None  # slot refills at the next _admit
        self.metrics.completed += 1
        self.metrics.latencies.append(req.stats["latency_s"])
        if self._tr is not None:
            self._tr.end(track=f"req:{req.uid}",
                         tokens=req.stats["tokens"])  # active ends
            self._tr.instant("retired", track=f"req:{req.uid}", uid=req.uid)
        if self._ev is not None:
            self._ev.emit("retirement", uid=req.uid, slot=slot,
                          tokens=req.stats["tokens"],
                          latency_s=req.stats["latency_s"],
                          length_capped=req.stats["length_capped"])

    # --------------------------------------------------------------- step --
    def step(self) -> List[Request]:
        """Admit waiting requests into free slots, run ONE megastep over the
        whole pool, distribute the emitted tokens, retire finished requests.
        Returns the requests completed during this step."""
        self._just_finished = []
        self._admit()
        n_decode = sum(1 for i, r in enumerate(self.slots)
                       if r is not None and i not in self._prefill)
        if self.chunked:
            # budgeted chunk quanta BEFORE the megastep: a prompt whose
            # final chunk lands here decodes in the same step
            self._run_prefill_lane(n_decode)
        active = [i for i, r in enumerate(self.slots)
                  if r is not None and i not in self._prefill]
        if not active:
            self._note_recompiles()  # chunk dispatches above must be seen
            self._note_paged()
            return self._just_finished
        if self.controller is not None:
            # occupancy-aware online bucket selection; every ladder bucket
            # was compiled at warmup, so this only changes WHICH cached
            # executable the megastep below replays. The lane's profiled
            # cost rides along so bucket choice sees the prefill tax.
            lane_cost = 0.0
            if self.controller.profile is not None:
                lane_cost = sum(self.controller.profile.t_verify(c)
                                for c in self._last_chunks)
            sw0 = self.controller.switches
            b = self.controller.choose(n_active=len(active),
                                       lane_cost=lane_cost)
            self.spec, self.verify_v = egt_spec(b.depth, b.width), b.verify
            if self._ev is not None and self.controller.switches > sw0:
                self._ev.emit("bucket_switch", **self.controller.last_switch)
        if self._degraded:
            self.metrics.degraded_steps += 1
        try:
            self.state, res = self.engine.decode_step(
                self.state, spec=self.spec, verify_v=self.verify_v)
        except NumericalFault as e:
            # the megastep's inputs were DONATED: adopt the carried post-
            # step state before unwinding, or every later dispatch touches
            # dead buffers. The front-end's boundary fails this replica and
            # replays its in-flight work token-exactly.
            if e.state is not None:
                self.state = e.state
            self.metrics.numerical_faults += 1
            self._note_recompiles()
            self._note_paged()
            raise
        except PoolExhausted:
            # decode needed growth pages and none were free (the allocator
            # raises before dispatch, so state is intact): surface it typed;
            # the front-end treats it as transient backpressure
            self.metrics.pool_parks += 1
            self._note_recompiles()
            self._note_paged()
            raise
        adv = np.asarray(res.accept_len, np.int64)
        if self._prefill:
            # mid-prefill slots ran garbage this megastep; their committed
            # length stays the lane cursor (the next chunk re-pins it)
            adv = adv.copy()
            adv[list(self._prefill)] = 0
        self._slot_len += adv
        self.metrics.steps += 1
        key = res.bucket
        self.metrics.bucket_history.append(key)
        if not self._defer_timing:   # emulated runs: driver charges costs
            self.metrics.iter_times.append(res.iter_time)
            self.metrics.bucket_iter.setdefault(key, RunningMean()).add(
                res.iter_time)
        self.metrics.occupancy.append(len(active) / self.batch_size)
        self.metrics.accept_lens.append(res.accept_len[active])
        self.metrics.bucket_steps[key] = self.metrics.bucket_steps.get(key, 0) + 1
        self.metrics.bucket_accept.setdefault(key, RunningMean()).add(
            res.mean_accept(active))
        if self.controller is not None:
            self.controller.observe(
                key, res.mean_accept(active),
                0.0 if self._defer_timing else res.iter_time)
            self.metrics.bucket_switches = self.controller.switches
            if self._g_bucket_aal is not None:
                self._g_bucket_aal.set(self.controller.aal.estimate(key),
                                       bucket="x".join(map(str, key)))
        if self._h_spec_ratio is not None:
            # speculation efficiency, per active slot: how much of the max
            # chain (depth+1) was accepted, and how many verified tree nodes
            # were wasted
            depth = key[0]
            for a in res.accept_len[active]:
                self._h_spec_ratio.observe(float(a) / (depth + 1))
            self._c_wasted.inc(float(np.sum(self.verify_v
                                            - res.accept_len[active])))
        for i in active:
            toks = res.tokens[i]
            self._credit(i, toks[toks >= 0])
        self._note_recompiles()
        self._note_paged()
        return self._just_finished

    def _note_paged(self) -> None:
        """Refresh the paged-layout gauges (prefix-store admission outcomes
        and the page pool's high-water mark) from the engine's PageState.
        No-op for contiguous engines and the scheduler tests' fakes."""
        ps = getattr(self.state, "pages", None)
        if ps is None:
            return
        m = self.metrics
        m.prefix_lookups = ps.store.lookups
        m.prefix_hits = ps.store.hits
        m.prefix_hit_tokens = ps.store.hit_tokens
        m.prefix_prompt_tokens = ps.store.prompt_tokens
        m.peak_pages_in_use = ps.peak_pages_in_use

    def _note_recompiles(self) -> None:
        """Refresh the zero-recompile signal. The executable counter is the
        honest one: it also sees silent jit retraces (a sharding drifting
        under a mesh retraces without any builder call) and subsumes
        builder-level compiles, whose new wrappers trace on first call. It
        reads a private jax attribute, so when it yielded nothing at warmup
        (warmup always traces several executables) fall back to builder-
        level counting rather than passing vacuously."""
        if self._compile_base is None:
            return
        if self._exec_base > 0:
            self.metrics.recompiles_after_warmup = max(
                0, self.engine.executable_count() - self._exec_base)
        else:
            self.metrics.recompiles_after_warmup = (
                self.engine._compile_count - self._compile_base)

    def serve(self, max_steps: Optional[int] = None
              ) -> Dict[int, RequestHandle]:
        """Serve until the queue drains and every slot retires; returns the
        completed :class:`RequestHandle` objects keyed by uid. This is the
        canonical drain loop; completed ``Request`` objects stay reachable
        through ``self.done`` for callers that want the raw records."""
        if self._compile_base is None:
            self.warmup()
        steps = 0
        while self.queue or any(r is not None for r in self.slots):
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return {u: h for u, h in self.handles.items() if h.done()}
