"""One serving configuration surface: ``ServeConfig``.

Six PRs of organic growth left ``launch/serve.py`` with a dozen accreted
flags and the benchmarks quietly rebuilding similar-but-not-identical
engines by hand. ``ServeConfig`` collapses that: ONE dataclass that

* round-trips to/from argv (``add_args``/``parse``/``to_argv``) — the CLI
  is generated from the dataclass, so a new knob is one field, and a
  config can be re-serialized into the exact command line reproducing it;
* round-trips to/from JSON (``to_json``/``from_json``) — benchmark
  artifacts can embed the config that produced them;
* builds the actual objects (``build_engine``/``build_server``/
  ``build_frontend``) — the CLI and ``benchmarks/fig_serving.py`` call the
  same constructors, so the bench can no longer drift from what the
  launcher serves.
"""
from __future__ import annotations

import argparse
import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional

# option vocabularies shared by the CLI and validation
CHOICES: Dict[str, tuple] = {
    "server": ("batched", "continuous", "frontend"),
    "plan": ("fused", "staged", "staged_device"),
    "quantize": ("none", "int8-kv", "int8-kv+w8"),
    "verify_kernel": ("auto", "fused", "xla"),
    "overload": ("park", "shed"),
    "cache_layout": ("contiguous", "paged"),
}

_HELP: Dict[str, str] = {
    "server": "batched (padded run-to-completion), continuous (slot pool), "
              "or frontend (async multi-replica router over N continuous "
              "engines)",
    "adaptive": "continuous mode: precompile a bucket ladder and let the "
                "online controller re-pick the bucket each megastep",
    "buckets": "adaptive bucket ladder, comma-separated DxW or DxWxV",
    "hysteresis": "relative score margin before an adaptive bucket switch",
    "profile": "LatencyProfile JSON path (default: synthetic)",
    "train_steps": "testbed training steps (checkpoint-cached per value)",
    "mesh": "device mesh: DxM (data x model) or 'host'; default unsharded",
    "quantize": "int8-kv: int8 KV caches; +w8 adds int8 weight-only params",
    "verify_kernel": "verify attention hot path: fused Pallas | xla | auto",
    "cache_layout": "KV cache layout: contiguous per-slot stripes or a "
                    "paged pool with per-slot page tables and cross-request "
                    "prefix sharing",
    "page_len": "paged layout: tokens per page (0 = layout default; must "
                "divide max_target_len)",
    "cache_pages": "paged layout: page-pool size (0 = full coverage — every "
                   "slot can grow to max_target_len)",
    "replicas": "frontend mode: number of engine replicas behind the router",
    "slo_s": "frontend mode: per-request deadline in seconds after submit "
             "(0 = no SLO)",
    "max_queue": "frontend mode: admission bound on the front queue",
    "overload": "frontend mode: park (hold under backpressure) or shed "
                "requests past the admission bound",
    "affinity": "frontend mode: pin sessions to replicas",
    "retry_budget": "frontend mode: token-exact replays per request after "
                    "replica failures before a terminal shed",
    "step_timeout": "frontend mode: wall watchdog per replica step in "
                    "seconds (0 = disabled); emulated hangs are charged "
                    "this budget",
    "watchdog": "frontend mode: consecutive transient step errors before "
                "a replica is failed and its work replayed",
    "depth": "pinned speculation depth (continuous mode)",
    "width": "pinned speculation width (continuous mode)",
    "prompt_pad": "static prompt slot width (tokens)",
    "prefill_chunk": "chunked prefill: comma-separated static chunk widths "
                     "(e.g. 8,16); empty = monolithic prompt-width prefill",
    "prefill_budget": "chunked prefill: prompt tokens the lane may advance "
                      "per megastep (0 = occupancy-priced by the "
                      "controller)",
    "log_json": "emit the event log as JSON lines instead of key=value",
    "trace_dir": "enable full telemetry; write trace.json/metrics.* here",
    "jax_profile": "with --trace-dir: jax.profiler trace around N megasteps",
}


@dataclass
class ServeConfig:
    """Everything the serving stack needs, CLI- and JSON-round-trippable."""
    server: str = "batched"
    requests: int = 8
    batch: int = 4
    max_new: int = 48
    temperature: float = 0.0
    plan: str = "fused"
    depth: int = 4
    width: int = 2
    adaptive: bool = False
    buckets: str = "2x2x4,4x2x7,8x2x13"
    hysteresis: float = 0.1
    profile: Optional[str] = None
    train_steps: int = 240
    mesh: Optional[str] = None
    quantize: str = "none"
    verify_kernel: str = "auto"
    cache_layout: str = "contiguous"
    page_len: int = 0
    cache_pages: int = 0
    prompt_pad: int = 24
    # chunked prefill lane ("" = off, monolithic prefill)
    prefill_chunk: str = ""
    prefill_budget: int = 0
    # frontend (async multi-replica) mode
    replicas: int = 2
    slo_s: float = 0.0
    max_queue: int = 64
    overload: str = "park"
    affinity: bool = True
    # frontend fault tolerance (see serving/frontend.py RecoveryConfig)
    retry_budget: int = 2
    step_timeout: float = 0.0
    watchdog: int = 3
    # observability
    log_level: str = "INFO"
    log_json: bool = False
    trace_dir: Optional[str] = None
    jax_profile: int = 0

    def __post_init__(self):
        for name, opts in CHOICES.items():
            if getattr(self, name) not in opts:
                raise ValueError(f"{name}={getattr(self, name)!r} not in "
                                 f"{opts}")
        self.chunk_lens()  # fail fast on a malformed --prefill-chunk
        if self.prefill_budget < 0:
            raise ValueError("prefill_budget must be >= 0")

    def chunk_lens(self) -> tuple:
        """The parsed static chunk-width set ('' = chunking off)."""
        if not self.prefill_chunk:
            return ()
        try:
            lens = tuple(sorted({int(c) for c in
                                 self.prefill_chunk.split(",")}))
        except ValueError:
            raise ValueError(f"prefill_chunk={self.prefill_chunk!r}: "
                             "expected comma-separated ints") from None
        if lens and lens[0] < 1:
            raise ValueError("prefill chunk widths must be >= 1")
        return lens

    # ------------------------------------------------------ argv round-trip --
    @classmethod
    def add_args(cls, ap: argparse.ArgumentParser) -> None:
        """Generate the CLI from the dataclass — one flag per field."""
        for f in dataclasses.fields(cls):
            flag = "--" + f.name.replace("_", "-")
            help_ = _HELP.get(f.name, f.name.replace("_", " "))
            if isinstance(f.default, bool):
                if f.default:      # True-default bools get a --no- switch
                    ap.add_argument("--no-" + f.name.replace("_", "-"),
                                    dest=f.name, action="store_false",
                                    help=f"disable: {help_}")
                else:
                    ap.add_argument(flag, action="store_true", help=help_)
            else:
                typ = str if f.default is None else type(f.default)
                ap.add_argument(flag, type=typ, default=f.default,
                                choices=CHOICES.get(f.name), help=help_)

    @classmethod
    def from_args(cls, ns: argparse.Namespace) -> "ServeConfig":
        return cls(**{f.name: getattr(ns, f.name)
                      for f in dataclasses.fields(cls)})

    @classmethod
    def parse(cls, argv: Optional[List[str]] = None) -> "ServeConfig":
        ap = argparse.ArgumentParser()
        cls.add_args(ap)
        return cls.from_args(ap.parse_args(argv))

    def to_argv(self) -> List[str]:
        """The minimal argv reproducing this config (non-default fields
        only). ``ServeConfig.parse(cfg.to_argv()) == cfg`` always holds —
        asserted in tests/test_public_api.py."""
        ref = type(self)()
        out: List[str] = []
        for f in dataclasses.fields(self):
            v, d = getattr(self, f.name), getattr(ref, f.name)
            if v == d:
                continue
            name = f.name.replace("_", "-")
            if isinstance(d, bool):
                out.append(("--" + name) if v else ("--no-" + name))
            else:
                out += ["--" + name, str(v)]
        return out

    # ------------------------------------------------------ json round-trip --
    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, blob: Dict) -> "ServeConfig":
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(blob) - names
        if unknown:
            raise ValueError(f"unknown ServeConfig fields: {sorted(unknown)}")
        return cls(**blob)

    # ----------------------------------------------------------- builders --
    def ladder(self):
        from repro.core.buckets import parse_buckets
        return parse_buckets(self.buckets)

    def pinned_spec(self):
        from repro.core.egt import egt_spec
        spec = egt_spec(self.depth, self.width)
        return spec, max(2, (3 * spec.num_nodes) // 4)

    def build_engine(self, tb, profile=None, mesh=None):
        """The one engine constructor the CLI and the benches share."""
        from repro.core.buckets import buckets_for_depths
        from repro.core.engine import EngineConfig, SpeculativeEngine
        from repro.quant import QuantConfig
        if self.server == "batched":
            depths: tuple = (2, 4, 8)          # dynamic per-batch selection
        elif self.adaptive:
            depths = tuple(sorted({b.depth for b in self.ladder()}))
        else:
            depths = (self.depth,)
        return SpeculativeEngine(
            tb.drafter, tb.d_params, tb.verifier, tb.v_params,
            profile=profile,
            buckets=buckets_for_depths(depths, width=self.width,
                                       verify_frac=0.75),
            depth_options=depths,
            config=EngineConfig(temperature=self.temperature, plan=self.plan,
                                quant=QuantConfig.parse(self.quantize),
                                verify_kernel=self.verify_kernel,
                                cache_layout=self.cache_layout,
                                page_len=self.page_len or None,
                                cache_pages=self.cache_pages),
            mesh=mesh)

    def build_server(self, engine, telemetry=None):
        """A single server of the configured kind over ``engine``."""
        from repro.serving.continuous import ContinuousServer
        from repro.serving.controller import BucketController
        from repro.serving.server import BatchedServer
        if self.server == "batched":
            return BatchedServer(engine, batch_size=self.batch,
                                 prompt_pad=self.prompt_pad)
        chunks = self.chunk_lens()
        if self.adaptive:
            ladder = self.ladder()
            return ContinuousServer(
                engine, batch_size=self.batch, prompt_pad=self.prompt_pad,
                buckets=ladder,
                controller=BucketController(ladder, profile=engine.profile,
                                            hysteresis=self.hysteresis),
                telemetry=telemetry, prefill_chunks=chunks or None,
                prefill_budget=self.prefill_budget)
        spec, verify_v = self.pinned_spec()
        return ContinuousServer(engine, batch_size=self.batch,
                                prompt_pad=self.prompt_pad, spec=spec,
                                verify_v=verify_v, telemetry=telemetry,
                                prefill_chunks=chunks or None,
                                prefill_budget=self.prefill_budget)

    def build_frontend(self, tb, profile=None, mesh=None):
        """The async multi-replica topology: ``replicas`` pinned continuous
        engines behind a session-affine SLO-aware router."""
        from repro.serving.frontend import (AdmissionConfig, RecoveryConfig,
                                            ServingFrontend)
        if self.server != "frontend":
            raise ValueError("build_frontend needs server='frontend'")
        spec, verify_v = self.pinned_spec()
        from repro.serving.continuous import ContinuousServer
        chunks = self.chunk_lens()
        servers = [
            ContinuousServer(self.build_engine(tb, profile=profile,
                                               mesh=mesh),
                             batch_size=self.batch,
                             prompt_pad=self.prompt_pad, spec=spec,
                             verify_v=verify_v,
                             prefill_chunks=chunks or None,
                             prefill_budget=self.prefill_budget)
            for _ in range(self.replicas)]
        admission = AdmissionConfig(max_pending=self.max_queue,
                                    on_overload=self.overload,
                                    slo_s=self.slo_s)
        from repro.serving.router import Router
        router = Router(servers, profile=profile, affinity=self.affinity)
        recovery = RecoveryConfig(retry_budget=self.retry_budget,
                                  step_timeout_s=self.step_timeout,
                                  watchdog=self.watchdog)
        return ServingFrontend(servers, profile=profile,
                               admission=admission, router=router,
                               recovery=recovery)
