"""Batched serving driver.

Requests are padded into fixed-size batches (static shapes) and decoded with
the speculative engine. This is the single-tenant latency-optimal regime of
the paper (§9): one batch in flight, engine monopolizes the device.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.engine import SpeculativeEngine


@dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] token ids
    max_new: int = 32
    result: Optional[np.ndarray] = None
    stats: Dict = field(default_factory=dict)


class BatchedServer:
    def __init__(self, engine: SpeculativeEngine, batch_size: int,
                 prompt_pad: int, eos_id: Optional[int] = None):
        self.engine = engine
        self.batch_size = batch_size
        self.prompt_pad = prompt_pad
        self.eos_id = eos_id
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}

    def submit(self, req: Request):
        self.queue.append(req)

    def _make_batch(self, reqs: List[Request]):
        B = self.batch_size
        toks = np.zeros((B, self.prompt_pad), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, r in enumerate(reqs):
            p = r.prompt[: self.prompt_pad]
            toks[i, : len(p)] = p
            lens[i] = len(p)
        for i in range(len(reqs), B):  # pad slots replay request 0
            toks[i] = toks[0]
            lens[i] = lens[0]
        return jnp.asarray(toks), jnp.asarray(lens)

    def step(self) -> List[Request]:
        """Serve one batch from the queue; returns completed requests."""
        if not self.queue:
            return []
        reqs, self.queue = self.queue[: self.batch_size], self.queue[self.batch_size:]
        toks, lens = self._make_batch(reqs)
        max_new = max(r.max_new for r in reqs)
        t0 = time.perf_counter()
        seq, stats = self.engine.generate(toks, lens, max_new)
        dt = time.perf_counter() - t0
        for i, r in enumerate(reqs):
            out = seq[i][seq[i] >= 0][: r.max_new]
            if self.eos_id is not None:
                stop = np.nonzero(out == self.eos_id)[0]
                if len(stop):
                    out = out[: stop[0] + 1]
            r.result = out
            r.stats = {**stats.summary(), "batch_time_s": dt}
            self.done[r.uid] = r
        return reqs

    def run(self) -> Dict[int, Request]:
        while self.queue:
            self.step()
        return self.done
