"""Batched serving driver.

Requests are padded into fixed-size batches (static shapes) and decoded with
the speculative engine. This is the single-tenant latency-optimal regime of
the paper (§9): one batch in flight, engine monopolizes the device.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.engine import SpeculativeEngine


@dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] token ids
    max_new: int = 32
    result: Optional[np.ndarray] = None
    stats: Dict = field(default_factory=dict)
    stream: Optional[Callable[[int, np.ndarray], None]] = None
    # stream(uid, tokens) is called with each emitted chunk (continuous mode)
    truncated: bool = False     # prompt exceeded prompt_pad and was cut
    # None (not 0.0) until first stamped: a trace arrival AT t=0.0 must not
    # be mistaken for "unstamped" and re-stamped on a recovery resubmission
    t_submit: Optional[float] = None
    t_start: float = 0.0        # first prefill (admission to a slot / batch)
    t_finish: float = 0.0
    # failure recovery: effective prompt + already-delivered tokens. When
    # set, admission prefills THIS instead of the prompt — greedy decode
    # then continues the original stream token-exactly (the verifier gates
    # every token, so re-prefilling the delivered prefix reproduces the
    # next token deterministically). max_new must already be decremented by
    # the delivered count; t_submit is preserved (no SLO reset on replay).
    replay_prefix: Optional[np.ndarray] = None


def pad_prompt(req: Request, prompt_pad: int):
    """Right-pad a request's prompt to `prompt_pad`; truncation is recorded
    on the request, never silent. Returns (tokens [prompt_pad], length)."""
    if len(req.prompt) > prompt_pad:
        req.truncated = True
    p = np.asarray(req.prompt[: prompt_pad], np.int32)
    toks = np.zeros(prompt_pad, np.int32)
    toks[: len(p)] = p
    return toks, len(p)


def cut_at_eos(tokens: np.ndarray, eos_id: Optional[int]):
    """Cut `tokens` after the first EOS. Returns (tokens, hit_eos)."""
    if eos_id is not None:
        stop = np.nonzero(tokens == eos_id)[0]
        if len(stop):
            return tokens[: stop[0] + 1], True
    return tokens, False


class BatchedServer:
    def __init__(self, engine: SpeculativeEngine, batch_size: int,
                 prompt_pad: int, eos_id: Optional[int] = None):
        self.engine = engine
        self.batch_size = batch_size
        self.prompt_pad = prompt_pad
        self.eos_id = eos_id
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}

    def submit(self, req: Request):
        if req.t_submit is None:
            req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _make_batch(self, reqs: List[Request]):
        if not reqs:
            raise ValueError("_make_batch needs at least one request")
        B = self.batch_size
        toks = np.zeros((B, self.prompt_pad), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, r in enumerate(reqs):
            toks[i], lens[i] = pad_prompt(r, self.prompt_pad)
        for i in range(len(reqs), B):  # pad slots replay request 0
            toks[i] = toks[0]
            lens[i] = lens[0]
        return jnp.asarray(toks), jnp.asarray(lens)

    def step(self) -> List[Request]:
        """Serve one batch from the queue; returns completed requests."""
        if not self.queue:
            return []
        reqs, self.queue = self.queue[: self.batch_size], self.queue[self.batch_size:]
        toks, lens = self._make_batch(reqs)
        max_new = max(r.max_new for r in reqs)
        t0 = time.perf_counter()
        for r in reqs:
            r.t_start = t0
        seq, stats = self.engine.generate(toks, lens, max_new)
        dt = time.perf_counter() - t0
        mesh_devices = self.engine.mesh_info()["devices"]
        for i, r in enumerate(reqs):
            out, _ = cut_at_eos(seq[i][seq[i] >= 0][: r.max_new], self.eos_id)
            r.result = out
            r.t_finish = time.perf_counter()
            r.stats = {**stats.summary(), "batch_time_s": dt,
                       "prompt_truncated": r.truncated,
                       "mesh_devices": mesh_devices}
            self.done[r.uid] = r
        return reqs

    def run(self) -> Dict[int, Request]:
        while self.queue:
            self.step()
        return self.done
