"""Online bucket controller for adaptive continuous serving.

The paper's latency objective (§4.1) runs offline: profile once, pick one
⟨D, W, V⟩, pin it. This module runs the same objective *online* over a small
precompiled **bucket ladder**: every megastep the controller re-scores the
ladder from

  (a) per-bucket AAL — an EMA of observed accept lengths (optimistic
      depth+1 prior for buckets not yet visited, so each gets tried once),
  (b) per-bucket iteration time — the measured ``LatencyProfile`` through
      ``speedup_objective`` when a profile is given, otherwise an online
      EMA of observed wall-clock iteration times seeded at warmup,
  (c) pool occupancy — with a profile, the number of active slots feeds the
      latency model's ``batch`` term: a full pool pushes wide/deep buckets
      past the saturation knee (shallow wins), a draining pool keeps deep
      trees in the flat memory-bound region (deep wins). WITHOUT a profile
      there is no model to predict a bucket's cost at a different
      occupancy, so online mode reacts to occupancy only indirectly —
      observed iteration times already include whatever occupancy they ran
      at, and the EMA lags the pool,

with hysteresis: the incumbent bucket is kept unless a challenger beats it
by a relative margin AND the incumbent has dwelt for a minimum number of
steps. That bounds switching frequency, keeps the executable cache hot
(every ladder bucket is compiled at warmup — switching replays a different
cached executable, it never compiles), and prevents flapping on noisy AAL.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

from repro.core.buckets import Bucket
from repro.core.objective import (AALEstimator, LatencyProfile, ema_update,
                                  speedup_objective, step_latency)

BucketKey = Tuple[int, int, int]


class BucketController:
    """Pick the next megastep's bucket from a precompiled ladder."""

    def __init__(self, ladder: Sequence[Bucket],
                 profile: Optional[LatencyProfile] = None,
                 aal_alpha: float = 0.3, iter_alpha: float = 0.3,
                 hysteresis: float = 0.1, min_dwell: int = 2):
        if not ladder:
            raise ValueError("controller needs a non-empty bucket ladder")
        self.ladder: Tuple[Bucket, ...] = tuple(ladder)
        self.profile = profile
        self.aal = AALEstimator(alpha=aal_alpha)
        self.iter_alpha = iter_alpha
        self.hysteresis = hysteresis
        self.min_dwell = min_dwell
        self._iter_ema: Dict[BucketKey, float] = {}
        self.current: Optional[Bucket] = None
        self.switches = 0
        self._dwell = 0
        # graceful degradation: when True (set by the front-end past the
        # overload knee or with a replica down), choose() floors the ladder
        # at its shallowest warmed bucket — the cheapest compiled step, the
        # closest thing to plain decode that cannot recompile
        self.degraded = False
        # why the most recent switch happened (scores, occupancy, dwell) —
        # surfaced as a structured `bucket_switch` event by the server
        self.last_switch: Optional[Dict[str, object]] = None

    # ------------------------------------------------------------ telemetry --
    def seed_iter_times(self, times: Dict[BucketKey, float]):
        """Seed the per-bucket iteration-time EMAs (from warmup replays)."""
        for k, t in times.items():
            if t > 0:
                self._iter_ema.setdefault(k, float(t))

    def observe(self, key: BucketKey, mean_accept_len: float,
                iter_time: float):
        """Feed one megastep's outcome back into the estimators."""
        self.aal.update(key, mean_accept_len)
        if iter_time > 0:
            ema_update(self._iter_ema, key, iter_time, self.iter_alpha)

    def observe_iter(self, key: BucketKey, iter_time: float):
        """Feed an iteration time alone — the deferred-timing path, where an
        emulation driver charges the profile cost after the step ran (the
        AAL half of that step was already fed through ``observe``)."""
        if iter_time > 0:
            ema_update(self._iter_ema, key, iter_time, self.iter_alpha)

    # -------------------------------------------------------------- scoring --
    def score(self, bucket: Bucket, n_active: int = 1,
              lane_cost: float = 0.0) -> float:
        """Estimated speedup of running `bucket` at the current occupancy.

        Profile mode predicts the cost at ``n_active`` explicitly. Online
        mode (no profile) scores AAL per observed second and necessarily
        ignores ``n_active`` — the iter-time EMA embeds the occupancy its
        observations ran at (see the module docstring, item c).

        ``lane_cost`` is the emulated/profiled seconds the step will ALSO
        spend on interleaved prefill chunks: a shared per-step tax that
        dilutes every bucket's tokens-per-second, but dilutes a cheap
        shallow step proportionally more than an expensive deep one — so
        under prefill pressure the controller leans deep, amortizing the
        lane over more accepted tokens per dispatch."""
        aal = self.aal.estimate(bucket.key())
        if self.profile is not None:
            s = speedup_objective(self.profile, aal, bucket.depth,
                                  bucket.width, bucket.verify,
                                  batch=max(1, n_active))
            if lane_cost > 0.0:
                t = step_latency(self.profile, bucket.depth, bucket.width,
                                 bucket.verify, batch=max(1, n_active))
                s *= t / (t + lane_cost)
            return s
        t = self._iter_ema.get(bucket.key())
        if t is None:
            return float("inf")     # unvisited: explore it once
        return aal / (t + max(0.0, lane_cost))

    def prefill_budget(self, n_active: int, pool: int,
                       chunks: Sequence[int]) -> int:
        """Token budget for the interleaved prefill lane this step.

        With a latency profile the budget is priced against the decode work
        it taxes: the lane may spend a fraction of the incumbent bucket's
        step latency that scales with pool idleness (25% under a full pool —
        prefill must not starve, or admissions never finish — up to 125%
        when the pool sits empty and decode has nothing better to do). The
        largest configured chunk whose verifier cost fits that allowance
        wins; the smallest chunk is the floor, so prefill always advances.

        Without a profile there is nothing to price against, so the policy
        degenerates to the same shape: drain fast while slots idle, trickle
        at minimum width once the pool is busy."""
        chunks = sorted(int(c) for c in chunks)
        if not chunks:
            return 0
        if self.profile is None:
            return chunks[-1] if n_active < pool else chunks[0]
        cur = self.current if self.current is not None else self.ladder[0]
        t_step = step_latency(self.profile, cur.depth, cur.width, cur.verify,
                              batch=max(1, n_active))
        idle_frac = 1.0 - n_active / max(1, pool)
        allow = t_step * (0.25 + idle_frac)
        fit = [c for c in chunks if self.profile.t_verify(c) <= allow]
        return fit[-1] if fit else chunks[0]

    def choose(self, n_active: int = 1, lane_cost: float = 0.0) -> Bucket:
        """Bucket for the next megastep, with hysteresis on the incumbent."""
        if self.degraded:
            floor = min(self.ladder,
                        key=lambda b: (b.depth, b.width, b.verify))
            if self.current is not None and self.current.key() != floor.key():
                self.last_switch = {
                    "from": "x".join(map(str, self.current.key())),
                    "to": "x".join(map(str, floor.key())),
                    "n_active": n_active, "reason": "degraded",
                }
                self.switches += 1
            self.current, self._dwell = floor, 0
            return self.current
        scores = {b.key(): self.score(b, n_active, lane_cost)
                  for b in self.ladder}
        best = max(self.ladder, key=lambda b: scores[b.key()])  # first wins ties
        if self.current is None:
            self.current, self._dwell = best, 0
        elif (best.key() != self.current.key()
              and self._dwell >= self.min_dwell
              and scores[best.key()]
              > scores[self.current.key()] * (1.0 + self.hysteresis)):
            self.last_switch = {
                "from": "x".join(map(str, self.current.key())),
                "to": "x".join(map(str, best.key())),
                "score_from": scores[self.current.key()],
                "score_to": scores[best.key()],
                "n_active": n_active, "dwell": self._dwell,
            }
            self.current, self._dwell = best, 0
            self.switches += 1
        else:
            self._dwell += 1
        return self.current

    def summary(self) -> Dict[str, object]:
        return {
            "ladder": [list(b.key()) for b in self.ladder],
            "current": list(self.current.key()) if self.current else None,
            "switches": self.switches,
            "degraded": self.degraded,
            "aal_estimates": {str(k): v for k, v in
                              self.aal.estimates(
                                  [b.key() for b in self.ladder]).items()},
            "iter_ema_s": {str(k): v for k, v in self._iter_ema.items()},
        }
