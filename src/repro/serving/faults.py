"""Deterministic fault injection for the serving stack.

A :class:`FaultPlan` is a seeded, time-ordered list of :class:`FaultEvent`
entries — replica crashes, step hangs, transient step exceptions, NaN/Inf
verifier logits, and paged-pool exhaustion — that the front-end applies at
trace timestamps on the **emulated clock** (`ServingFrontend.serve_trace`
consumes events as their timestamps come due, so two drives of the same
plan against the same trace are byte-identical).  For the wall-clock
asyncio path, :class:`WallFaultInjector` monkeypatches each replica
server's ``step`` so the same plan fires at wall offsets from ``start()``.

The plan only *describes* faults; all recovery semantics (health model,
evacuation, token-exact replay) live in ``serving/frontend.py``.  Fault
kinds:

========== ===============================================================
kind       effect at the step boundary
========== ===============================================================
crash      the step raises a fatal :class:`ReplicaError`; no work happens
hang       the step burns ``duration_s`` (or the watchdog budget) and
           raises :class:`StepTimeout`
error      the step raises a *transient* :class:`ReplicaError` (counts
           against the consecutive-error watchdog, retried in place)
nan        the engine's next megastep raises :class:`NumericalFault`
           (via ``poison_next_step`` — same path as real non-finite
           logits)
pool_      ``duration_s`` worth of free pages vanish from the replica's
exhaust    paged pool, so allocations hit :class:`PoolExhausted`; pages
           are returned when the window closes
========== ===============================================================
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.errors import ReplicaError, StepTimeout

KINDS = ("crash", "hang", "error", "nan", "pool_exhaust")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: fire at (or after) time ``t`` on ``replica``."""
    t: float                 # seconds on the driving clock
    kind: str                # one of KINDS
    replica: int             # target replica index
    duration_s: float = 0.0  # hang length / pool-theft window
    pages: int = 0           # pool_exhaust: pages stolen (0 = every free page)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")


class FaultPlan:
    """A time-ordered fault schedule.  ``pop_due`` hands each event out
    exactly once, at the first step of its target replica at or after the
    event's timestamp — fully deterministic given the plan and the clock."""

    def __init__(self, events: Sequence[FaultEvent] = (),
                 seed: Optional[int] = None):
        self.seed = seed
        self.events: List[FaultEvent] = sorted(events, key=lambda e: e.t)
        self._pending: List[FaultEvent] = list(self.events)
        self.injected: Dict[str, int] = {k: 0 for k in KINDS}

    @classmethod
    def seeded(cls, seed: int, horizon_s: float, replicas: int,
               n_faults: int = 4,
               kinds: Sequence[str] = ("crash", "hang", "error", "nan"),
               ) -> "FaultPlan":
        """Sample ``n_faults`` events uniformly over ``[0, horizon_s)`` —
        same seed, same plan, always."""
        rng = np.random.default_rng(seed)
        events = [
            FaultEvent(t=float(rng.uniform(0.0, horizon_s)),
                       kind=str(rng.choice(list(kinds))),
                       replica=int(rng.integers(0, replicas)),
                       duration_s=float(rng.uniform(0.5, 2.0)))
            for _ in range(n_faults)
        ]
        return cls(events, seed=seed)

    @property
    def faults_injected(self) -> int:
        return sum(self.injected.values())

    def pop_due(self, replica: int, now: float) -> Optional[FaultEvent]:
        """The earliest not-yet-fired event for ``replica`` with
        ``t <= now``, or None.  At most one event per call: a step boundary
        absorbs one fault."""
        for i, ev in enumerate(self._pending):
            if ev.t > now:
                return None  # _pending is time-sorted
            if ev.replica == replica:
                self.injected[ev.kind] += 1
                return self._pending.pop(i)
        return None

    def reset(self) -> None:
        """Re-arm every event (for a second deterministic drive)."""
        self._pending = list(self.events)
        self.injected = {k: 0 for k in KINDS}

    def summary(self) -> Dict:
        return {"seed": self.seed,
                "events": len(self.events),
                "injected": dict(self.injected),
                "faults_injected": self.faults_injected}


# ---------------------------------------------------------------- wall shim
class WallFaultInjector:
    """Monkeypatch shim for the asyncio (wall-clock) path.

    Wraps each replica server's ``step`` so plan events fire at wall
    offsets from :meth:`start`.  ``hang`` sleeps through the front-end's
    watchdog budget before raising; ``pool_exhaust`` steals the replica's
    free pages and returns them when the window closes (checked at each
    subsequent step of that replica).  Use as a context manager::

        with WallFaultInjector(frontend.router.replicas, plan):
            asyncio.run(frontend.run_until_drained())
    """

    def __init__(self, replicas: Sequence, plan: FaultPlan,
                 clock: Callable[[], float] = time.monotonic):
        self.replicas = list(replicas)
        self.plan = plan
        self._clock = clock
        self._t0: Optional[float] = None
        self._orig: Dict[int, Callable] = {}
        self._stolen: Dict[int, List[Tuple[float, List[int]]]] = {}

    def start(self) -> None:
        self._t0 = self._clock()
        for rep in self.replicas:
            self._orig[rep.idx] = rep.server.step
            rep.server.step = self._wrap(rep)

    def stop(self) -> None:
        for rep in self.replicas:
            orig = self._orig.pop(rep.idx, None)
            if orig is not None:
                rep.server.step = orig
        # return any pages still held when the run ends
        for idx, windows in self._stolen.items():
            ps = self._pages(self.replicas[idx])
            if ps is not None:
                for _, pages in windows:
                    ps.free.extend(pages)
        self._stolen.clear()

    def __enter__(self) -> "WallFaultInjector":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    @staticmethod
    def _pages(rep):
        return getattr(getattr(rep.server, "state", None), "pages", None)

    def _wrap(self, rep):
        orig = self._orig[rep.idx]

        def step():
            now = self._clock() - self._t0
            self._restore(rep, now)
            ev = self.plan.pop_due(rep.idx, now)
            if ev is not None:
                if ev.kind == "crash":
                    raise ReplicaError(
                        f"injected crash on replica {rep.idx}")
                if ev.kind == "hang":
                    time.sleep(ev.duration_s)
                    raise StepTimeout(
                        f"injected hang on replica {rep.idx}",
                        timeout_s=ev.duration_s)
                if ev.kind == "error":
                    raise ReplicaError(
                        f"injected transient error on replica {rep.idx}",
                        fatal=False)
                if ev.kind == "nan":
                    poison = getattr(rep.server.engine, "poison_next_step",
                                     None)
                    if callable(poison):
                        poison()
                elif ev.kind == "pool_exhaust":
                    self._steal(rep, ev, now)
            return orig()

        return step

    def _steal(self, rep, ev: FaultEvent, now: float) -> None:
        ps = self._pages(rep)
        if ps is None:
            return
        take = ev.pages or len(ps.free)
        stolen = [ps.free.pop() for _ in range(min(take, len(ps.free)))]
        self._stolen.setdefault(rep.idx, []).append(
            (now + (ev.duration_s or 1.0), stolen))

    def _restore(self, rep, now: float) -> None:
        windows = self._stolen.get(rep.idx)
        if not windows:
            return
        keep = []
        for until, pages in windows:
            if now >= until:
                ps = self._pages(rep)
                if ps is not None:
                    ps.free.extend(pages)
            else:
                keep.append((until, pages))
        self._stolen[rep.idx] = keep
