"""Public serving API.

The stable surface of ``repro.serving`` is exactly ``__all__`` below —
``tests/test_public_api.py`` pins it. Three layers, composable top-down:

* **engines in a loop** — :class:`BatchedServer` (padded run-to-completion)
  and :class:`ContinuousServer` (slot pool, mid-flight refill, optional
  :class:`BucketController` adaptivity). ``submit()`` returns a
  :class:`RequestHandle`; ``serve()`` drains the pool. ``run()`` survives
  only as a deprecated ``Dict[int, Request]`` shim.
* **the async front-end** — :class:`ServingFrontend` multiplexes N
  continuous replicas behind a session-affine SLO-aware :class:`Router`
  with :class:`AdmissionConfig`-controlled admission; emulated-clock runs
  go through :func:`drive_frontend_trace`.
* **fault tolerance** — typed step errors (:class:`ServingError` and its
  subclasses), deterministic fault injection (:class:`FaultPlan` of
  :class:`FaultEvent` rows), and :class:`RecoveryConfig`-tuned replica
  failure recovery with token-exact replay (see ``serving/frontend.py``).
* **configuration** — :class:`ServeConfig` is the one CLI/JSON-
  round-trippable config the launcher and the benchmarks both build from.

Anything not exported here (``repro.serving.emulation`` internals, the
``_``-prefixed server machinery) may change without notice.
"""
from repro.serving.config import ServeConfig
from repro.serving.continuous import ContinuousServer, ServingMetrics
from repro.serving.controller import BucketController
from repro.serving.errors import (NoReplicaAvailable, NumericalFault,
                                  PoolExhausted, ReplicaError, ServingError,
                                  StepTimeout)
from repro.serving.faults import FaultEvent, FaultPlan
from repro.serving.frontend import (AdmissionConfig, FrontendMetrics,
                                    RecoveryConfig, ServingFrontend,
                                    drive_frontend_trace)
from repro.serving.handle import RequestHandle
from repro.serving.router import Replica, Router, RouterMetrics
from repro.serving.sampling import mask_padded_vocab, sample
from repro.serving.server import BatchedServer, Request

__all__ = [
    "AdmissionConfig",
    "BatchedServer",
    "BucketController",
    "ContinuousServer",
    "FaultEvent",
    "FaultPlan",
    "FrontendMetrics",
    "NoReplicaAvailable",
    "NumericalFault",
    "PoolExhausted",
    "RecoveryConfig",
    "Replica",
    "ReplicaError",
    "Request",
    "RequestHandle",
    "Router",
    "RouterMetrics",
    "ServeConfig",
    "ServingError",
    "ServingFrontend",
    "ServingMetrics",
    "StepTimeout",
    "drive_frontend_trace",
    "mask_padded_vocab",
    "sample",
]
