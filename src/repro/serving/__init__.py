from repro.serving.continuous import ContinuousServer, ServingMetrics
from repro.serving.controller import BucketController
from repro.serving.sampling import mask_padded_vocab, sample
from repro.serving.server import BatchedServer, Request
