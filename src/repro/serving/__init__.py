"""Public serving API.

The stable surface of ``repro.serving`` is exactly ``__all__`` below —
``tests/test_public_api.py`` pins it. Three layers, composable top-down:

* **engines in a loop** — :class:`BatchedServer` (padded run-to-completion)
  and :class:`ContinuousServer` (slot pool, mid-flight refill, optional
  :class:`BucketController` adaptivity). ``submit()`` returns a
  :class:`RequestHandle`; ``serve()`` drains the pool. ``run()`` survives
  only as a deprecated ``Dict[int, Request]`` shim.
* **the async front-end** — :class:`ServingFrontend` multiplexes N
  continuous replicas behind a session-affine SLO-aware :class:`Router`
  with :class:`AdmissionConfig`-controlled admission; emulated-clock runs
  go through :func:`drive_frontend_trace`.
* **configuration** — :class:`ServeConfig` is the one CLI/JSON-
  round-trippable config the launcher and the benchmarks both build from.

Anything not exported here (``repro.serving.emulation`` internals, the
``_``-prefixed server machinery) may change without notice.
"""
from repro.serving.config import ServeConfig
from repro.serving.continuous import ContinuousServer, ServingMetrics
from repro.serving.controller import BucketController
from repro.serving.frontend import (AdmissionConfig, FrontendMetrics,
                                    ServingFrontend, drive_frontend_trace)
from repro.serving.handle import RequestHandle
from repro.serving.router import Replica, Router, RouterMetrics
from repro.serving.sampling import mask_padded_vocab, sample
from repro.serving.server import BatchedServer, Request

__all__ = [
    "AdmissionConfig",
    "BatchedServer",
    "BucketController",
    "ContinuousServer",
    "FrontendMetrics",
    "Replica",
    "Request",
    "RequestHandle",
    "Router",
    "RouterMetrics",
    "ServeConfig",
    "ServingFrontend",
    "ServingMetrics",
    "drive_frontend_trace",
    "mask_padded_vocab",
    "sample",
]
