"""Aligned drafter/verifier pair for CPU-scale experiments.

Trains a small verifier and a smaller drafter on the same Markov corpus so
that the drafter genuinely approximates the verifier (the llama-68m /
llama-2-7b relationship at laptop scale). Checkpoints are cached on disk so
tests and benchmarks pay the training cost once.
"""
from __future__ import annotations

import hashlib
import os
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, batches
from repro.models import Model
from repro.training import (OptConfig, init_opt_state, make_train_step,
                            restore_checkpoint, save_checkpoint)

CACHE_DIR = os.environ.get("REPRO_CACHE", "/root/repo/.cache")


@dataclass
class TestbedSpec:
    __test__ = False  # not a pytest class despite the name
    vocab: int = 64
    seq_len: int = 128
    concentration: float = 0.03
    train_steps: int = 240
    batch: int = 32
    verifier_layers: int = 4
    verifier_dim: int = 256
    drafter_layers: int = 1
    drafter_dim: int = 128
    max_target_len: int = 512
    seed: int = 0

    def key(self) -> str:
        s = repr(self).encode()
        return hashlib.sha1(s).hexdigest()[:12]


@dataclass
class Testbed:
    __test__ = False  # not a pytest class despite the name
    spec: TestbedSpec
    verifier: Model
    v_params: dict
    drafter: Model
    d_params: dict
    data_cfg: DataConfig
    losses: Tuple[float, float] = (0.0, 0.0)


def _model_cfg(name: str, layers: int, dim: int, spec: TestbedSpec) -> ModelConfig:
    return ModelConfig(
        name=name, num_layers=layers, d_model=dim, num_heads=max(2, dim // 64),
        num_kv_heads=max(2, dim // 64), head_dim=64, d_ff=dim * 4,
        vocab_size=spec.vocab, max_seq_len=spec.max_target_len)


def _train(model: Model, params, data_cfg: DataConfig, steps: int,
           seed: int) -> Tuple[dict, float]:
    opt = OptConfig(lr=1e-3, warmup_steps=20, total_steps=steps)
    step_fn = jax.jit(make_train_step(model, opt))
    state = init_opt_state(params)
    loss = float("nan")
    for batch in batches(data_cfg, steps):
        params, state, metrics = step_fn(params, state,
                                         {"tokens": jnp.asarray(batch["tokens"])})
        loss = float(metrics["loss"])
    return params, loss


def build_testbed(spec: Optional[TestbedSpec] = None,
                  force: bool = False) -> Testbed:
    spec = spec or TestbedSpec()
    vcfg = _model_cfg("testbed-verifier", spec.verifier_layers,
                      spec.verifier_dim, spec)
    dcfg = _model_cfg("testbed-drafter", spec.drafter_layers,
                      spec.drafter_dim, spec)
    verifier, drafter = Model(vcfg), Model(dcfg)
    v_params = verifier.init(jax.random.PRNGKey(spec.seed))
    d_params = drafter.init(jax.random.PRNGKey(spec.seed + 1))
    data_cfg = DataConfig(vocab=spec.vocab, seq_len=spec.seq_len,
                          batch=spec.batch, concentration=spec.concentration,
                          seed=spec.seed)

    path = os.path.join(CACHE_DIR, f"testbed_{spec.key()}.npz")
    if os.path.exists(path) and not force:
        blob = restore_checkpoint(path, {"v": v_params, "d": d_params})
        return Testbed(spec, verifier, blob["v"], drafter, blob["d"], data_cfg)

    v_params, v_loss = _train(verifier, v_params, data_cfg, spec.train_steps,
                              spec.seed)
    d_params, d_loss = _train(drafter, d_params, data_cfg, spec.train_steps,
                              spec.seed + 7)
    os.makedirs(CACHE_DIR, exist_ok=True)
    save_checkpoint(path, {"v": v_params, "d": d_params})
    return Testbed(spec, verifier, v_params, drafter, d_params, data_cfg,
                   losses=(v_loss, d_loss))
