"""SLO-aware request routing over a pool of engine replicas.

One :class:`ContinuousServer` is one engine replica: a slot pool over one
compiled megastep family (possibly mesh-sharded). The :class:`Router` is
the layer that turns N of them into a service — it decides, per request,
WHICH replica admits it, and it owns the replica lifecycle (drain /
scale-down / scale-up) the front-end emulates on the testbed clock.

Placement policy, in priority order:

1. **Session affinity** — requests carrying a session id stay pinned to
   the replica that served the session before (KV-prefix locality: at
   millions-of-users scale, re-routing a session re-prefills its whole
   context on a cold replica). A pin to a draining or retired replica is
   re-pinned to the best live replica and counted (``repins``).
2. **SLO-aware least-cost** — among active replicas, pick the one whose
   *modeled* time-to-slot is smallest: queued work ahead of the request,
   priced by ``objective.step_latency`` at the replica's bucket and
   projected occupancy (so a replica past its saturation knee looks as
   expensive as it actually is). Without a profile this degrades to
   least-loaded. Ties break on the lowest replica index — routing is a
   pure function of queue state, which is what keeps emulated-clock runs
   byte-deterministic.

Drain/scale semantics: ``drain()`` stops new admissions while in-flight
slots retire on the replica's own warmup-compiled executables (NO
recompile — the pool shape never changes, the slots simply empty out);
``scale_down()`` is drain plus retirement once empty; ``scale_up()``
reactivates a retired replica whose executable cache is still warm.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.objective import LatencyProfile, step_latency
from repro.serving.continuous import ContinuousServer
from repro.serving.errors import NoReplicaAvailable

# replica lifecycle states
ACTIVE = "active"
DRAINING = "draining"
RETIRED = "retired"
FAILED = "failed"          # crashed/wedged: evacuated, awaiting backoff
RECOVERING = "recovering"  # backoff elapsed, rejoining the pool


class Replica:
    """One engine replica in the router's pool."""

    def __init__(self, idx: int, server: ContinuousServer):
        self.idx = idx
        self.server = server
        self.state = ACTIVE
        self.routed = 0          # requests this replica admitted, lifetime
        # ---- health model (driven by the front-end's step boundary)
        self.consecutive_errors = 0  # transient errors since last good step
        self.faults_seen = 0     # typed step errors observed, lifetime
        self.failures = 0        # times this replica entered FAILED
        self.replays = 0         # in-flight requests evacuated + replayed
        self.recoveries = 0      # FAILED -> ACTIVE round trips
        self.failed_at: Optional[float] = None
        self.recover_at: Optional[float] = None  # backoff expiry
        self.mttr_total = 0.0    # summed FAILED->ACTIVE downtime, seconds

    # ------------------------------------------------------------- load --
    def in_flight(self) -> int:
        """Requests occupying slots right now."""
        return sum(1 for r in self.server.slots if r is not None)

    def queued(self) -> int:
        return len(self.server.queue)

    def load(self) -> int:
        return self.in_flight() + self.queued()

    def free_slots(self) -> int:
        return self.server.batch_size - self.in_flight()

    def has_work(self) -> bool:
        """Anything left to step — draining replicas keep stepping until
        their in-flight slots retire."""
        return bool(self.server.queue) or self.in_flight() > 0

    def accepting(self) -> bool:
        return self.state == ACTIVE

    def steppable(self) -> bool:
        """May this replica's step() be driven? FAILED replicas are wedged
        until recovery; RETIRED ones are gone."""
        return self.state in (ACTIVE, DRAINING, RECOVERING)

    def summary(self) -> Dict:
        m = self.server.metrics.summary()
        return {"state": self.state, "routed": self.routed,
                "steps": m["steps"], "completed": m["completed"],
                "tokens": m["tokens"], "occupancy": m["occupancy"],
                "faults_seen": self.faults_seen, "failures": self.failures,
                "replays": self.replays, "recoveries": self.recoveries,
                "mttr_s": self.mttr_total,
                "pool_parks": m["pool_parks"],
                "recompiles_after_warmup": m["recompiles_after_warmup"]}


@dataclass
class RouterMetrics:
    """Routing decisions and replica lifecycle events, by count."""
    routed: Dict[int, int] = field(default_factory=dict)
    affinity_hits: int = 0    # session routed to its pinned replica
    repins: int = 0           # pin moved off a draining/retired replica
    drains: int = 0
    scale_downs: int = 0
    scale_ups: int = 0
    fails: int = 0            # replicas marked FAILED
    recoveries: int = 0       # replicas readmitted to ACTIVE after FAILED

    def summary(self) -> Dict:
        return {"routed": {str(k): v for k, v in sorted(self.routed.items())},
                "affinity_hits": self.affinity_hits, "repins": self.repins,
                "drains": self.drains, "scale_downs": self.scale_downs,
                "scale_ups": self.scale_ups, "fails": self.fails,
                "recoveries": self.recoveries}


class Router:
    """Session-affine, SLO-aware placement over N engine replicas."""

    def __init__(self, servers: Sequence[ContinuousServer],
                 profile: Optional[LatencyProfile] = None,
                 affinity: bool = True):
        if not servers:
            raise ValueError("router needs at least one replica")
        self.replicas: List[Replica] = [Replica(i, s)
                                        for i, s in enumerate(servers)]
        self.profile = profile
        self.affinity = affinity
        self.metrics = RouterMetrics()
        self._pins: Dict[str, int] = {}   # session id -> replica idx

    # --------------------------------------------------------- topology --
    def active(self) -> List[Replica]:
        return [r for r in self.replicas if r.accepting()]

    def live(self) -> List[Replica]:
        """Replicas holding or able to take work — FAILED ones are out of
        the pool (their work was evacuated) until they recover."""
        return [r for r in self.replicas if r.state not in (RETIRED, FAILED)]

    def total_slots(self) -> int:
        return sum(r.server.batch_size for r in self.active())

    def total_load(self) -> int:
        return sum(r.load() for r in self.live())

    def occupancy(self) -> float:
        """Live load over active slot capacity — the number admission
        control compares against the deadline-feasibility bound."""
        return self.total_load() / max(1, self.total_slots())

    # ---------------------------------------------------------- scoring --
    def est_wait(self, rep: Replica, extra: int = 1) -> float:
        """Modeled seconds until ``extra`` more requests reach a slot on
        this replica: full-queue waves ahead of them, each priced at the
        replica's bucket via ``step_latency`` at projected occupancy. An
        AAL of ~2 tokens/step means a request occupies its slot for about
        ``max_new / 2`` steps; we fold that into a per-wave service time of
        a few steps rather than modeling each request's length (admission
        needs an ordering signal, not a simulator)."""
        B = rep.server.batch_size
        q = rep.queued() + extra
        waves = max(0.0, (rep.in_flight() + q - B) / B)
        if self.profile is None:
            return waves + rep.load() / max(1, B)   # unitless least-loaded
        d = rep.server.spec.depth
        w = rep.server.spec.width
        v = rep.server.verify_v
        occ = min(B, max(1, rep.in_flight() + q))
        return step_latency(self.profile, d, w, v, batch=occ) * (1.0 + waves)

    def _best(self) -> Replica:
        pool = self.active()
        if not pool:
            # typed: the front-end queues-and-waits on this (bounded by
            # RecoveryConfig.no_replica_timeout_s) instead of crashing submit
            raise NoReplicaAvailable(
                "no active replica to route to (all draining/retired/failed)")
        # load before idx in the tie-break: below the saturation knee the
        # modeled wait is FLAT in occupancy, and an idx-only tie-break
        # would pile every session onto replica 0
        return min(pool, key=lambda r: (self.est_wait(r), r.load(), r.idx))

    # ---------------------------------------------------------- routing --
    def route(self, session: Optional[str] = None) -> Replica:
        """Pick the replica for one request (no submission side effects
        beyond pin bookkeeping and routing counters)."""
        rep: Optional[Replica] = None
        if self.affinity and session is not None:
            pin = self._pins.get(session)
            if pin is not None:
                pinned = self.replicas[pin]
                if pinned.accepting():
                    rep = pinned
                    self.metrics.affinity_hits += 1
                else:                      # pinned replica is going away
                    rep = self._best()
                    self._pins[session] = rep.idx
                    self.metrics.repins += 1
            else:
                rep = self._best()
                self._pins[session] = rep.idx
        if rep is None:
            rep = self._best()
        rep.routed += 1
        self.metrics.routed[rep.idx] = self.metrics.routed.get(rep.idx, 0) + 1
        return rep

    def submit(self, req, handle=None, session: Optional[str] = None):
        """Route and enqueue: returns ``(replica, handle)``."""
        rep = self.route(session=session)
        h = rep.server.submit(req, handle=handle)
        h.replica = rep.idx
        h.session = session
        return rep, h

    # ------------------------------------------------------- drain/scale --
    def drain(self, idx: int) -> Replica:
        """Stop routing to replica ``idx``; its in-flight slots retire on
        the already-compiled executables (pool shape unchanged — this is
        why a drain can never recompile)."""
        rep = self.replicas[idx]
        if rep.state == ACTIVE:
            rep.state = DRAINING
            self.metrics.drains += 1
        return rep

    def scale_down(self, idx: int) -> Replica:
        """Drain and mark for retirement once empty (an emulated
        autoscaler removing capacity)."""
        rep = self.drain(idx)
        self.metrics.scale_downs += 1
        return rep

    def scale_up(self, idx: int) -> Replica:
        """Reactivate a drained/retired replica. Its executable cache is
        still warm from the original warmup, so rejoining the pool costs
        zero compiles."""
        rep = self.replicas[idx]
        if rep.state != ACTIVE:
            rep.state = ACTIVE
            self.metrics.scale_ups += 1
        return rep

    # ------------------------------------------------------ fail/recover --
    def fail(self, idx: int) -> Replica:
        """Mark replica ``idx`` FAILED: it stops accepting AND stepping.
        The caller (front-end) evacuates its work and schedules the
        backoff; the executable cache stays warm for recovery."""
        rep = self.replicas[idx]
        if rep.state not in (RETIRED, FAILED):
            rep.state = FAILED
            rep.failures += 1
            self.metrics.fails += 1
        return rep

    def recover(self, idx: int) -> Replica:
        """Readmit a FAILED replica to ACTIVE (through RECOVERING). Like
        ``scale_up``, the warmup-compiled executables are still cached, so
        rejoining costs zero compiles."""
        rep = self.replicas[idx]
        if rep.state == FAILED:
            rep.state = RECOVERING
        if rep.state == RECOVERING:
            rep.state = ACTIVE
            rep.consecutive_errors = 0
            rep.recover_at = None
            rep.recoveries += 1
            self.metrics.recoveries += 1
        return rep

    def reap(self) -> List[int]:
        """Retire replicas that finished draining (no queue, no slots).
        Returns the indices retired by this call."""
        out = []
        for rep in self.replicas:
            if rep.state == DRAINING and not rep.has_work():
                rep.state = RETIRED
                out.append(rep.idx)
        return out

    def summary(self) -> Dict:
        return {**self.metrics.summary(),
                "replicas": {str(r.idx): r.summary() for r in self.replicas}}
