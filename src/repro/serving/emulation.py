"""Emulated-clock driving for scheduling-policy evaluation.

Wall clock on the CPU testbed cannot distinguish draft-tree buckets — it is
dominated by interpreter and dispatch overhead, not by the width-latency
curves the scheduler reasons about. Experiments that compare scheduling
policies therefore run the REAL engine (real token flow, real acceptance)
but charge each megastep the latency model's occupancy-aware cost
(`objective.step_latency`) and each admission one prefill-width verifier
call, accumulating an emulated clock. Used by benchmarks/fig_serving.py's
``adaptive_sweep`` and tests/test_adaptive_serving.py — one implementation,
so the acceptance test and the benchmark artifact cannot disagree about
what a step costs.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core.objective import LatencyProfile, step_latency
from repro.serving.continuous import ContinuousServer


def charged_step(server: ContinuousServer, profile: LatencyProfile
                 ) -> Tuple[float, List]:
    """Run one ``server.step()`` and return (emulated cost, finished
    requests): admissions this call are charged a prefill-width verifier
    call each; a decode step is charged the profile latency of the bucket
    it ran at the occupancy it ran at."""
    adm0, steps0 = server.metrics.admissions, server.metrics.steps
    finished = server.step()
    cost = ((server.metrics.admissions - adm0)
            * profile.t_verify(server.prompt_pad))
    if server.metrics.steps > steps0:
        d, w, v = server.metrics.bucket_history[-1]
        n_active = int(round(server.metrics.occupancy[-1]
                             * server.batch_size))
        cost += step_latency(profile, d, w, v, batch=max(1, n_active))
    return cost, finished


def drive_trace(server: ContinuousServer, trace, profile: LatencyProfile
                ) -> Dict:
    """Replay ``trace`` ([(arrival_emu_s, Request)] sorted by arrival) on
    the emulated clock until everything retires. Warmup is charged nothing
    (it is off the steady-state path). Returns busy/makespan times and
    per-request submit->finish latencies in emulated seconds."""
    server.warmup()
    emu_t, busy = 0.0, 0.0
    submit_at: Dict[int, float] = {}
    finish_at: Dict[int, float] = {}
    pending: List = list(trace)
    while pending or server.queue or any(s is not None for s in server.slots):
        while pending and pending[0][0] <= emu_t:
            arr, req = pending.pop(0)
            submit_at[req.uid] = arr
            server.submit(req)
        if not (server.queue or any(s is not None for s in server.slots)):
            emu_t = pending[0][0]       # idle: jump to the next arrival
            continue
        cost, finished = charged_step(server, profile)
        emu_t += cost
        busy += cost
        for req in finished:
            finish_at[req.uid] = emu_t
    return {"busy_s": busy, "makespan_s": emu_t,
            "latencies_s": {u: finish_at[u] - submit_at[u]
                            for u in finish_at}}
