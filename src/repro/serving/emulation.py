"""Emulated-clock driving for scheduling-policy evaluation.

Wall clock on the CPU testbed cannot distinguish draft-tree buckets — it is
dominated by interpreter and dispatch overhead, not by the width-latency
curves the scheduler reasons about. Experiments that compare scheduling
policies therefore run the REAL engine (real token flow, real acceptance)
but charge each megastep the latency model's occupancy-aware cost
(`objective.step_latency`) and each admission one prefill-width verifier
call, accumulating an emulated clock. Used by benchmarks/fig_serving.py's
``adaptive_sweep`` and tests/test_adaptive_serving.py — one implementation,
so the acceptance test and the benchmark artifact cannot disagree about
what a step costs.

Clock integration: ``drive_trace`` installs an ``EmulatedClock`` on the
server (reusing the server's own if it already runs one, e.g. from an
attached ``Telemetry(clock=EmulatedClock())``), which flips the server into
deferred-timing mode — it stops recording wall durations and the driver
charges the profile costs back through ``observe_prefill``/``charge_step``.
Every timestamp the server takes (request submit/start/finish, tracer
spans, event log) then reads emulated seconds, so two identical drives
export bit-identical metrics snapshots and traces. ``charged_step`` called
directly on a wall-clock server (the adaptive tests do this) leaves the
server's own timing untouched, exactly as before.

Note on latencies: a request's ``t_finish`` is stamped DURING the step that
retires it, i.e. before that step's cost is charged to the clock, so
``metrics.latencies`` runs one step-cost behind the driver-side
``latencies_s`` (which is stamped after the charge). Both are deterministic;
the driver-side numbers are what the benchmark artifact reports.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core.objective import LatencyProfile, step_latency
from repro.serving.continuous import ContinuousServer
from repro.telemetry import EmulatedClock


def charged_step(server: ContinuousServer, profile: LatencyProfile,
                 advance_clock: bool = True) -> Tuple[float, List]:
    """Run one ``server.step()`` and return (emulated cost, finished
    requests): admissions this call are charged a prefill-width verifier
    call each; a decode step is charged the profile latency of the bucket
    it ran at the occupancy it ran at. On a deferred-timing server the
    charges are also fed back into its metrics/controller, and its
    EmulatedClock is advanced by the total — unless ``advance_clock`` is
    False, which a multi-replica driver uses to advance ONE shared clock
    by the max (not the sum) of concurrent replica step costs."""
    adm0, steps0 = server.metrics.admissions, server.metrics.steps
    finished = server.step()
    if getattr(server, "chunked", False):
        # chunked prefill: the lane's actual chunk widths are the prefill
        # work this step did — a short prompt is charged short chunks, not
        # one prompt-pad-width verifier call per admission
        cost = 0.0
        for c in server._last_chunks:
            chunk_cost = profile.t_verify(c)
            cost += chunk_cost
            if server._defer_timing:
                server.observe_prefill(chunk_cost)
    else:
        n_adm = server.metrics.admissions - adm0
        prefill_cost = profile.t_verify(server.prompt_pad)
        cost = n_adm * prefill_cost
        if server._defer_timing:
            for _ in range(n_adm):
                server.observe_prefill(prefill_cost)
    if server.metrics.steps > steps0:
        d, w, v = server.metrics.bucket_history[-1]
        n_active = int(round(server.metrics.occupancy[-1]
                             * server.batch_size))
        step_cost = step_latency(profile, d, w, v, batch=max(1, n_active))
        cost += step_cost
        if server._defer_timing:
            server.charge_step(step_cost)
    if advance_clock and isinstance(server.clock, EmulatedClock):
        server.clock.advance(cost)
    return cost, finished


def fault_step_cost(server: ContinuousServer,
                    profile: LatencyProfile) -> float:
    """Nominal emulated cost of a step that died mid-flight: the profile
    latency of the server's current bucket at its current occupancy. Used
    by the front-end's fault boundary — a failed step never returns, so
    ``charged_step`` cannot price it, but the emulated clock must still
    move or a crash would be free."""
    d, w = server.spec.depth, server.spec.width
    v = server.verify_v
    occ = max(1, sum(1 for r in server.slots if r is not None))
    return step_latency(profile, d, w, v, batch=occ)


def drive_trace(server: ContinuousServer, trace, profile: LatencyProfile
                ) -> Dict:
    """Replay ``trace`` ([(arrival_emu_s, Request)] sorted by arrival) on
    the emulated clock until everything retires. Warmup is charged nothing
    (it is off the steady-state path). Returns busy/makespan times and
    per-request submit->finish latencies in emulated seconds."""
    clock = (server.clock if isinstance(server.clock, EmulatedClock)
             else EmulatedClock())
    server.set_clock(clock)
    server.warmup()
    busy = 0.0
    submit_at: Dict[int, float] = {}
    finish_at: Dict[int, float] = {}
    pending: List = list(trace)
    while pending or server.queue or any(s is not None for s in server.slots):
        while pending and pending[0][0] <= clock.now():
            arr, req = pending.pop(0)
            submit_at[req.uid] = arr
            req.t_submit = arr  # queue latency measured in emulated seconds
            server.submit(req)
        if not (server.queue or any(s is not None for s in server.slots)):
            clock.advance_to(pending[0][0])   # idle: jump to the next arrival
            continue
        cost, finished = charged_step(server, profile)
        busy += cost
        for req in finished:
            finish_at[req.uid] = clock.now()
    return {"busy_s": busy, "makespan_s": clock.now(),
            "latencies_s": {u: finish_at[u] - submit_at[u]
                            for u in finish_at}}
