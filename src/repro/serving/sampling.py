"""Token sampling utilities (temperature / top-k / greedy), vocab-pad aware."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def mask_padded_vocab(logits: jax.Array, real_vocab: int) -> jax.Array:
    v = logits.shape[-1]
    if v == real_vocab:
        return logits
    mask = jnp.arange(v) < real_vocab
    return jnp.where(mask, logits, -1e9)


def sample(logits: jax.Array, key: Optional[jax.Array],
           temperature: float = 0.0, top_k: int = 0,
           real_vocab: Optional[int] = None) -> jax.Array:
    if real_vocab is not None:
        logits = mask_padded_vocab(logits, real_vocab)
    if temperature == 0.0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e9, logits)
    return jax.random.categorical(key, logits, -1).astype(jnp.int32)
