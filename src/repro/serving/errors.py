"""Typed failure hierarchy for the serving stack.

Every fault the runtime can survive is a :class:`ServingError` subclass, so
the front-end's exception boundary can classify with ``isinstance`` instead
of string-matching, and callers outside the boundary (tests, operators) get
a stable contract for what each failure means:

* :class:`ReplicaError` — a replica step raised.  ``fatal`` distinguishes
  crashes (fail the replica immediately) from transient blips (count them
  against the consecutive-error watchdog and retry in place).
* :class:`StepTimeout` — a megastep exceeded the watchdog budget.  Always
  fatal: the replica is wedged from the router's point of view even if the
  thread eventually returns.
* :class:`NumericalFault` — the verifier produced non-finite logits.  Fatal
  by construction: the committed caches may hold garbage past the last
  delivered token, so the only safe recovery is evacuate-and-replay.  The
  engine attaches the post-step ``state`` so the server can reassign its
  donated buffers before the boundary unwinds.
* :class:`PoolExhausted` — the paged KV pool has no free page.  Transient:
  the server parks admissions and the prefill lane until pages free up; the
  attached pool stats let the operator tell "too many slots" from "prefix
  store hoarding".
* :class:`NoReplicaAvailable` — routing found no ACTIVE replica.  The
  front-end queues-and-waits up to a configured bound before shedding with
  this as the typed reason.

This module must stay stdlib-only: ``models/cache.py`` and
``core/engine.py`` import it lazily at raise sites, below the serving
package in the import graph.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence


class ServingError(Exception):
    """Base class for every recoverable serving-stack failure."""


class ReplicaError(ServingError):
    """A replica's step raised.  ``fatal=False`` marks a transient blip."""

    def __init__(self, msg: str, *, fatal: bool = True):
        super().__init__(msg)
        self.fatal = bool(fatal)


class StepTimeout(ReplicaError):
    """A megastep exceeded the watchdog budget (always fatal)."""

    def __init__(self, msg: str, *, timeout_s: float = 0.0):
        super().__init__(msg, fatal=True)
        self.timeout_s = float(timeout_s)


class NumericalFault(ReplicaError):
    """Non-finite verifier logits.  Carries the post-step engine state so the
    server can reassign its donated cache buffers before re-raising."""

    def __init__(self, msg: str, *, state: Any = None,
                 slots: Sequence[int] = ()):
        super().__init__(msg, fatal=True)
        self.state = state
        self.slots = tuple(int(s) for s in slots)


class PoolExhausted(ServingError):
    """The paged KV pool has no free page.  Attaches pool stats so the park
    path and the operator can tell apart the two exhaustion modes."""

    def __init__(self, *, n_pages: int, pages_in_use: int, prefix_pages: int,
                 peak_pages: int, detail: str = ""):
        self.n_pages = int(n_pages)
        self.pages_in_use = int(pages_in_use)
        self.prefix_pages = int(prefix_pages)
        self.peak_pages = int(peak_pages)
        # more than half the busy pages pinned by the prefix store points at
        # hoarding; otherwise the pool is simply oversubscribed by live slots
        if self.prefix_pages * 2 > self.pages_in_use:
            why = (f"prefix store hoarding ({self.prefix_pages} refcounted "
                   f"prefix pages) — lower prefix retention or raise "
                   f"cache_pages")
        else:
            why = (f"too many slots for the pool — raise cache_pages or "
                   f"lower concurrency")
        msg = (f"page pool exhausted ({self.n_pages} pages, "
               f"{self.pages_in_use} in use, peak {self.peak_pages}): {why}")
        if detail:
            msg = f"{msg} [{detail}]"
        super().__init__(msg)


class NoReplicaAvailable(ServingError):
    """Routing found no ACTIVE replica to place a request on."""

    def __init__(self, msg: str = "no active replica to route to",
                 *, waited_s: Optional[float] = None):
        if waited_s is not None:
            msg = f"{msg} (waited {waited_s:.3g}s)"
        super().__init__(msg)
        self.waited_s = waited_s
