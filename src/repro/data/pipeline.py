"""Data pipeline: synthetic corpora with learnable structure + batching.

The speculative-decoding experiments need a *drafter that aligns with the
verifier* — on real hardware that's llama-68m vs llama-2-7b trained on the
same web data. Offline we reproduce the phenomenon by generating text from a
ground-truth low-order Markov source; both models learn it, small model
faster, so acceptance rates become realistic (and tunable via source entropy).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass
class MarkovSource:
    """Order-1 Markov chain over `vocab` symbols with controllable entropy.

    concentration -> 0 gives near-deterministic transitions (high drafter/
    verifier agreement, high AAL); large concentration -> uniform (low AAL).
    """
    vocab: int = 256
    concentration: float = 0.05
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        alpha = np.full(self.vocab, self.concentration)
        self.trans = rng.dirichlet(alpha, size=self.vocab)  # [V, V]
        self.init = rng.dirichlet(alpha)

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty(length, np.int32)
        out[0] = rng.choice(self.vocab, p=self.init)
        for t in range(1, length):
            out[t] = rng.choice(self.vocab, p=self.trans[out[t - 1]])
        return out

    def sample_fast(self, rng: np.random.Generator, batch: int,
                    length: int) -> np.ndarray:
        """Vectorized over the batch via inverse-CDF sampling."""
        cdf = np.cumsum(self.trans, axis=1)
        out = np.empty((batch, length), np.int32)
        u0 = rng.random(batch)
        out[:, 0] = np.searchsorted(np.cumsum(self.init), u0)
        for t in range(1, length):
            u = rng.random(batch)
            rows = cdf[out[:, t - 1]]
            out[:, t] = (rows < u[:, None]).sum(axis=1)
        np.clip(out, 0, self.vocab - 1, out=out)
        return out


@dataclass
class DataConfig:
    vocab: int = 256
    seq_len: int = 128
    batch: int = 16
    concentration: float = 0.05
    seed: int = 0


def batches(cfg: DataConfig, steps: int) -> Iterator[Dict[str, np.ndarray]]:
    src = MarkovSource(cfg.vocab, cfg.concentration, cfg.seed)
    rng = np.random.default_rng(cfg.seed + 1)
    for _ in range(steps):
        toks = src.sample_fast(rng, cfg.batch, cfg.seq_len)
        yield {"tokens": toks}


def prompts(cfg: DataConfig, n: int, prompt_len: int,
            seed: int = 1234) -> np.ndarray:
    src = MarkovSource(cfg.vocab, cfg.concentration, cfg.seed)
    rng = np.random.default_rng(seed)
    return src.sample_fast(rng, n, prompt_len)
