"""Byte-level tokenizer (no external vocab files needed offline)."""
from __future__ import annotations


import numpy as np

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
BYTE_OFFSET = 3


class ByteTokenizer:
    """ids = raw bytes + 3 specials. vocab_size = 259 (pad to model vocab)."""

    vocab_size = 256 + BYTE_OFFSET

    def encode(self, text: str, add_bos: bool = True) -> np.ndarray:
        ids = [BOS_ID] if add_bos else []
        ids += [b + BYTE_OFFSET for b in text.encode("utf-8")]
        return np.array(ids, np.int32)

    def decode(self, ids) -> str:
        bs = bytes(int(i) - BYTE_OFFSET for i in ids
                   if int(i) >= BYTE_OFFSET)
        return bs.decode("utf-8", errors="replace")
