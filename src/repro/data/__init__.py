from repro.data.pipeline import DataConfig, MarkovSource, batches, prompts
from repro.data.tokenizer import ByteTokenizer
