"""repro: Yggdrasil (latency-optimal tree speculative decoding) in JAX."""
__version__ = "0.1.0"
