"""Unified model API.

``Model`` wraps a ModelConfig with pure functions:

    init(key)                      -> params
    hidden_train(params, tokens)   -> (h, aux)            # full causal
    logits(params, h)              -> vocab logits
    encode(params, feats)          -> encoder states       (enc-dec only)
    prefill(params, tokens, lengths, cache, enc_feats)
                                   -> (last_logits, cache)
    decode(params, token, cache)   -> (logits, cache)      # commits 1 token
    tree_verify(params, tree, cache)
                                   -> (logits [B,W,V], scratch)
    commit(cache, scratch, node_idx, accept_len, tokens)   -> cache

All functions are jit-compatible; shapes are static given (batch, seq, W).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import cache as cache_lib
from repro.models import transformer
from repro.models.layers import (apply_lm_head, apply_norm, embed_defs,
                                 embed_tokens, lm_head_defs, norm_defs,
                                 rope_frequencies)
from repro.models.params import ParamDef, abstract_params, init_params, stacked


def _with_blocks(cache: Dict, new_blocks, length) -> Dict:
    """Rebuild a cache dict around new blocks/length, preserving the page
    table (a paged cache's table leaf rides through every executable
    unchanged — only the host allocator rewrites it)."""
    out = {"blocks": new_blocks, "length": length}
    if "table" in cache:
        out["table"] = cache["table"]
    return out


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.kv = cache_lib.make_kv_cache(cfg)

    # ------------------------------------------------------------ params --
    def param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        defs: Dict[str, Any] = {
            "embed": embed_defs(cfg),
            "blocks": stacked(transformer.block_defs(cfg), cfg.num_blocks),
            "final_norm": norm_defs(cfg),
        }
        head = lm_head_defs(cfg)
        if head:
            defs["lm_head"] = head
        if cfg.is_encoder_decoder:
            defs["enc_blocks"] = stacked(
                transformer.block_defs(cfg, encoder=True), cfg.num_encoder_layers)
            defs["enc_norm"] = norm_defs(cfg)
            if cfg.pos_embedding == "learned":
                defs["enc_pos"] = {
                    "pos": ParamDef((cfg.encoder_seq_len, cfg.d_model), (None, None))}
        return defs

    def init(self, key: jax.Array, dtype=jnp.float32):
        return init_params(self.param_defs(), key, dtype)

    def abstract(self, dtype=jnp.float32):
        return abstract_params(self.param_defs(), dtype)

    def _inv_freq(self):
        return (rope_frequencies(self.cfg)
                if self.cfg.pos_embedding == "rope" else None)

    # ------------------------------------------------------------- trunk --
    def _run_blocks(self, params, h, mode: str, ctx: Dict,
                    cache: Optional[Dict] = None):
        cfg = self.cfg

        def body(carry, xs):
            h, aux = carry
            bp, cb = xs
            h, new_cb, scratch, a = transformer.apply_block(
                bp, h, cfg, mode, ctx, cb)
            return (h, aux + a), (new_cb, scratch)

        if cfg.remat and mode == "train":
            body = jax.checkpoint(body)

        cache_blocks = None if cache is None else cache["blocks"]
        (h, aux), (new_blocks, scratch) = jax.lax.scan(
            body, (h, jnp.zeros((), jnp.float32)),
            (params["blocks"], cache_blocks),
            unroll=cfg.num_blocks if cfg.scan_unroll else 1)
        h = apply_norm(params["final_norm"], h, cfg)
        return h, aux, new_blocks, scratch

    def logits(self, params, h: jax.Array) -> jax.Array:
        return apply_lm_head(params, h, self.cfg)

    # ------------------------------------------------------------- train --
    def hidden_train(self, params, tokens: jax.Array,
                     seq_valid: Optional[jax.Array] = None,
                     enc_feats: Optional[jax.Array] = None,
                     moe_dropless: bool = False):
        """Full causal forward. ``moe_dropless=True`` disables MoE capacity
        dropping, making this an exact reference for the inference paths."""
        cfg = self.cfg
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        h = embed_tokens(params["embed"], tokens, cfg, positions)
        ctx = {"positions": positions, "inv_freq": self._inv_freq(),
               "seq_valid": seq_valid, "moe_dropless": moe_dropless}
        if cfg.is_encoder_decoder:
            enc_out = self.encode(params, enc_feats)
            ctx["enc_out"] = enc_out
            # training with cross attention needs ck/cv; reuse prefill path by
            # treating train as prefill-with-full-cache-less cross attention:
            # we inline cross K/V per block via ctx (computed inside block).
            return self._hidden_train_encdec(params, h, ctx)
        h, aux, _, _ = self._run_blocks(params, h, "train", ctx)
        return h, aux

    def _hidden_train_encdec(self, params, h, ctx):
        """Enc-dec training: per-block cross K/V computed on the fly."""
        cfg = self.cfg
        from repro.models import attention as attn_mod
        from repro.models.layers import apply_norm as _an

        def body(carry, bp):
            h, aux = carry
            lp = bp["layer0"]
            x = _an(lp["mixer_norm"], h, cfg)
            out, _, _ = attn_mod.attention_layer(
                lp["attn"], x, cfg, mode="train",
                positions=ctx["positions"], inv_freq=ctx.get("inv_freq"),
                seq_valid=ctx.get("seq_valid"))
            h = h + out
            ck, cv = attn_mod.encode_cross_kv(lp["cross"], ctx["enc_out"], cfg)
            xc = _an(lp["cross_norm"], h, cfg)
            h = h + attn_mod.cross_attention_layer(
                lp["cross"], xc, cfg, {"ck": ck, "cv": cv})
            x = _an(lp["ffn_norm"], h, cfg)
            from repro.models.layers import apply_mlp
            h = h + apply_mlp(lp["mlp"], x, cfg)
            return (h, aux), None

        if cfg.remat:
            body = jax.checkpoint(body)
        (h, aux), _ = jax.lax.scan(
            body, (h, jnp.zeros((), jnp.float32)), params["blocks"],
            unroll=cfg.num_blocks if cfg.scan_unroll else 1)
        return apply_norm(params["final_norm"], h, cfg), aux

    # ------------------------------------------------------------ encode --
    def encode(self, params, feats: jax.Array) -> jax.Array:
        """feats: [B, T, d] precomputed frontend embeddings (stub carve-out)."""
        cfg = self.cfg
        h = feats + params["enc_pos"]["pos"][None] if "enc_pos" in params else feats
        B, T = h.shape[:2]
        ctx = {"positions": jnp.broadcast_to(jnp.arange(T)[None], (B, T)),
               "inv_freq": None}

        def body(carry, bp):
            h, = carry
            h, _, _, _ = transformer.apply_block(bp, h, cfg, "encode", ctx,
                                                 encoder=True)
            return (h,), None

        (h,), _ = jax.lax.scan(
            body, (h,), params["enc_blocks"],
            unroll=cfg.num_encoder_layers if cfg.scan_unroll else 1)
        return apply_norm(params["enc_norm"], h, cfg)

    # ----------------------------------------------------------- prefill --
    def prefill(self, params, tokens: jax.Array, lengths: jax.Array,
                cache: Dict, enc_feats: Optional[jax.Array] = None):
        """tokens: [B, S] right-padded prompts; lengths: [B].

        Returns (last_logits [B, V], cache) where last_logits is the
        distribution after each prompt's final token.
        """
        cfg = self.cfg
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        seq_valid = positions < lengths[:, None]
        h = embed_tokens(params["embed"], tokens, cfg, positions)
        ctx = {"positions": positions, "inv_freq": self._inv_freq(),
               "seq_valid": seq_valid, "lengths": lengths,
               "table": cache.get("table")}
        if cfg.is_encoder_decoder:
            ctx["enc_out"] = self.encode(params, enc_feats)
        h, aux, new_blocks, _ = self._run_blocks(params, h, "prefill", ctx, cache)
        # hidden state of each prompt's last token
        idx = jnp.clip(lengths - 1, 0, S - 1)
        h_last = jnp.take_along_axis(h, idx[:, None, None].repeat(h.shape[-1], -1),
                                     axis=1)[:, 0]
        logits = self.logits(params, h_last)
        # `+ 0` forces a fresh buffer so donating the cache later can never
        # invalidate the caller's `lengths` array
        new_cache = _with_blocks(cache, new_blocks,
                                 lengths.astype(jnp.int32) + 0)
        return logits, new_cache, h_last

    # ------------------------------------------------------------ decode --
    def decode(self, params, token: jax.Array, cache: Dict):
        """token: [B] confirmed next token. Commits it and returns logits."""
        cfg = self.cfg
        lengths = cache["length"]
        positions = lengths[:, None]  # [B, 1]
        h = embed_tokens(params["embed"], token[:, None], cfg, positions)
        ctx = {"positions": positions, "inv_freq": self._inv_freq(),
               "lengths": lengths, "table": cache.get("table")}
        h, aux, new_blocks, _ = self._run_blocks(params, h, "decode", ctx, cache)
        logits = self.logits(params, h[:, 0])
        new_cache = _with_blocks(cache, new_blocks, lengths + 1)
        return logits, new_cache, h[:, 0]

    # ------------------------------------------------------- tree verify --
    def tree_verify(self, params, tree_tokens: jax.Array, depths: jax.Array,
                    tree_mask: jax.Array, cache: Dict,
                    tree_paths: Optional[jax.Array] = None):
        """tree_tokens: [B, W]; depths: [B, W] (root depth 0); tree_mask:
        [B, W, W] ancestor-or-self; tree_paths: [B, W, Dmax] for SSM layers.

        Returns (logits [B, W, V], scratch, hidden [B, W, d]); cache is NOT
        mutated — call ``commit`` with the acceptance result.
        """
        cfg = self.cfg
        lengths = cache["length"]
        positions = lengths[:, None] + depths  # [B, W]
        h = embed_tokens(params["embed"], tree_tokens, cfg, positions)
        ctx = {"positions": positions, "inv_freq": self._inv_freq(),
               "lengths": lengths, "tree_mask": tree_mask,
               "tree_paths": tree_paths, "table": cache.get("table")}
        h, aux, _, scratch = self._run_blocks(params, h, "tree", ctx, cache)
        logits = self.logits(params, h)
        return logits, scratch, h

    # ----------------------------------------------- drafter tree growth --
    def init_tree_scratch(self, batch: int, n: int, dtype=jnp.float32):
        """Per-layer K/V buffers for N in-flight tree nodes (drafter side)."""
        cfg = self.cfg
        assert all(cfg.layer_mixer(i) == "attn" for i in range(cfg.num_layers)), \
            "tree_extend drafting requires an attention drafter (see DESIGN.md)"
        kv, dh = cfg.num_kv_heads, cfg.head_dim
        proto = {f"layer{j}": {
            "k": jnp.zeros((batch, n, kv, dh), dtype),
            "v": jnp.zeros((batch, n, kv, dh), dtype)}
            for j in range(cfg.layers_per_block)}
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_blocks,) + a.shape), proto)

    def tree_extend(self, params, new_tokens: jax.Array, depths_new: jax.Array,
                    ext_mask: jax.Array, scratch, offset: int, cache: Dict):
        """Process Q new tree nodes on the drafter.

        new_tokens: [B, Q]; depths_new: [B, Q]; ext_mask: [B, Q, N] visibility
        over ALL N scratch slots (ancestors only); offset: static write slot.
        Returns (logits [B, Q, V], new_scratch).
        """
        cfg = self.cfg
        lengths = cache["length"]
        table = cache.get("table")
        positions = lengths[:, None] + depths_new
        h = embed_tokens(params["embed"], new_tokens, cfg, positions)
        inv_freq = self._inv_freq()

        from repro.models import attention as attn_mod
        from repro.models.layers import apply_mlp

        def body(carry, xs):
            h, = carry
            bp, cb, sb = xs
            new_sb = {}
            for j in range(cfg.layers_per_block):
                lp, entry, sc = bp[f"layer{j}"], cb[f"layer{j}"], sb[f"layer{j}"]
                x = apply_norm(lp["mixer_norm"], h, cfg)
                out, sk, sv = attn_mod.attention_tree_extend(
                    lp["attn"], x, cfg, positions=positions, inv_freq=inv_freq,
                    cache_entry=entry, lengths=lengths,
                    scratch_k=sc["k"], scratch_v=sc["v"], offset=offset,
                    ext_mask=ext_mask, table=table)
                h = h + out
                new_sb[f"layer{j}"] = {"k": sk, "v": sv}
                if "mlp" in lp:
                    x = apply_norm(lp["ffn_norm"], h, cfg)
                    h = h + apply_mlp(lp["mlp"], x, cfg)
                elif "moe" in lp:
                    from repro.models import moe as moe_mod
                    x = apply_norm(lp["ffn_norm"], h, cfg)
                    mo, _ = moe_mod.apply_moe(lp["moe"], x, cfg, dropless=True)
                    h = h + mo
            return (h,), new_sb

        (h,), new_scratch = jax.lax.scan(
            body, (h,), (params["blocks"], cache["blocks"], scratch))
        h = apply_norm(params["final_norm"], h, cfg)
        return self.logits(params, h), new_scratch

    def commit_scratch(self, cache: Dict, scratch, node_idx: jax.Array,
                       accept_len: jax.Array) -> Dict:
        """Commit accepted tree nodes from a drafter tree scratch (full-N
        buffers) into the drafter's cache."""
        cfg = self.cfg
        lengths = cache["length"]
        table = cache.get("table")

        def per_block(cb, sb):
            return {f"layer{j}": self.kv.commit_region(
                cb[f"layer{j}"], sb[f"layer{j}"]["k"], sb[f"layer{j}"]["v"],
                node_idx, lengths, accept_len, table=table)
                for j in range(cfg.layers_per_block)}

        new_blocks = jax.vmap(per_block)(cache["blocks"], scratch)
        return _with_blocks(cache, new_blocks, lengths + accept_len)

    # ------------------------------------------------------------ commit --
    def commit(self, cache: Dict, scratch: Dict, node_idx: jax.Array,
               accept_len: jax.Array) -> Dict:
        """Write accepted tree nodes into the cache.

        node_idx: [B, A_max] tree-node index of the j-th accepted token;
        accept_len: [B] number of accepted nodes (>= 1: root always accepted).
        """
        cfg = self.cfg
        lengths = cache["length"]
        table = cache.get("table")
        B = node_idx.shape[0]
        b_idx = jnp.arange(B)

        def per_block(cb, sb):
            new_cb = {}
            for j in range(cfg.layers_per_block):
                key = f"layer{j}"
                entry, sc = cb[key], (sb or {}).get(key)
                if sc is None:
                    new_cb[key] = entry
                elif "k" in sc:  # attention layer
                    new_cb[key] = self.kv.commit_region(
                        entry, sc["k"], sc["v"], node_idx, lengths,
                        accept_len, table=table)
                else:            # ssm layer: adopt last accepted node's state
                    last = node_idx[b_idx, jnp.maximum(accept_len - 1, 0)]
                    new_state = sc["node_states"][b_idx, last]
                    new_conv = sc["node_conv"][b_idx, last]
                    keep = (accept_len > 0)[:, None]
                    new_cb[key] = {
                        "state": jnp.where(keep[..., None, None],
                                           new_state, entry["state"]),
                        "conv": jnp.where(
                            keep[..., None],
                            new_conv.astype(entry["conv"].dtype), entry["conv"]),
                    }
            return new_cb

        new_blocks = jax.vmap(per_block)(cache["blocks"], scratch)
        return _with_blocks(cache, new_blocks, lengths + accept_len)


@functools.lru_cache(maxsize=64)
def get_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
