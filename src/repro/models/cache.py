"""Static-shape decode caches.

The cache is a plain pytree so it can be jit-carried, donated and sharded.

Layout (per attention layer, stacked over scan blocks):
    k, v : [num_blocks, B, S_cache, KV, Dh]   (seq dim sharded over `model`)
    pos  : [num_blocks, B, S_cache] int32     absolute position held in the
                                              slot, -1 if empty
Per SSM layer:
    state: [num_blocks, B, H, P, N] float32
    conv : [num_blocks, B, W-1, conv_dim]
Global:
    length: [B] int32  committed tokens per request

Sliding-window archs use a ring buffer: S_cache == window and slots are
addressed ``pos % window``; full-attention archs use S_cache == max target
length with slot == pos. Both cases are handled by `slot_for`.

Quantized caches (``init_cache(..., kv_dtype=jnp.int8)``) store the K/V
payload as int8 with per-slot, per-head fp32 absmax scales alongside
(sub-grouped along the head dim, G = head_dim/KV_GROUP scales per head):
    k_scale, v_scale : [num_blocks, B, S_cache, KV, G]
Tokens are quantized once at write time (`write_tokens`/`commit_region`)
and dequantized at read time (`entry_kv`), so a committed token always
dequantizes to the same values — the per-slot ops (`slot_update`,
`slot_slice`, `reset_slot`) move/clear payload and scales together and the
round-trip is exact. Cross-attention K/V (ck/cv) stays at the cache dtype:
it is written once per request and read every step, so quantizing it saves
little and would touch the encoder path.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.quant.kv import dequant_kv, kv_scale_groups, quantize_kv
from repro.sharding import shard, sharding_for

Cache = Dict[str, Any]


def cache_seq_len(cfg: ModelConfig, target_len: int) -> int:
    if cfg.sliding_window:
        return min(cfg.sliding_window, target_len)
    return target_len


def _attn_entry(cfg: ModelConfig, batch: int, s_cache: int, dtype,
                kv_dtype=None) -> Dict:
    kv, dh = cfg.num_kv_heads, cfg.head_dim
    if kv_dtype is not None and jnp.dtype(kv_dtype) == jnp.int8:
        g = kv_scale_groups(dh)
        return {
            "k": jnp.zeros((batch, s_cache, kv, dh), jnp.int8),
            "v": jnp.zeros((batch, s_cache, kv, dh), jnp.int8),
            # neutral scale: an empty slot dequantizes to exact zeros
            "k_scale": jnp.ones((batch, s_cache, kv, g), jnp.float32),
            "v_scale": jnp.ones((batch, s_cache, kv, g), jnp.float32),
            "pos": jnp.full((batch, s_cache), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, s_cache, kv, dh), dtype),
        "v": jnp.zeros((batch, s_cache, kv, dh), dtype),
        "pos": jnp.full((batch, s_cache), -1, jnp.int32),
    }


def _attn_entry_abstract(cfg: ModelConfig, batch: int, s_cache: int, dtype,
                         kv_dtype=None) -> Dict:
    kv, dh = cfg.num_kv_heads, cfg.head_dim
    if kv_dtype is not None and jnp.dtype(kv_dtype) == jnp.int8:
        g = kv_scale_groups(dh)
        return {
            "k": jax.ShapeDtypeStruct((batch, s_cache, kv, dh), jnp.int8),
            "v": jax.ShapeDtypeStruct((batch, s_cache, kv, dh), jnp.int8),
            "k_scale": jax.ShapeDtypeStruct((batch, s_cache, kv, g), jnp.float32),
            "v_scale": jax.ShapeDtypeStruct((batch, s_cache, kv, g), jnp.float32),
            "pos": jax.ShapeDtypeStruct((batch, s_cache), jnp.int32),
        }
    return {
        "k": jax.ShapeDtypeStruct((batch, s_cache, kv, dh), dtype),
        "v": jax.ShapeDtypeStruct((batch, s_cache, kv, dh), dtype),
        "pos": jax.ShapeDtypeStruct((batch, s_cache), jnp.int32),
    }


def _ssm_entry(cfg: ModelConfig, batch: int, dtype) -> Dict:
    h, p, n = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state_size
    conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_num_groups * cfg.ssm_state_size
    return {
        "state": jnp.zeros((batch, h, p, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
    }


def _ssm_entry_abstract(cfg: ModelConfig, batch: int, dtype) -> Dict:
    h, p, n = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state_size
    conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_num_groups * cfg.ssm_state_size
    return {
        "state": jax.ShapeDtypeStruct((batch, h, p, n), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
    }


def _cross_entry(cfg: ModelConfig, batch: int, dtype, abstract: bool) -> Dict:
    kv, dh, t = cfg.num_kv_heads, cfg.head_dim, cfg.encoder_seq_len
    mk = (lambda s, dt: jax.ShapeDtypeStruct(s, dt)) if abstract else (
        lambda s, dt: jnp.zeros(s, dt))
    return {"ck": mk((batch, t, kv, dh), dtype), "cv": mk((batch, t, kv, dh), dtype)}


def init_cache(cfg: ModelConfig, batch: int, target_len: int,
               dtype=jnp.float32, abstract: bool = False,
               kv_dtype=None) -> Cache:
    """Build the full cache pytree (stacked over scan blocks).

    ``kv_dtype=jnp.int8`` stores attention K/V as int8 with per-slot,
    per-head fp32 scales (see module docstring); None keeps ``dtype``.
    """
    s_cache = cache_seq_len(cfg, target_len)
    lpb, nb = cfg.layers_per_block, cfg.num_blocks

    def block_entry(block_idx: int) -> Dict:
        entry = {}
        for j in range(lpb):
            i = block_idx * lpb + j
            if cfg.layer_mixer(i) == "attn":
                e = (_attn_entry_abstract if abstract else _attn_entry)(
                    cfg, batch, s_cache, dtype, kv_dtype=kv_dtype)
                if cfg.is_encoder_decoder:
                    e.update(_cross_entry(cfg, batch, dtype, abstract))
            else:
                e = (_ssm_entry_abstract if abstract else _ssm_entry)(cfg, batch, dtype)
            entry[f"layer{j}"] = e
        return entry

    # every block has identical structure (period == layers_per_block), so
    # stack block 0's structure nb times
    proto = block_entry(0)
    if abstract:
        blocks = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((nb,) + s.shape, s.dtype), proto)
    else:
        blocks = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (nb,) + a.shape), proto)
        blocks = jax.tree.map(jnp.array, blocks)  # materialize

    length = (jax.ShapeDtypeStruct((batch,), jnp.int32) if abstract
              else jnp.zeros((batch,), jnp.int32))
    return {"blocks": blocks, "length": length}


def _leaf_axes(path: Tuple, leaf) -> Tuple:
    leafname = getattr(path[-1], "key", str(path[-1]))
    if leafname in ("k", "v", "ck", "cv"):
        return ("layers", "batch", "cache_seq", "kv_heads", "head_dim_shard")[-leaf.ndim:]
    if leafname in ("k_scale", "v_scale"):
        # scales shard with their payload's batch/seq/head axes so a mesh
        # keeps each int8 tile and its scales on the same device (the
        # trailing scale-group axis stays unsharded)
        return ("layers", "batch", "cache_seq", "kv_heads", None)[-leaf.ndim:]
    if leafname == "pos":
        return ("layers", "batch", "cache_seq")[-leaf.ndim:]
    if leafname == "state":
        return ("layers", "batch", "ssm_heads", None, None)[-leaf.ndim:]
    if leafname == "conv":
        return ("layers", "batch", None, "ssm_inner")[-leaf.ndim:]
    if leafname == "length":
        return ("batch",)
    raise ValueError(leafname)


def cache_logical_axes(cache: Cache):
    """(path, axes) pairs for every cache leaf — used for jit shardings."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: _leaf_axes(p, x), cache,
        is_leaf=lambda x: hasattr(x, "ndim") and not isinstance(x, dict))


def shard_cache(cache: Cache) -> Cache:
    """Apply sharding constraints to every cache leaf."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: shard(x, *_leaf_axes(p, x)), cache)


def cache_shardings(cache: Cache, mesh=None) -> Cache:
    """NamedSharding pytree for a (concrete or abstract) cache — the eager
    counterpart of `shard_cache`, for `jax.device_put` placement of a
    host-built cache and for explicit jit in/out shardings."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: sharding_for(_leaf_axes(p, x), x.shape, mesh), cache,
        is_leaf=lambda x: hasattr(x, "ndim") and not isinstance(x, dict))


def place_cache(cache: Cache, mesh=None) -> Cache:
    """Device-put every cache leaf onto its logical-axis sharding. No-op
    without a mesh (active or passed)."""
    shardings = cache_shardings(cache, mesh)
    if all(s is None for s in jax.tree.leaves(
            shardings, is_leaf=lambda x: x is None)):
        return cache
    return jax.tree.map(jax.device_put, cache, shardings,
                        is_leaf=lambda x: x is None)


# ------------------------------------------------- per-slot management ----
# Continuous batching refills one batch slot while the others keep decoding.
# Every leaf's batch axis is recovered from `_leaf_axes`, so these work for
# attention, SSM, cross-attention and `length` leaves alike, and stay
# jit-compatible with a *traced* slot index (one compiled executable serves
# every slot).

def batch_axis(path: Tuple, leaf) -> int:
    """Index of the batch axis for a cache leaf at `path`."""
    return _leaf_axes(path, leaf).index("batch")


def slot_slice(cache: Cache, slot) -> Cache:
    """Extract batch slot `slot` as a batch-1 cache (same structure)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: jax.lax.dynamic_slice_in_dim(
            x, slot, 1, axis=batch_axis(p, x)), cache)


def slot_update(cache: Cache, slot, slot_cache: Cache) -> Cache:
    """Overwrite batch slot `slot` of `cache` with the content of the
    batch-1 `slot_cache`, leaving every other slot untouched."""

    def upd(path, big, small):
        ax = batch_axis(path, big)
        return jax.lax.dynamic_update_index_in_dim(
            big, jnp.take(small, 0, axis=ax).astype(big.dtype), slot, axis=ax)

    return jax.tree_util.tree_map_with_path(upd, cache, slot_cache)


def reset_slot(cache: Cache, slot) -> Cache:
    """Clear batch slot `slot`: committed length -> 0, positions -> -1 (so
    `visible_mask` hides every stale entry), SSM state/conv -> 0. Floating
    K/V payloads are left in place — unreachable once pos/length are
    cleared — but the fill is per-leaf, not one shared value: int8 K/V
    payloads reset to 0 and their scales to 1.0 (the empty-slot neutral
    pair), never 0-scales, which would survive as a degenerate dequant if a
    later write were ever partial."""

    def upd(path, leaf):
        name = getattr(path[-1], "key", str(path[-1]))
        ax = batch_axis(path, leaf)
        if name in ("k", "v", "ck", "cv"):
            if not jnp.issubdtype(leaf.dtype, jnp.integer):
                return leaf
            fill = 0                       # int8 payload back to empty
        elif name == "pos":
            fill = -1
        elif name in ("k_scale", "v_scale"):
            fill = 1.0                     # neutral scale, NOT 0
        else:
            fill = 0
        row_shape = leaf.shape[:ax] + leaf.shape[ax + 1:]
        row = jnp.full(row_shape, fill, leaf.dtype)
        return jax.lax.dynamic_update_index_in_dim(leaf, row, slot, axis=ax)

    return jax.tree_util.tree_map_with_path(upd, cache)


def slot_for(pos: jax.Array, s_cache: int, sliding_window: int) -> jax.Array:
    """Map absolute positions to cache slots (ring buffer under SWA)."""
    if sliding_window:
        return pos % s_cache
    return pos


def is_quantized_entry(entry: Dict) -> bool:
    """True when an attention cache entry holds int8 K/V + scales."""
    return "k_scale" in entry


def entry_kv(entry: Dict) -> Tuple[jax.Array, jax.Array]:
    """The entry's K/V at compute precision — dequantized fp32 views for an
    int8 entry, the stored arrays otherwise."""
    if is_quantized_entry(entry):
        return (dequant_kv(entry["k"], entry["k_scale"]),
                dequant_kv(entry["v"], entry["v_scale"]))
    return entry["k"], entry["v"]


def entry_kernel_kv(entry: Dict):
    """The entry's K/V in the fused verify kernel's contract: the raw
    **un-repeated** ``[B, S_cache, KV, Dh]`` arrays exactly as stored —
    still int8 for a quantized entry, with their fp32 scale groups
    alongside (``(k, v, k_scale, v_scale)``; scales are None for fp).

    The kernel dequantizes tiles in VMEM and repeats nothing, so handing it
    the storage layout directly is what keeps the verify megastep's HBM
    traffic at the cache's true byte size (no materialized fp32 copy, no
    ``repeat_kv`` G× blow-up)."""
    return (entry["k"], entry["v"],
            entry.get("k_scale"), entry.get("v_scale"))


def write_tokens(entry: Dict, k_new: jax.Array, v_new: jax.Array,
                 positions: jax.Array, cfg: ModelConfig,
                 valid: Optional[jax.Array] = None) -> Dict:
    """Write S_new tokens into an attention cache entry.

    k_new/v_new: [B, S_new, KV, Dh]; positions: [B, S_new] absolute positions;
    valid: [B, S_new] bool (False entries are not written). On a quantized
    entry the tokens are quantized here — the single rounding point — and
    payload + scales are scattered to the same slots.
    """
    s_cache = entry["k"].shape[1]
    slots = slot_for(positions, s_cache, cfg.sliding_window)  # [B, S_new]
    if valid is None:
        valid = positions >= 0
    # scatter along the slot axis; invalid entries routed to slot 0 with
    # a no-op via where-merge below would clobber — instead route invalid
    # writes to an out-of-range slot and rely on mode="drop".
    slots = jnp.where(valid, slots, s_cache)  # s_cache is out of range -> drop
    b_idx = jnp.arange(k_new.shape[0])[:, None]

    def scat(store, val):
        return store.at[b_idx, slots].set(val, mode="drop")

    out = dict(entry)  # preserves ck/cv (and anything future) untouched
    if is_quantized_entry(entry):
        qk, ks = quantize_kv(k_new)
        qv, vs = quantize_kv(v_new)
        out["k"] = scat(entry["k"], qk)
        out["v"] = scat(entry["v"], qv)
        out["k_scale"] = scat(entry["k_scale"], ks)
        out["v_scale"] = scat(entry["v_scale"], vs)
    else:
        out["k"] = scat(entry["k"], k_new)
        out["v"] = scat(entry["v"], v_new)
    out["pos"] = scat(entry["pos"], jnp.where(valid, positions, -1))
    return out


def commit_region(entry: Dict, k_nodes: jax.Array, v_nodes: jax.Array,
                  node_idx: jax.Array, lengths: jax.Array, accept_len: jax.Array,
                  cfg: ModelConfig) -> Dict:
    """Commit accepted tree nodes into the cache.

    k_nodes/v_nodes: [B, W, KV, Dh] tree-node K/V from verification;
    node_idx: [B, A_max] indices into the W tree nodes forming the accepted
    path (position j holds the node committed at lengths+j);
    accept_len: [B] number of accepted nodes.
    """
    b = k_nodes.shape[0]
    a_max = node_idx.shape[1]
    b_idx = jnp.arange(b)[:, None]
    gathered_k = k_nodes[b_idx, node_idx]  # [B, A_max, KV, Dh]
    gathered_v = v_nodes[b_idx, node_idx]
    positions = lengths[:, None] + jnp.arange(a_max)[None, :]
    valid = jnp.arange(a_max)[None, :] < accept_len[:, None]
    return write_tokens(entry, gathered_k, gathered_v, positions, cfg, valid=valid)


def visible_mask(entry_pos: jax.Array, q_pos: jax.Array, lengths: jax.Array,
                 sliding_window: int) -> jax.Array:
    """[B, S_q, S_cache] mask of committed slots visible to each query.

    entry_pos: [B, S_cache] absolute positions (-1 empty);
    q_pos: [B, S_q] query absolute positions; lengths: [B] committed length.
    """
    kp = entry_pos[:, None, :]
    qp = q_pos[:, :, None]
    m = (kp >= 0) & (kp < lengths[:, None, None]) & (kp < qp)
    if sliding_window:
        m &= kp > qp - sliding_window
    return m


# ----------------------------------------------------- byte accounting ----
def cache_nbytes(cfg: ModelConfig, batch: int, target_len: int,
                 dtype=jnp.float32, kv_dtype=None) -> int:
    """Device bytes one cache pytree holds (payload + scales + pos + SSM +
    length), computed on the abstract cache so no buffers materialize. This
    is what serving capacity accounting divides an HBM budget by."""
    c = init_cache(cfg, batch, target_len, dtype=dtype, abstract=True,
                   kv_dtype=kv_dtype)
    return int(sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
                   for x in jax.tree.leaves(c)))
