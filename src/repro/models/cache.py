"""Static-shape decode caches behind the ``KVCache`` interface.

Two layouts implement the same protocol, selected by
``ModelConfig.cache_layout`` (``make_kv_cache(cfg)`` returns the strategy):

**Contiguous** (``ContiguousCache``) — per attention layer, stacked over
scan blocks:
    k, v : [num_blocks, B, S_cache, KV, Dh]   (seq dim sharded over `model`)
    pos  : [num_blocks, B, S_cache] int32     absolute position held in the
                                              slot, -1 if empty
Per SSM layer:
    state: [num_blocks, B, H, P, N] float32
    conv : [num_blocks, B, W-1, conv_dim]
Global:
    length: [B] int32  committed tokens per request

Sliding-window archs use a ring buffer: S_cache == window and slots are
addressed ``pos % window``; full-attention archs use S_cache == max target
length with slot == pos.

**Paged** (``PagedCache``) — a fixed page pool plus a per-slot page table,
so HBM is priced by *live* tokens instead of ``max_target_len`` and
identical prompt prefixes are stored once:
    k, v : [num_blocks, n_pages, page_len, KV, Dh]
    pos  : [num_blocks, n_pages, page_len] int32  (-1 if empty)
Global:
    length: [B] int32
    table : [B, T] int32   T = max_target_len // page_len; row r of slot b
                           names the pool page backing virtual positions
                           [r*page_len, (r+1)*page_len)

Page 0 is the **trash page**: unmapped table rows point at it, so garbage
writes from parked or mid-prefill slots land there harmlessly, and reads of
unmapped rows are hidden by the visibility masks (the XLA oracle path
additionally applies an identity mask ``pos == virtual_index`` after the
gather). The invariant that makes recycling safe is *free pages are always
clean*: a page's ``pos`` lanes are -1 at pool init and are re-cleared (via
``clear_pages``) whenever its refcount drops to zero, before it can be
remapped. All shapes are static — a fixed pool and a fixed-width table —
so page churn never recompiles anything.

Cross-request prefix sharing is page-granular copy-on-write: ``PrefixStore``
keys *full* prompt pages by a chain hash, admission maps resident pages into
the new slot's table (refcounted, prefill skipped for those rows) and the
first divergent page stays private. Shared pages are never written because
writes only target positions at or beyond the committed length, which the
admission path pins past the shared rows.

Quantized caches (``kv_dtype=jnp.int8``) store the K/V payload as int8 with
per-slot, per-head fp32 absmax scales alongside (sub-grouped along the head
dim, G = head_dim/KV_GROUP scales per head). Tokens are quantized once at
write time (``write_tokens``/``commit_region``) and dequantized at read
time (``entry_kv``), so a committed token always dequantizes to the same
values in either layout. Cross-attention K/V (ck/cv) stays at the cache
dtype.
"""
from __future__ import annotations

from collections import OrderedDict
from functools import lru_cache
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.quant.kv import dequant_kv, kv_scale_groups, quantize_kv
from repro.sharding import shard, sharding_for

__all__ = [
    "Cache",
    "ContiguousCache",
    "KVCache",
    "PageState",
    "PagedCache",
    "PrefixStore",
    "cache_logical_axes",
    "cache_shardings",
    "make_kv_cache",
    "place_cache",
    "shard_cache",
    "visible_mask",
]

Cache = Dict[str, Any]

TRASH_PAGE = 0


def cache_seq_len(cfg: ModelConfig, target_len: int) -> int:
    if cfg.sliding_window:
        return min(cfg.sliding_window, target_len)
    return target_len


# ------------------------------------------------------- entry builders ----
def _attn_entry(cfg: ModelConfig, batch: int, s_cache: int, dtype,
                kv_dtype=None) -> Dict:
    kv, dh = cfg.num_kv_heads, cfg.head_dim
    if kv_dtype is not None and jnp.dtype(kv_dtype) == jnp.int8:
        g = kv_scale_groups(dh)
        return {
            "k": jnp.zeros((batch, s_cache, kv, dh), jnp.int8),
            "v": jnp.zeros((batch, s_cache, kv, dh), jnp.int8),
            # neutral scale: an empty slot dequantizes to exact zeros
            "k_scale": jnp.ones((batch, s_cache, kv, g), jnp.float32),
            "v_scale": jnp.ones((batch, s_cache, kv, g), jnp.float32),
            "pos": jnp.full((batch, s_cache), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, s_cache, kv, dh), dtype),
        "v": jnp.zeros((batch, s_cache, kv, dh), dtype),
        "pos": jnp.full((batch, s_cache), -1, jnp.int32),
    }


def _attn_entry_abstract(cfg: ModelConfig, batch: int, s_cache: int, dtype,
                         kv_dtype=None) -> Dict:
    kv, dh = cfg.num_kv_heads, cfg.head_dim
    if kv_dtype is not None and jnp.dtype(kv_dtype) == jnp.int8:
        g = kv_scale_groups(dh)
        return {
            "k": jax.ShapeDtypeStruct((batch, s_cache, kv, dh), jnp.int8),
            "v": jax.ShapeDtypeStruct((batch, s_cache, kv, dh), jnp.int8),
            "k_scale": jax.ShapeDtypeStruct((batch, s_cache, kv, g), jnp.float32),
            "v_scale": jax.ShapeDtypeStruct((batch, s_cache, kv, g), jnp.float32),
            "pos": jax.ShapeDtypeStruct((batch, s_cache), jnp.int32),
        }
    return {
        "k": jax.ShapeDtypeStruct((batch, s_cache, kv, dh), dtype),
        "v": jax.ShapeDtypeStruct((batch, s_cache, kv, dh), dtype),
        "pos": jax.ShapeDtypeStruct((batch, s_cache), jnp.int32),
    }


def _paged_attn_entry(cfg: ModelConfig, n_pages: int, page_len: int, dtype,
                      kv_dtype=None, abstract: bool = False) -> Dict:
    """One attention layer's slice of the page pool. ``pos`` starts at -1
    everywhere — the 'free pages are clean' invariant at birth."""
    kv, dh = cfg.num_kv_heads, cfg.head_dim
    if abstract:
        mk = lambda s, dt, fill: jax.ShapeDtypeStruct(s, dt)  # noqa: E731
    else:
        mk = lambda s, dt, fill: jnp.full(s, fill, dt)  # noqa: E731
    if kv_dtype is not None and jnp.dtype(kv_dtype) == jnp.int8:
        g = kv_scale_groups(dh)
        return {
            "k": mk((n_pages, page_len, kv, dh), jnp.int8, 0),
            "v": mk((n_pages, page_len, kv, dh), jnp.int8, 0),
            "k_scale": mk((n_pages, page_len, kv, g), jnp.float32, 1.0),
            "v_scale": mk((n_pages, page_len, kv, g), jnp.float32, 1.0),
            "pos": mk((n_pages, page_len), jnp.int32, -1),
        }
    return {
        "k": mk((n_pages, page_len, kv, dh), dtype, 0),
        "v": mk((n_pages, page_len, kv, dh), dtype, 0),
        "pos": mk((n_pages, page_len), jnp.int32, -1),
    }


def _ssm_entry(cfg: ModelConfig, batch: int, dtype) -> Dict:
    h, p, n = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state_size
    conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_num_groups * cfg.ssm_state_size
    return {
        "state": jnp.zeros((batch, h, p, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
    }


def _ssm_entry_abstract(cfg: ModelConfig, batch: int, dtype) -> Dict:
    h, p, n = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state_size
    conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_num_groups * cfg.ssm_state_size
    return {
        "state": jax.ShapeDtypeStruct((batch, h, p, n), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
    }


def _cross_entry(cfg: ModelConfig, batch: int, dtype, abstract: bool) -> Dict:
    kv, dh, t = cfg.num_kv_heads, cfg.head_dim, cfg.encoder_seq_len
    mk = (lambda s, dt: jax.ShapeDtypeStruct(s, dt)) if abstract else (
        lambda s, dt: jnp.zeros(s, dt))
    return {"ck": mk((batch, t, kv, dh), dtype), "cv": mk((batch, t, kv, dh), dtype)}


def _stack_blocks(cfg: ModelConfig, proto: Dict, abstract: bool) -> Dict:
    """Stack one block's entry structure over the scan-block axis."""
    nb = cfg.num_blocks
    if abstract:
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((nb,) + s.shape, s.dtype), proto)
    blocks = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (nb,) + a.shape), proto)
    return jax.tree.map(jnp.array, blocks)  # materialize


# --------------------------------------------------- sharding rules --------
def _is_paged(cache: Any) -> bool:
    return isinstance(cache, dict) and "table" in cache


def _leaf_axes(path: Tuple, leaf, paged: bool = False) -> Tuple:
    leafname = getattr(path[-1], "key", str(path[-1]))
    if paged:
        # the page axis is replicated (any slot on any data shard may read
        # any page); kv heads / head dim shard exactly as contiguous
        if leafname in ("k", "v"):
            return ("layers", None, None, "kv_heads", "head_dim_shard")[-leaf.ndim:]
        if leafname in ("k_scale", "v_scale"):
            return ("layers", None, None, "kv_heads", None)[-leaf.ndim:]
        if leafname == "pos":
            return ("layers", None, None)[-leaf.ndim:]
        if leafname == "table":
            return ("batch", None)[-leaf.ndim:]
        if leafname == "length":
            return ("batch",)
        raise ValueError(leafname)
    if leafname in ("k", "v", "ck", "cv"):
        return ("layers", "batch", "cache_seq", "kv_heads", "head_dim_shard")[-leaf.ndim:]
    if leafname in ("k_scale", "v_scale"):
        # scales shard with their payload's batch/seq/head axes so a mesh
        # keeps each int8 tile and its scales on the same device (the
        # trailing scale-group axis stays unsharded)
        return ("layers", "batch", "cache_seq", "kv_heads", None)[-leaf.ndim:]
    if leafname == "pos":
        return ("layers", "batch", "cache_seq")[-leaf.ndim:]
    if leafname == "state":
        return ("layers", "batch", "ssm_heads", None, None)[-leaf.ndim:]
    if leafname == "conv":
        return ("layers", "batch", None, "ssm_inner")[-leaf.ndim:]
    if leafname == "length":
        return ("batch",)
    raise ValueError(leafname)


def cache_logical_axes(cache: Cache):
    """(path, axes) pairs for every cache leaf — used for jit shardings."""
    paged = _is_paged(cache)
    return jax.tree_util.tree_map_with_path(
        lambda p, x: _leaf_axes(p, x, paged), cache,
        is_leaf=lambda x: hasattr(x, "ndim") and not isinstance(x, dict))


def shard_cache(cache: Cache) -> Cache:
    """Apply sharding constraints to every cache leaf."""
    paged = _is_paged(cache)
    return jax.tree_util.tree_map_with_path(
        lambda p, x: shard(x, *_leaf_axes(p, x, paged)), cache)


def cache_shardings(cache: Cache, mesh=None) -> Cache:
    """NamedSharding pytree for a (concrete or abstract) cache — the eager
    counterpart of `shard_cache`, for `jax.device_put` placement of a
    host-built cache and for explicit jit in/out shardings."""
    paged = _is_paged(cache)
    return jax.tree_util.tree_map_with_path(
        lambda p, x: sharding_for(_leaf_axes(p, x, paged), x.shape, mesh), cache,
        is_leaf=lambda x: hasattr(x, "ndim") and not isinstance(x, dict))


def place_cache(cache: Cache, mesh=None) -> Cache:
    """Device-put every cache leaf onto its logical-axis sharding. No-op
    without a mesh (active or passed)."""
    shardings = cache_shardings(cache, mesh)
    if all(s is None for s in jax.tree.leaves(
            shardings, is_leaf=lambda x: x is None)):
        return cache
    return jax.tree.map(jax.device_put, cache, shardings,
                        is_leaf=lambda x: x is None)


# ------------------------------------------- contiguous per-slot ops -------
def _batch_axis(path: Tuple, leaf) -> int:
    return _leaf_axes(path, leaf, paged=False).index("batch")


def _slot_slice(cache: Cache, slot) -> Cache:
    return jax.tree_util.tree_map_with_path(
        lambda p, x: jax.lax.dynamic_slice_in_dim(
            x, slot, 1, axis=_batch_axis(p, x)), cache)


def _slot_update(cache: Cache, slot, slot_cache: Cache) -> Cache:
    def upd(path, big, small):
        ax = _batch_axis(path, big)
        return jax.lax.dynamic_update_index_in_dim(
            big, jnp.take(small, 0, axis=ax).astype(big.dtype), slot, axis=ax)

    return jax.tree_util.tree_map_with_path(upd, cache, slot_cache)


def _reset_slot_contiguous(cache: Cache, slot) -> Cache:
    def upd(path, leaf):
        name = getattr(path[-1], "key", str(path[-1]))
        ax = _batch_axis(path, leaf)
        if name in ("k", "v", "ck", "cv"):
            if not jnp.issubdtype(leaf.dtype, jnp.integer):
                return leaf
            fill = 0                       # int8 payload back to empty
        elif name == "pos":
            fill = -1
        elif name in ("k_scale", "v_scale"):
            fill = 1.0                     # neutral scale, NOT 0
        else:
            fill = 0
        row_shape = leaf.shape[:ax] + leaf.shape[ax + 1:]
        row = jnp.full(row_shape, fill, leaf.dtype)
        return jax.lax.dynamic_update_index_in_dim(leaf, row, slot, axis=ax)

    return jax.tree_util.tree_map_with_path(upd, cache)


def _slot_for(pos: jax.Array, s_cache: int, sliding_window: int) -> jax.Array:
    """Map absolute positions to cache slots (ring buffer under SWA)."""
    if sliding_window:
        return pos % s_cache
    return pos


def _is_quantized_entry(entry: Dict) -> bool:
    return "k_scale" in entry


def _write_tokens_contiguous(entry: Dict, k_new: jax.Array, v_new: jax.Array,
                             positions: jax.Array, cfg: ModelConfig,
                             valid: Optional[jax.Array] = None) -> Dict:
    s_cache = entry["k"].shape[1]
    slots = _slot_for(positions, s_cache, cfg.sliding_window)  # [B, S_new]
    if valid is None:
        valid = positions >= 0
    # invalid writes are routed to an out-of-range slot and dropped
    slots = jnp.where(valid, slots, s_cache)
    b_idx = jnp.arange(k_new.shape[0])[:, None]

    def scat(store, val):
        return store.at[b_idx, slots].set(val, mode="drop")

    out = dict(entry)  # preserves ck/cv (and anything future) untouched
    if _is_quantized_entry(entry):
        qk, ks = quantize_kv(k_new)
        qv, vs = quantize_kv(v_new)
        out["k"] = scat(entry["k"], qk)
        out["v"] = scat(entry["v"], qv)
        out["k_scale"] = scat(entry["k_scale"], ks)
        out["v_scale"] = scat(entry["v_scale"], vs)
    else:
        out["k"] = scat(entry["k"], k_new)
        out["v"] = scat(entry["v"], v_new)
    out["pos"] = scat(entry["pos"], jnp.where(valid, positions, -1))
    return out


# -------------------------------------------------- paged entry ops --------
def _write_tokens_paged(entry: Dict, k_new: jax.Array, v_new: jax.Array,
                        positions: jax.Array, table: jax.Array,
                        valid: Optional[jax.Array] = None) -> Dict:
    """Scatter S_new tokens through the page table into the pool.

    Positions outside the virtual range [0, T*page_len) are dropped
    entirely (routed to an out-of-range page id); positions whose table row
    is unmapped land in the trash page — both are invisible to readers, so
    garbage megasteps over parked or mid-prefill slots stay harmless.
    Shared (refcount > 1) pages are never hit here because callers only
    write at or beyond the committed length, which admission pins past the
    shared rows.
    """
    n_pages, page_len = entry["k"].shape[0], entry["k"].shape[1]
    t_rows = table.shape[1]
    if valid is None:
        valid = positions >= 0
    valid = valid & (positions >= 0) & (positions < t_rows * page_len)
    row = jnp.clip(positions // page_len, 0, t_rows - 1)
    b_idx = jnp.arange(positions.shape[0])[:, None]
    page = jnp.where(valid, table[b_idx, row], n_pages)  # OOR -> drop
    off = jnp.where(valid, positions % page_len, 0)

    def scat(store, val):
        return store.at[page, off].set(val, mode="drop")

    out = dict(entry)
    if _is_quantized_entry(entry):
        qk, ks = quantize_kv(k_new)
        qv, vs = quantize_kv(v_new)
        out["k"] = scat(entry["k"], qk)
        out["v"] = scat(entry["v"], qv)
        out["k_scale"] = scat(entry["k_scale"], ks)
        out["v_scale"] = scat(entry["v_scale"], vs)
    else:
        out["k"] = scat(entry["k"], k_new)
        out["v"] = scat(entry["v"], v_new)
    out["pos"] = scat(entry["pos"], jnp.where(valid, positions, -1))
    return out


def _gather_entry(entry: Dict, table: jax.Array) -> Dict:
    """Materialize a contiguous-shaped virtual view of a paged entry.

    Gathers ``pool[table]`` and flattens pages into a [B, T*page_len, ...]
    entry, then applies the identity mask ``pos == virtual_index`` so
    trash-page aliasing and cross-slot page reuse can never surface a stale
    position: an entry is kept only where its recorded absolute position is
    exactly the virtual slot it was gathered into. The result feeds the
    unchanged XLA oracle attention path (`visible_mask` applies on top).
    """
    b, t_rows = table.shape
    page_len = entry["k"].shape[1]

    def g(x):
        y = jnp.take(x, table, axis=0)  # [B, T, page_len, ...]
        return y.reshape((b, t_rows * page_len) + x.shape[2:])

    out = dict(entry)
    out["k"], out["v"] = g(entry["k"]), g(entry["v"])
    if _is_quantized_entry(entry):
        out["k_scale"], out["v_scale"] = g(entry["k_scale"]), g(entry["v_scale"])
    pos = g(entry["pos"])
    virt = jnp.arange(t_rows * page_len, dtype=pos.dtype)[None, :]
    out["pos"] = jnp.where(pos == virt, pos, jnp.int32(-1))
    return out


def _commit_nodes(entry: Dict, k_nodes: jax.Array, v_nodes: jax.Array,
                  node_idx: jax.Array, lengths: jax.Array,
                  accept_len: jax.Array):
    """Shared gather for commit_region: accepted tree nodes -> (k, v,
    positions, valid) ready for write_tokens in either layout."""
    b = k_nodes.shape[0]
    a_max = node_idx.shape[1]
    b_idx = jnp.arange(b)[:, None]
    gathered_k = k_nodes[b_idx, node_idx]  # [B, A_max, KV, Dh]
    gathered_v = v_nodes[b_idx, node_idx]
    positions = lengths[:, None] + jnp.arange(a_max)[None, :]
    valid = jnp.arange(a_max)[None, :] < accept_len[:, None]
    return gathered_k, gathered_v, positions, valid


def _clear_pages(cache: Cache, page_ids: jax.Array) -> Cache:
    """Reset ``pos`` to -1 for the given pool pages in every attention
    entry — the device half of the 'free pages are always clean' invariant
    (payload and scales can stay: an entry is unreachable once its position
    lane is -1). ``page_ids`` may repeat and may include the trash page."""
    def upd(path, leaf):
        name = getattr(path[-1], "key", str(path[-1]))
        if name == "pos":
            return leaf.at[:, page_ids].set(-1)
        return leaf

    return {**cache,
            "blocks": jax.tree_util.tree_map_with_path(upd, cache["blocks"])}


def visible_mask(entry_pos: jax.Array, q_pos: jax.Array, lengths: jax.Array,
                 sliding_window: int) -> jax.Array:
    """[B, S_q, S_cache] mask of committed slots visible to each query.

    entry_pos: [B, S_cache] absolute positions (-1 empty);
    q_pos: [B, S_q] query absolute positions; lengths: [B] committed length.
    """
    kp = entry_pos[:, None, :]
    qp = q_pos[:, :, None]
    m = (kp >= 0) & (kp < lengths[:, None, None]) & (kp < qp)
    if sliding_window:
        m &= kp > qp - sliding_window
    return m


# ------------------------------------------------------ KVCache API --------
class KVCache:
    """Layout strategy for the decode cache.

    Stateless (holds only the config); every method is jit-traceable and
    operates on plain cache pytrees, so one strategy object serves every
    executable. Obtain one via ``make_kv_cache(cfg)``.
    """

    layout: str = ""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---- entry helpers shared by both layouts
    @staticmethod
    def is_quantized_entry(entry: Dict) -> bool:
        """True when an attention cache entry holds int8 K/V + scales."""
        return _is_quantized_entry(entry)

    @staticmethod
    def entry_kv(entry: Dict) -> Tuple[jax.Array, jax.Array]:
        """The entry's K/V at compute precision — dequantized fp32 views
        for an int8 entry, the stored arrays otherwise."""
        if _is_quantized_entry(entry):
            return (dequant_kv(entry["k"], entry["k_scale"]),
                    dequant_kv(entry["v"], entry["v_scale"]))
        return entry["k"], entry["v"]

    @staticmethod
    def entry_kernel_kv(entry: Dict):
        """The entry's K/V in the fused verify kernel's contract: the raw
        **un-repeated** arrays exactly as stored — still int8 for a
        quantized entry, with their fp32 scale groups alongside
        (``(k, v, k_scale, v_scale)``; scales are None for fp)."""
        return (entry["k"], entry["v"],
                entry.get("k_scale"), entry.get("v_scale"))

    # ---- construction
    def init(self, batch: int, target_len: int, dtype=jnp.float32,
             abstract: bool = False, kv_dtype=None, pages: int = 0) -> Cache:
        raise NotImplementedError

    def nbytes(self, batch: int, target_len: int, dtype=jnp.float32,
               kv_dtype=None, pages: int = 0) -> int:
        """Device bytes one cache pytree holds (payload + scales + pos +
        SSM + length + table), computed on the abstract cache so no buffers
        materialize. This is what serving capacity accounting divides an
        HBM budget by."""
        c = self.init(batch, target_len, dtype=dtype, abstract=True,
                      kv_dtype=kv_dtype, pages=pages)
        return int(sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
                       for x in jax.tree.leaves(c)))

    # ---- per-entry ops (model layers)
    def gather_entry(self, entry: Dict, table) -> Dict:
        raise NotImplementedError

    def write_tokens(self, entry: Dict, k_new, v_new, positions,
                     valid=None, table=None) -> Dict:
        raise NotImplementedError

    def commit_region(self, entry: Dict, k_nodes, v_nodes, node_idx,
                      lengths, accept_len, table=None) -> Dict:
        """Commit accepted tree nodes into the cache.

        k_nodes/v_nodes: [B, W, KV, Dh] tree-node K/V from verification;
        node_idx: [B, A_max] indices into the W tree nodes forming the
        accepted path (position j holds the node committed at lengths+j);
        accept_len: [B] number of accepted nodes.
        """
        k, v, positions, valid = _commit_nodes(
            entry, k_nodes, v_nodes, node_idx, lengths, accept_len)
        return self.write_tokens(entry, k, v, positions, valid=valid,
                                 table=table)

    # ---- per-slot ops (engine)
    def slot_view(self, cache: Cache, slot) -> Cache:
        raise NotImplementedError

    def merge_slot(self, cache: Cache, slot, view: Cache) -> Cache:
        raise NotImplementedError

    def reset_slot(self, cache: Cache, slot) -> Cache:
        raise NotImplementedError


class ContiguousCache(KVCache):
    """Per-slot ``[B, S_cache, KV, Dh]`` storage — slot == batch row."""

    layout = "contiguous"

    def init(self, batch: int, target_len: int, dtype=jnp.float32,
             abstract: bool = False, kv_dtype=None, pages: int = 0) -> Cache:
        """Build the full cache pytree (stacked over scan blocks).

        ``kv_dtype=jnp.int8`` stores attention K/V as int8 with per-slot,
        per-head fp32 scales (see module docstring); None keeps ``dtype``.
        ``pages`` is accepted for interface parity and ignored.
        """
        cfg = self.cfg
        s_cache = cache_seq_len(cfg, target_len)
        lpb = cfg.layers_per_block

        entry = {}
        for j in range(lpb):
            if cfg.layer_mixer(j) == "attn":
                e = (_attn_entry_abstract if abstract else _attn_entry)(
                    cfg, batch, s_cache, dtype, kv_dtype=kv_dtype)
                if cfg.is_encoder_decoder:
                    e.update(_cross_entry(cfg, batch, dtype, abstract))
            else:
                e = (_ssm_entry_abstract if abstract else _ssm_entry)(
                    cfg, batch, dtype)
            entry[f"layer{j}"] = e

        blocks = _stack_blocks(cfg, entry, abstract)
        length = (jax.ShapeDtypeStruct((batch,), jnp.int32) if abstract
                  else jnp.zeros((batch,), jnp.int32))
        return {"blocks": blocks, "length": length}

    def gather_entry(self, entry: Dict, table=None) -> Dict:
        return entry  # storage already addressed by absolute position

    def write_tokens(self, entry: Dict, k_new, v_new, positions,
                     valid=None, table=None) -> Dict:
        """Write S_new tokens into an attention cache entry.

        k_new/v_new: [B, S_new, KV, Dh]; positions: [B, S_new] absolute
        positions; valid: [B, S_new] bool (False entries are not written).
        On a quantized entry the tokens are quantized here — the single
        rounding point — and payload + scales scatter to the same slots.
        """
        return _write_tokens_contiguous(entry, k_new, v_new, positions,
                                        self.cfg, valid=valid)

    def slot_view(self, cache: Cache, slot) -> Cache:
        """Extract batch slot `slot` as a batch-1 cache (same structure)."""
        return _slot_slice(cache, slot)

    def merge_slot(self, cache: Cache, slot, view: Cache) -> Cache:
        """Write the batch-1 `view` back over slot `slot`, leaving every
        other slot untouched."""
        return _slot_update(cache, slot, view)

    def reset_slot(self, cache: Cache, slot) -> Cache:
        """Clear batch slot `slot`: committed length -> 0, positions -> -1
        (so `visible_mask` hides every stale entry), SSM state/conv -> 0.
        Floating K/V payloads are left in place — unreachable once
        pos/length are cleared — but the fill is per-leaf: int8 payloads
        reset to 0 and their scales to 1.0 (the empty-slot neutral pair),
        never 0-scales."""
        return _reset_slot_contiguous(cache, slot)


class PagedCache(KVCache):
    """Fixed page pool + per-slot page table (see module docstring).

    Supports full-attention decoder-only stacks: a ring buffer would remap
    virtual rows (sliding window), SSM state is not positional, and the
    encoder cross-cache is write-once — all three keep the contiguous
    layout.
    """

    layout = "paged"

    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg)
        if cfg.sliding_window:
            raise NotImplementedError(
                "paged cache: sliding-window ring buffers not supported")
        if cfg.is_encoder_decoder:
            raise NotImplementedError(
                "paged cache: encoder-decoder cross caches not supported")
        if any(cfg.layer_mixer(i) == "ssm" for i in range(cfg.num_layers)):
            raise NotImplementedError(
                "paged cache: SSM state is not positional storage")
        if cfg.page_len < 1:
            raise ValueError(f"page_len must be >= 1, got {cfg.page_len}")
        self.page_len = cfg.page_len

    # ---- geometry
    def pages_per_slot(self, target_len: int) -> int:
        if target_len % self.page_len:
            raise ValueError(
                f"page_len={self.page_len} must divide target_len={target_len}")
        return target_len // self.page_len

    def default_pages(self, batch: int, target_len: int) -> int:
        """Full coverage — every slot can map its whole virtual range —
        plus the trash page. Smaller pools trade capacity for HBM and rely
        on admission/eviction keeping live tokens under the pool."""
        return batch * self.pages_per_slot(target_len) + 1

    def page_nbytes(self, dtype=jnp.float32, kv_dtype=None) -> int:
        """Bytes one pool page holds across all layers (K+V payload,
        scales, pos)."""
        cfg = self.cfg
        kv, dh = cfg.num_kv_heads, cfg.head_dim
        quant = kv_dtype is not None and jnp.dtype(kv_dtype) == jnp.int8
        item = jnp.dtype(jnp.int8 if quant else dtype).itemsize
        n = 2 * self.page_len * kv * dh * item
        if quant:
            n += 2 * self.page_len * kv * kv_scale_groups(dh) * 4
        n += self.page_len * 4  # pos
        return cfg.num_layers * n

    # ---- construction
    def init(self, batch: int, target_len: int, dtype=jnp.float32,
             abstract: bool = False, kv_dtype=None, pages: int = 0) -> Cache:
        """Build the pool + table pytree. ``pages=0`` sizes the pool for
        full coverage (``default_pages``). Page 0 is the trash page; the
        table starts all-trash (nothing mapped) and ``pos`` starts -1
        everywhere (free pages are clean)."""
        cfg = self.cfg
        t_rows = self.pages_per_slot(target_len)
        n_pages = pages or self.default_pages(batch, target_len)
        lpb = cfg.layers_per_block

        entry = {f"layer{j}": _paged_attn_entry(
            cfg, n_pages, self.page_len, dtype, kv_dtype=kv_dtype,
            abstract=abstract) for j in range(lpb)}
        blocks = _stack_blocks(cfg, entry, abstract)
        if abstract:
            length = jax.ShapeDtypeStruct((batch,), jnp.int32)
            table = jax.ShapeDtypeStruct((batch, t_rows), jnp.int32)
        else:
            length = jnp.zeros((batch,), jnp.int32)
            table = jnp.full((batch, t_rows), TRASH_PAGE, jnp.int32)
        return {"blocks": blocks, "length": length, "table": table}

    def make_page_state(self, batch: int, target_len: int,
                        pages: int = 0) -> "PageState":
        return PageState(batch, self.pages_per_slot(target_len),
                         pages or self.default_pages(batch, target_len),
                         self.page_len)

    # ---- per-entry ops
    def gather_entry(self, entry: Dict, table) -> Dict:
        return _gather_entry(entry, table)

    def write_tokens(self, entry: Dict, k_new, v_new, positions,
                     valid=None, table=None) -> Dict:
        if table is None:
            raise ValueError("paged write_tokens needs the slot page table")
        return _write_tokens_paged(entry, k_new, v_new, positions, table,
                                   valid=valid)

    # ---- per-slot ops
    def slot_view(self, cache: Cache, slot) -> Cache:
        """Batch-1 view of slot `slot`: the *shared* pool blocks plus the
        slot's table row and length. Writes through the view hit only the
        slot's own pages (plus the trash page), so `merge_slot` can adopt
        the view's pool wholesale."""
        return {
            "blocks": cache["blocks"],
            "length": jax.lax.dynamic_slice_in_dim(cache["length"], slot, 1),
            "table": jax.lax.dynamic_slice_in_dim(cache["table"], slot, 1,
                                                  axis=0),
        }

    def merge_slot(self, cache: Cache, slot, view: Cache) -> Cache:
        return {
            "blocks": view["blocks"],
            "length": jax.lax.dynamic_update_index_in_dim(
                cache["length"], view["length"][0], slot, 0),
            "table": jax.lax.dynamic_update_index_in_dim(
                cache["table"], view["table"][0], slot, 0),
        }

    def reset_slot(self, cache: Cache, slot) -> Cache:
        """Unmap slot `slot`: length -> 0, table row -> trash. Freed pages
        are pos-cleared separately via `clear_pages` (the host allocator
        knows which pages actually dropped to refcount zero — shared pages
        must survive)."""
        t_rows = cache["table"].shape[1]
        return {
            "blocks": cache["blocks"],
            "length": jax.lax.dynamic_update_index_in_dim(
                cache["length"], jnp.int32(0), slot, 0),
            "table": jax.lax.dynamic_update_index_in_dim(
                cache["table"], jnp.full((t_rows,), TRASH_PAGE, jnp.int32),
                slot, 0),
        }

    def clear_pages(self, cache: Cache, page_ids) -> Cache:
        return _clear_pages(cache, page_ids)


@lru_cache(maxsize=None)
def make_kv_cache(cfg: ModelConfig) -> KVCache:
    """The layout strategy for ``cfg`` (keyed by ``cfg.cache_layout``)."""
    if cfg.cache_layout == "paged":
        return PagedCache(cfg)
    if cfg.cache_layout == "contiguous":
        return ContiguousCache(cfg)
    raise ValueError(f"unknown cache_layout: {cfg.cache_layout!r}")


# ---------------------------------------------- host-side page manager ----
class PageState:
    """Host-side allocator mirroring the device page table (numpy only —
    never traced). The engine owns one per DecodeState and shares it
    between the drafter and verifier caches: both models commit identical
    positions, so one table serves both pools.

    Invariants it maintains:
      * ``table[slot, r]`` names a real page for r < ``mapped[slot]`` and
        the trash page beyond;
      * every page on the free list has been (or is pending being)
        pos-cleared on device — drain ``pending_clear`` before the next
        dispatch;
      * ``refcount`` counts slot mappings plus PrefixStore references; a
        page is recycled only at zero.
    """

    def __init__(self, batch: int, pages_per_slot: int, n_pages: int,
                 page_len: int):
        if n_pages < 2:
            raise ValueError("paged pool needs >= 2 pages (trash + 1)")
        self.batch = batch
        self.pages_per_slot = pages_per_slot
        self.n_pages = n_pages
        self.page_len = page_len
        self.table = np.full((batch, pages_per_slot), TRASH_PAGE, np.int32)
        self.refcount = np.zeros(n_pages, np.int64)
        self.refcount[TRASH_PAGE] = 1  # pinned forever
        self.free: List[int] = list(range(n_pages - 1, 0, -1))
        self.mapped = np.zeros(batch, np.int64)   # table rows in use per slot
        self.host_len = np.zeros(batch, np.int64)  # committed-length mirror
        self.live = np.zeros(batch, bool)  # slots whose tokens matter
        self.pending_clear: List[int] = []  # freed pages awaiting device clear
        self.pending_prompt: Dict[int, List[int]] = {}  # slot -> prompt held
        #   from admission (adopt) until the final prefill chunk registers it
        self.peak_pages_in_use = 0
        self.store = PrefixStore(self)

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - 1 - len(self.free)

    def _alloc(self) -> int:
        if not self.free:
            # reclaim cold prefix pages before declaring exhaustion
            self.store.evict(1)
        if not self.free:
            # lazy import: errors lives above cache in the package graph and
            # this module must stay importable without repro.serving
            from repro.serving.errors import PoolExhausted
            raise PoolExhausted(
                n_pages=self.n_pages, pages_in_use=self.pages_in_use,
                prefix_pages=len(self.store._hash_of_page),
                peak_pages=self.peak_pages_in_use)
        pid = self.free.pop()
        self.refcount[pid] = 1
        self.peak_pages_in_use = max(self.peak_pages_in_use, self.pages_in_use)
        return pid

    def ensure(self, slot: int, tokens: int) -> bool:
        """Map enough pages for `slot` to hold `tokens` committed tokens.
        Returns True when the table changed (device refresh needed).
        Transactional: on exhaustion mid-grow the pages already taken are
        unwound (they were never written, so they go straight back on the
        free list in their original order) — a parked admission must not
        leak pages into a slot that will not run."""
        need = min(-(-int(tokens) // self.page_len), self.pages_per_slot)
        changed = False
        added: List[int] = []
        base = int(self.mapped[slot])
        try:
            while self.mapped[slot] < need:
                pid = self._alloc()
                added.append(pid)
                self.table[slot, int(self.mapped[slot])] = pid
                self.mapped[slot] += 1
                changed = True
        except Exception:
            for pid in reversed(added):
                self.refcount[pid] = 0
                self.free.append(pid)
            self.table[slot, base:base + len(added)] = TRASH_PAGE
            self.mapped[slot] = base
            raise
        return changed

    def release(self, slot: int) -> None:
        """Unmap every page of `slot`. Pages whose refcount drops to zero
        return to the free list and are queued for a device pos-clear."""
        for r in range(int(self.mapped[slot])):
            pid = int(self.table[slot, r])
            self.refcount[pid] -= 1
            if self.refcount[pid] == 0:
                self.free.append(pid)
                self.pending_clear.append(pid)
        self.table[slot, :] = TRASH_PAGE
        self.mapped[slot] = 0
        self.host_len[slot] = 0
        self.live[slot] = False
        self.pending_prompt.pop(slot, None)


class PrefixStore:
    """Cross-request prefix page registry (host side).

    Keys are chain hashes of page-aligned prompt prefixes: page r's key
    folds page r-1's key with page r's tokens, so a hit at page r implies
    the whole prefix [0, (r+1)*page_len) matches. Only FULL prompt pages
    are registered or shared; the page containing a divergence point stays
    private to its slot (copy-on-write at page granularity).

    The store holds its own reference on every registered page, so shared
    pages survive slot resets. Eviction is LRU; an evicted page is actually
    freed (and queued for a device pos-clear) only once no live slot maps
    it.
    """

    def __init__(self, pages: PageState):
        self.pages = pages
        self._by_hash: "OrderedDict[int, int]" = OrderedDict()  # hash -> pid
        self._hash_of_page: Dict[int, int] = {}
        # metrics
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.prompt_tokens = 0
        self.adopt_denied = 0

    @staticmethod
    def _chain(tokens: Sequence[int], page_len: int) -> List[int]:
        out: List[int] = []
        h = 0
        for r in range(len(tokens) // page_len):
            h = hash((h, tuple(int(t) for t in
                               tokens[r * page_len:(r + 1) * page_len])))
            out.append(h)
        return out

    def lookup(self, tokens: Sequence[int]) -> Tuple[int, List[int]]:
        """Longest registered chain prefix -> (hit_pages, page_ids)."""
        ids: List[int] = []
        for h in self._chain(tokens, self.pages.page_len):
            pid = self._by_hash.get(h)
            if pid is None:
                break
            ids.append(pid)
        return len(ids), ids

    def adopt(self, slot: int, tokens: Sequence[int]) -> int:
        """Map the longest resident prefix into `slot` (which must be
        freshly released) and return the hit length in tokens. The hit is
        capped below the full prompt so at least one prompt token is always
        re-prefilled — the root logits need the last prompt token run."""
        plen = len(tokens)
        page_len = self.pages.page_len
        self.lookups += 1
        self.prompt_tokens += plen
        if not self.pages.free:
            # pool under pressure: sharing more pages would pin them against
            # eviction, so deny the adoption and let the prompt re-prefill
            self.adopt_denied += 1
            return 0
        n, ids = self.lookup(tokens)
        while n and n * page_len >= plen:
            n -= 1
        ids = ids[:n]
        if not n:
            return 0
        st = self.pages
        for r, pid in enumerate(ids):
            st.table[slot, r] = pid
            st.refcount[pid] += 1
            self._by_hash.move_to_end(self._hash_of_page[pid])
        st.mapped[slot] = n
        self.hits += 1
        self.hit_tokens += n * page_len
        return n * page_len

    def register(self, slot: int, tokens: Sequence[int]) -> None:
        """Publish `slot`'s full prompt pages after the prompt is fully
        committed. Already-registered hashes are refreshed (LRU); new ones
        take a store-owned reference on the slot's page."""
        st = self.pages
        for r, h in enumerate(self._chain(tokens, st.page_len)):
            if h in self._by_hash:
                self._by_hash.move_to_end(h)
                continue
            pid = int(st.table[slot, r])
            if pid == TRASH_PAGE or pid in self._hash_of_page:
                continue
            self._by_hash[h] = pid
            self._hash_of_page[pid] = h
            st.refcount[pid] += 1

    def evict(self, want_free: int = 1) -> int:
        """Drop LRU entries until `want_free` pages have actually been
        freed or the store is empty. Returns the number freed (freed pages
        are queued on ``pending_clear``)."""
        st = self.pages
        freed = 0
        while self._by_hash and freed < want_free:
            _, pid = self._by_hash.popitem(last=False)
            del self._hash_of_page[pid]
            st.refcount[pid] -= 1
            if st.refcount[pid] == 0:
                st.free.append(pid)
                st.pending_clear.append(pid)
                freed += 1
        return freed

    @property
    def hit_rate(self) -> float:
        """Fraction of prompt tokens skipped via resident prefix pages."""
        return self.hit_tokens / max(self.prompt_tokens, 1)
