"""Mixture-of-Experts FFN with static-shape capacity-based dispatch.

Dispatch is gather-based (indices, not one-hot einsum) so the big tensors are
[E, C, d] activations rather than [T, E, C] routing masks. Expert weights are
tensor-sharded on the expert hidden dim (`expert_ff` -> model axis), which is
uniform across E = 8 / 16 / 40 (none of which divide a 16-way model axis).
Expert-parallel layout is explored separately in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _act
from repro.models.params import ParamDef
from repro.sharding import shard


def moe_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    defs = {
        "router": ParamDef((d, e), (None, None)),
        "w_in": ParamDef((e, d, f), ("experts", None, "expert_ff"), fan_in_dims=(1,)),
        "w_out": ParamDef((e, f, d), ("experts", "expert_ff", None), fan_in_dims=(1,)),
    }
    if cfg.gated_mlp:
        defs["w_gate"] = ParamDef((e, d, f), ("experts", None, "expert_ff"),
                                  fan_in_dims=(1,))
    return defs


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(tokens * cfg.num_experts_per_tok * cfg.capacity_factor
            / cfg.num_experts) + 1
    return max(8, min(c, tokens))


def apply_moe(p: Dict, x: jax.Array, cfg: ModelConfig,
              dropless: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar).

    ``dropless=True`` sets capacity to the static worst-case per-expert load
    (T: top-k indices are distinct, so a token hits an expert at most once)
    and thus never drops an assignment. Inference paths MUST be dropless —
    capacity dropping makes a token's output depend on the rest of the
    batch, which breaks prefill/decode/tree_verify exactness (the lossless-
    decoding contract). Training keeps capacity-factor dropping as the usual
    throughput concession.
    """
    if cfg.moe_batch_dispatch:
        return _apply_moe_batched(p, x, cfg, dropless)
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    C = T if dropless else _capacity(T, cfg)
    xt = x.reshape(T, d)

    logits = (xt @ p["router"]).astype(jnp.float32)          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # [T, K]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    me = probs.mean(0)                                       # [E]
    ce = jnp.zeros((E,)).at[expert_idx.reshape(-1)].add(1.0) / (T * K)
    aux = cfg.router_aux_loss_coef * E * jnp.sum(me * ce)

    # position of each (token, k) assignment within its expert's capacity
    flat_expert = expert_idx.reshape(-1)                     # [T*K]
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)  # [T*K, E]
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - onehot)    # exclusive cumsum
    pos_in_expert = jnp.take_along_axis(
        pos_in_expert, flat_expert[:, None], axis=1)[:, 0]   # [T*K]
    keep = pos_in_expert < C

    token_ids = jnp.repeat(jnp.arange(T), K)
    # scatter token ids into the [E, C] dispatch table (dropped -> sentinel T)
    dispatch = jnp.full((E, C), T, jnp.int32)
    slot_e = jnp.where(keep, flat_expert, E)                 # drop -> OOB row
    slot_c = jnp.where(keep, pos_in_expert, 0)
    dispatch = dispatch.at[slot_e, slot_c].set(token_ids, mode="drop")

    # gather expert inputs ([E, C, d]); sentinel row reads zeros
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xin = xt_pad[dispatch]                                   # [E, C, d]
    xin = shard(xin, "experts", None, None)

    h = jnp.einsum("ecd,edf->ecf", xin, p["w_in"])
    h = shard(h, "experts", None, "expert_ff")
    if cfg.gated_mlp:
        g = jnp.einsum("ecd,edf->ecf", xin, p["w_gate"])
        h = _act(g, cfg.mlp_act) * h
    else:
        h = _act(h, cfg.mlp_act)
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_out"])        # [E, C, d]
    out_e = shard(out_e, "experts", None, None)

    # combine: scatter-add back to tokens with gate weights
    gates_flat = jnp.where(keep, gate_vals.reshape(-1), 0.0)  # [T*K]
    gate_table = jnp.zeros((E, C), gates_flat.dtype).at[slot_e, slot_c].set(
        gates_flat, mode="drop")
    out = jnp.zeros((T + 1, d), jnp.float32).at[dispatch.reshape(-1)].add(
        (out_e * gate_table[..., None]).reshape(E * C, d).astype(jnp.float32))
    out = out[:T].reshape(B, S, d).astype(x.dtype)
    return shard(out, "batch", None, None), aux


def _apply_moe_batched(p: Dict, x: jax.Array, cfg: ModelConfig,
                       dropless: bool = False) -> Tuple[jax.Array, jax.Array]:
    """§Perf variant: batch-row-local dispatch + gather-based combine.

    Routing, capacity and combine all keep the leading batch dim, so under a
    batch-sharded mesh every step is shard-local — the cross-device scatter/
    gather of the flat-token path disappears, and the only collective left
    is the w_out contraction's all-reduce. Combine is a GATHER over [E, C]
    expert outputs per token (no [T, d] scatter-add accumulator).
    Capacity is per-sequence (C = S·K·cf/E), a standard deployment choice.
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    C = S if dropless else _capacity(S, cfg)
    b_idx = jnp.arange(B)[:, None]

    logits = (x @ p["router"]).astype(jnp.float32)            # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)           # [B, S, K]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    me = probs.mean((0, 1))
    ce = (jnp.zeros((B, E)).at[b_idx.repeat(S * K, 1).reshape(B, -1),
                               expert_idx.reshape(B, -1)].add(1.0)
          ).mean(0) / (S * K)
    aux = cfg.router_aux_loss_coef * E * jnp.sum(me * ce)

    flat_e = expert_idx.reshape(B, S * K)                     # [B, S*K]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) - onehot                 # exclusive
    pos = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = pos < C                                            # [B, S*K]

    token_ids = jnp.repeat(jnp.arange(S), K)[None].repeat(B, 0)
    slot_e = jnp.where(keep, flat_e, E)
    slot_c = jnp.where(keep, pos, 0)
    dispatch = jnp.full((B, E, C), S, jnp.int32)
    dispatch = dispatch.at[b_idx, slot_e, slot_c].set(token_ids, mode="drop")

    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, d), x.dtype)], axis=1)
    xin = x_pad[b_idx[..., None], dispatch]                   # [B, E, C, d]
    xin = shard(xin, "batch", "experts", None, None)

    h = jnp.einsum("becd,edf->becf", xin, p["w_in"])
    h = shard(h, "batch", "experts", None, "expert_ff")
    if cfg.gated_mlp:
        g = jnp.einsum("becd,edf->becf", xin, p["w_gate"])
        h = _act(g, cfg.mlp_act) * h
    else:
        h = _act(h, cfg.mlp_act)
    out_e = jnp.einsum("becf,efd->becd", h, p["w_out"])       # [B, E, C, d]
    # NOTE: no sharding constraint on out_e — the combine below is linear in
    # out_e, so the model-axis reduction of the w_out contraction is allowed
    # to commute past the gather; pinning out_e here forces the all-reduce
    # on [B,E,C,d] (capacity-inflated) instead of [B,S*K,d] (§Perf it4).

    # gather-based combine: each (token, k) reads its expert/capacity slot
    acc_dt = jnp.dtype(cfg.moe_combine_dtype)
    picked = out_e[b_idx, slot_e.clip(0, E - 1), slot_c]   # [B, S*K, d]
    gates = jnp.where(keep, gate_vals.reshape(B, S * K), 0.0)
    out = (picked.astype(acc_dt) * gates[..., None].astype(acc_dt))
    out = out.reshape(B, S, K, d).sum(2).astype(x.dtype)
    return shard(out, "batch", None, None), aux
