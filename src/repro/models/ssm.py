"""Mamba2 / SSD (state-space duality) block [arXiv:2405.21060].

Three execution modes mirror the attention layer:
  * full-sequence chunked SSD scan (train / prefill),
  * single-step recurrence (decode),
  * per-path re-scan for tree verification (an SSM has no attention mask, so
    a W-node speculation tree is verified by re-scanning each node's ancestor
    path from the committed state — see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamDef
from repro.sharding import shard


# ------------------------------------------------------------- params ----
def ssm_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, di, n = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state_size
    g, h, w = cfg.ssm_num_groups, cfg.ssm_num_heads, cfg.ssm_conv_width
    conv_dim = di + 2 * g * n
    return {
        "w_in_z": ParamDef((d, di), (None, "ssm_inner")),
        "w_in_xbc": ParamDef((d, conv_dim), (None, "ssm_inner")),
        "w_in_dt": ParamDef((d, h), (None, "ssm_heads")),
        "conv_w": ParamDef((w, conv_dim), (None, "ssm_inner")),
        "conv_b": ParamDef((conv_dim,), ("ssm_inner",), init="zeros"),
        "A_log": ParamDef((h,), ("ssm_heads",), init="zeros"),
        "D": ParamDef((h,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamDef((h,), ("ssm_heads",), init="zeros"),
        "norm_scale": ParamDef((di,), ("ssm_inner",), init="ones"),
        "w_out": ParamDef((di, d), ("ssm_inner", None)),
    }


def _split_xbc(xbc: jax.Array, cfg: ModelConfig):
    di, g, n = cfg.ssm_d_inner, cfg.ssm_num_groups, cfg.ssm_state_size
    x = xbc[..., :di]
    b = xbc[..., di: di + g * n]
    c = xbc[..., di + g * n:]
    return x, b, c


def _heads(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """[..., d_inner] -> [..., H, P]"""
    return x.reshape(*x.shape[:-1], cfg.ssm_num_heads, cfg.ssm_head_dim)


def _groups(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """[..., G*N] -> [..., G, N] broadcast-expanded to heads later."""
    return x.reshape(*x.shape[:-1], cfg.ssm_num_groups, cfg.ssm_state_size)


def _expand_groups(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """[..., G, N] -> [..., H, N] (each group serves H/G heads)."""
    rep = cfg.ssm_num_heads // cfg.ssm_num_groups
    return jnp.repeat(x, rep, axis=-2)


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    y = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(y), -1, keepdims=True)
    return (y * jax.lax.rsqrt(ms + eps) * scale).astype(z.dtype)


# ----------------------------------------------------------- full scan ----
def _segsum(x: jax.Array) -> jax.Array:
    """segsum(x)[..., i, j] = sum_{j < k <= i} x[..., k]  (lower-triangular)."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, chunk: int,
             initial_state: Optional[jax.Array] = None,
             ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. x: [b,s,h,p]; dt: [b,s,h] (>=0, already softplus'ed);
    A: [h] (negative); B,C: [b,s,h,n] (groups pre-expanded).
    Returns (y [b,s,h,p], final_state [b,h,p,n])."""
    bsz, s, h, p = x.shape
    n = B.shape[-1]
    L = min(chunk, s)
    orig_s = s
    if s % L:  # pad to a chunk multiple; dt=0 pads are identity steps
        padn = L - s % L
        x = jnp.pad(x, ((0, 0), (0, padn), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, padn), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, padn), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, padn), (0, 0), (0, 0)))
        s = s + padn
    c = s // L

    xr = x.reshape(bsz, c, L, h, p)
    dtr = dt.reshape(bsz, c, L, h)
    Br = B.reshape(bsz, c, L, h, n)
    Cr = C.reshape(bsz, c, L, h, n)

    dA = dtr * A  # [b,c,L,h]
    dA_cs = jnp.cumsum(dA, axis=2)

    # intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))          # [b,c,h,L,L]
    CB = jnp.einsum("bclhn,bcshn->bchls", Cr, Br)              # [b,c,h,L,L]
    M = CB * Lmat
    xdt = xr * dtr[..., None]                                  # [b,c,L,h,p]
    y_diag = jnp.einsum("bchls,bcshp->bclhp", M, xdt)

    # chunk-end states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)        # [b,c,L,h]
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", Br, decay_states * dtr, xr)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                  # [b,c,h]
    s0 = (jnp.zeros((bsz, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit the state *entering* the chunk

    final, prev_states = jax.lax.scan(
        step, s0,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)         # [b,c,h,p,n]

    # off-diagonal (cross-chunk) contribution
    state_decay = jnp.exp(dA_cs)                               # [b,c,L,h]
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", Cr, prev_states, state_decay)

    y = (y_diag + y_off).reshape(bsz, s, h, p)[:, :orig_s]
    return y.astype(x.dtype), final


def ssd_step(state: jax.Array, x: jax.Array, dt: jax.Array, A: jax.Array,
             B: jax.Array, C: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrence. state: [b,h,p,n]; x: [b,h,p]; dt: [b,h];
    B,C: [b,h,n]. Returns (y [b,h,p], new_state)."""
    decay = jnp.exp(dt * A)                                    # [b,h]
    state = (state * decay[..., None, None]
             + jnp.einsum("bh,bhp,bhn->bhpn", dt, x.astype(jnp.float32),
                          B.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bhn->bhp", state, C.astype(jnp.float32))
    return y.astype(x.dtype), state


# --------------------------------------------------------- conv (causal) ----
def causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                init_tail: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv. xbc: [B,S,Cd]; w: [W,Cd]; init_tail: [B,W-1,Cd]
    (the last W-1 pre-conv inputs preceding this sequence)."""
    W = w.shape[0]
    if init_tail is None:
        init_tail = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[-1]), xbc.dtype)
    padded = jnp.concatenate([init_tail, xbc], axis=1)         # [B, S+W-1, Cd]
    out = sum(padded[:, i: i + xbc.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def conv_step(conv_state: jax.Array, x_new: jax.Array, w: jax.Array,
              b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """conv_state: [B, W-1, Cd]; x_new: [B, Cd]."""
    window = jnp.concatenate([conv_state, x_new[:, None]], axis=1)  # [B,W,Cd]
    y = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, w) + b)
    return y, window[:, 1:]


# ----------------------------------------------------------- layer API ----
def ssm_layer(p: Dict, xin: jax.Array, cfg: ModelConfig, *, mode: str,
              cache_entry: Optional[Dict] = None,
              seq_valid: Optional[jax.Array] = None,
              tree_paths: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[Dict], Optional[Dict]]:
    """One Mamba2 block.

    mode 'train'/'prefill': xin [B,S,d]; 'decode': [B,1,d];
    'tree': [B,W,d] with tree_paths [B,W,Dmax] ancestor chains (-1 pad at
    front, ending with the node itself).
    Returns (out, new_cache_entry, per_node_scratch) — scratch carries
    per-node states for tree commit.
    """
    z = xin @ p["w_in_z"]
    xbc_pre = xin @ p["w_in_xbc"]
    dt_raw = xin @ p["w_in_dt"]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if mode in ("train", "prefill"):
        tail = None if cache_entry is None else None  # fresh sequence
        xbc = causal_conv(xbc_pre, p["conv_w"], p["conv_b"], init_tail=tail)
        x, B_, C_ = _split_xbc(xbc, cfg)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
        if seq_valid is not None:  # padded positions are identity steps
            dt = dt * seq_valid[..., None]
        xh = _heads(x, cfg)
        Bh = _expand_groups(_groups(B_, cfg), cfg)
        Ch = _expand_groups(_groups(C_, cfg), cfg)
        xh = shard(xh, "batch", None, "ssm_heads", None)
        y, final_state = ssd_scan(xh, dt, A, Bh, Ch, cfg.ssm_chunk)
        y = y + xh * p["D"][:, None]
        y = y.reshape(*xin.shape[:-1], cfg.ssm_d_inner)
        out = _gated_norm(y.astype(jnp.float32), z, p["norm_scale"], cfg.norm_eps)
        out = out @ p["w_out"]
        new_entry = None
        if mode == "prefill":
            # conv tail = last W-1 *valid* pre-conv inputs; with right-padding
            # the valid tail is at positions [len-W+1, len) — gather them.
            Wc = cfg.ssm_conv_width
            if seq_valid is None:
                tail_idx = xin.shape[1] - (Wc - 1) + jnp.arange(Wc - 1)
                tail_idx = jnp.broadcast_to(tail_idx, (xin.shape[0], Wc - 1))
            else:
                lengths = seq_valid.sum(-1).astype(jnp.int32)
                tail_idx = lengths[:, None] - (Wc - 1) + jnp.arange(Wc - 1)[None]
            tail_idx = jnp.clip(tail_idx, 0, xin.shape[1] - 1)
            conv_tail = jnp.take_along_axis(
                xbc_pre, tail_idx[..., None], axis=1)
            new_entry = {"state": final_state, "conv": conv_tail}
        return shard(out, "batch", None, None), new_entry, None

    if mode == "decode":
        xbc_t, new_conv = conv_step(cache_entry["conv"], xbc_pre[:, 0],
                                    p["conv_w"], p["conv_b"])
        x, B_, C_ = _split_xbc(xbc_t, cfg)
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
        y, new_state = ssd_step(cache_entry["state"], _heads(x, cfg), dt, A,
                                _expand_groups(_groups(B_, cfg), cfg),
                                _expand_groups(_groups(C_, cfg), cfg))
        y = y + _heads(x, cfg) * p["D"][:, None]
        y = y.reshape(xin.shape[0], 1, cfg.ssm_d_inner)
        out = _gated_norm(y.astype(jnp.float32), z, p["norm_scale"], cfg.norm_eps)
        out = out @ p["w_out"]
        return out, {"state": new_state, "conv": new_conv}, None

    if mode == "tree":
        # Re-scan each node's ancestor path from the committed state.
        assert tree_paths is not None
        Bsz, W, _ = xin.shape
        Dmax = tree_paths.shape[-1]
        Wc = cfg.ssm_conv_width

        def gather_nodes(arr, idx):
            # arr: [B, W, F]; idx: [B, W, Dmax] -> [B, W, Dmax, F]
            return jax.vmap(lambda a, i: a[jnp.clip(i, 0, W - 1)])(arr, idx)

        path_xbc = gather_nodes(xbc_pre, tree_paths)           # [B,W,Dmax,Cd]
        path_dt = gather_nodes(dt_raw, tree_paths)             # [B,W,Dmax,H]
        pad = (tree_paths < 0)
        path_x_masked = jnp.where(pad[..., None], 0.0, path_xbc)
        n_pad = pad.sum(-1)                                    # [B,W]

        def per_node(xp, dtp, npad, st0, tail0):
            # xp: [Dmax, Cd] (front-padded); dtp: [Dmax, H]; tail0: [Wc-1, Cd]
            # Left-align the real chain, then prepend the committed conv tail
            # so the conv window for chain step t is seqf[t : t + Wc].
            chain = jnp.roll(xp, -npad, axis=0)
            seqf = jnp.concatenate([tail0, chain], axis=0)     # [Wc-1+Dmax, Cd]
            steps = Dmax - npad

            def body(st, t):
                window = jax.lax.dynamic_slice_in_dim(seqf, t, Wc, axis=0)
                xbc_t = jax.nn.silu(
                    jnp.sum(window * p["conv_w"], axis=0) + p["conv_b"])
                x_t, B_t, C_t = _split_xbc(xbc_t, cfg)
                dt_t = jax.nn.softplus(
                    dtp[jnp.clip(npad + t, 0, Dmax - 1)].astype(jnp.float32)
                    + p["dt_bias"])
                live = t < steps
                dt_t = jnp.where(live, dt_t, 0.0)
                xh = x_t.reshape(cfg.ssm_num_heads, cfg.ssm_head_dim)
                Bh = _expand_groups(B_t.reshape(cfg.ssm_num_groups, -1), cfg)
                Ch = _expand_groups(C_t.reshape(cfg.ssm_num_groups, -1), cfg)
                decay = jnp.exp(dt_t * A)
                st_new = st * decay[:, None, None] + jnp.einsum(
                    "h,hp,hn->hpn", dt_t, xh.astype(jnp.float32),
                    Bh.astype(jnp.float32))
                y_t = jnp.einsum("hpn,hn->hp", st_new, Ch.astype(jnp.float32))
                y_t = y_t + xh * p["D"][:, None]
                return st_new, (y_t, st_new)

            _, (ys, sts) = jax.lax.scan(body, st0, jnp.arange(Dmax))
            # output/state of the node itself = last live step
            last = jnp.clip(steps - 1, 0, Dmax - 1)
            # conv tail after consuming this node = last Wc-1 raw inputs
            tail_after = jax.lax.dynamic_slice_in_dim(seqf, steps, Wc - 1, axis=0)
            return ys[last], sts[last], tail_after

        per_node_v = jax.vmap(jax.vmap(per_node, in_axes=(0, 0, 0, None, None)),
                              in_axes=(0, 0, 0, 0, 0))
        y_nodes, st_nodes, tails = per_node_v(
            path_x_masked, path_dt, n_pad,
            cache_entry["state"].astype(jnp.float32), cache_entry["conv"])
        y = y_nodes.reshape(Bsz, W, cfg.ssm_d_inner)
        out = _gated_norm(y.astype(jnp.float32), z, p["norm_scale"], cfg.norm_eps)
        out = out @ p["w_out"]
        scratch = {"node_states": st_nodes, "node_conv": tails}
        return out, cache_entry, scratch

    raise ValueError(mode)
