"""Attention: GQA/MQA projections, flash-style prefill, cached decode,
tree-mask verification, sliding window, cross-attention.

Sharding strategy (baseline — see DESIGN.md §5 and EXPERIMENTS.md §Perf):
  * Q heads sharded over `model` when divisible, else replicated.
  * K/V: kv-heads sharded when divisible (MHA), else replicated; the decode
    cache is always sharded along the *sequence* axis so long caches fit.
  * Decode softmax over the sequence-sharded axis is left to GSPMD (the
    shard_map flash-decode variant is a §Perf optimization).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import cache as cache_lib
from repro.models.layers import apply_rope, rms_norm
from repro.models.params import ParamDef
from repro.sharding import shard
from repro.sharding import specs as shard_lib

NEG_INF = -1e9


# ------------------------------------------------------------- params ----
def attn_defs(cfg: ModelConfig, cross: bool = False) -> Dict[str, ParamDef]:
    d, H, KV, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    defs = {
        "wq": ParamDef((d, H, dh), (None, "heads", None)),
        "wk": ParamDef((d, KV, dh), (None, "kv_heads", None)),
        "wv": ParamDef((d, KV, dh), (None, "kv_heads", None)),
        "wo": ParamDef((H, dh, d), ("heads", None, None), fan_in_dims=(0, 1)),
    }
    if cfg.use_qk_norm and not cross:
        defs["q_norm"] = ParamDef((dh,), (None,), init="ones")
        defs["k_norm"] = ParamDef((dh,), (None,), init="ones")
    return defs


def _project_q(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.use_qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    return shard(q, "batch", None, "heads", None)


def _project_kv(p: Dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.use_qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return k, v


def _out_proj(p: Dict, o: jax.Array, cfg: ModelConfig) -> jax.Array:
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return shard(out, "batch", None, None)


def _repeat_kv(x: jax.Array, groups: int) -> jax.Array:
    if groups == 1:
        return x
    return jnp.repeat(x, groups, axis=2)


# ----------------------------------------------------- full / prefill ----
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, cfg: ModelConfig,
                    *, causal: bool = True,
                    q_offset: int = 0,
                    kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """Block-wise online-softmax attention over full sequences.

    q: [B, Sq, H, Dh]; k/v: [B, Skv, H, Dh] (kv already repeated to H heads).
    kv_valid: [B, Skv] bool for padding. Sliding window honored via
    cfg.sliding_window by dynamic kv slicing per query block.
    """
    B, Sq, H, Dh = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(Dh)
    qc = min(cfg.attn_chunk, Sq)
    kc = min(cfg.attn_chunk, Skv)
    while Sq % qc:       # fall back to the largest chunk that divides
        qc -= 1
    while Skv % kc:
        kc -= 1
    n_qc = Sq // qc
    window = cfg.sliding_window

    if cfg.use_pallas and kv_valid is None and not window and causal and Sq == Skv:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.flash_prefill(q, k, v, block_q=qc, block_k=kc)

    q_pos_base = jnp.arange(qc)
    kv_pos_all = jnp.arange(Skv)

    def q_block(carry, qi):
        qb = jax.lax.dynamic_slice_in_dim(q, qi * qc, qc, axis=1)  # [B,qc,H,Dh]
        q_pos = qi * qc + q_pos_base + q_offset

        if window:
            # only the last `window + qc` keys can be visible to this block
            span = min(window + qc, Skv)
            start = jnp.clip(qi * qc + qc - span + q_offset, 0, Skv - span)
            kb_all = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vb_all = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            kv_pos = start + jnp.arange(span)
            valid_all = (None if kv_valid is None
                         else jax.lax.dynamic_slice_in_dim(kv_valid, start, span, axis=1))
        else:
            kb_all, vb_all, kv_pos, valid_all = k, v, kv_pos_all, kv_valid

        span = kb_all.shape[1]
        n_kc = span // kc

        def kv_block(state, ki):
            m_prev, l_prev, acc = state
            kb = jax.lax.dynamic_slice_in_dim(kb_all, ki * kc, kc, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(vb_all, ki * kc, kc, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(kv_pos, ki * kc, kc, axis=0)
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb).astype(jnp.float32) * scale
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= q_pos[:, None] >= kp[None, :]
            if window:
                mask &= kp[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, None], s, NEG_INF)
            if valid_all is not None:
                vb_mask = jax.lax.dynamic_slice_in_dim(valid_all, ki * kc, kc, axis=1)
                s = jnp.where(vb_mask[:, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m_prev, s.max(-1))
            alpha = jnp.exp(m_prev - m_new)
            pexp = jnp.exp(s - m_new[..., None])
            l_new = l_prev * alpha + pexp.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", pexp, vb.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, H, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, qc), jnp.float32)
        a0 = jnp.zeros((B, H, qc, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), jnp.arange(n_kc))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return carry, out.transpose(0, 2, 1, 3).astype(q.dtype)  # [B,qc,H,Dh]

    _, outs = jax.lax.scan(q_block, None, jnp.arange(n_qc))  # [n_qc,B,qc,H,Dh]
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, Dh)


def grouped_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                            cfg: ModelConfig, *, causal: bool = True,
                            kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """§Perf: block-wise online-softmax attention contracting in KV-head
    space — K/V blocks are read once instead of materialized G× by
    repeat_kv. q: [B, Sq, H, Dh]; k/v: [B, Skv, KV, Dh]."""
    B, Sq, H, Dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(Dh)
    qc = min(cfg.attn_chunk, Sq)
    kc = min(cfg.attn_chunk, Skv)
    while Sq % qc:
        qc -= 1
    while Skv % kc:
        kc -= 1
    n_qc = Sq // qc
    window = cfg.sliding_window
    q_pos_base = jnp.arange(qc)
    kv_pos_all = jnp.arange(Skv)

    def q_block(carry, qi):
        qb = jax.lax.dynamic_slice_in_dim(q, qi * qc, qc, axis=1)
        qb = qb.reshape(B, qc, KV, G, Dh)
        q_pos = qi * qc + q_pos_base

        if window:
            span = min(window + qc, Skv)
            start = jnp.clip(qi * qc + qc - span, 0, Skv - span)
            kb_all = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vb_all = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            kv_pos = start + jnp.arange(span)
            valid_all = (None if kv_valid is None else
                         jax.lax.dynamic_slice_in_dim(kv_valid, start, span,
                                                      axis=1))
        else:
            kb_all, vb_all, kv_pos, valid_all = k, v, kv_pos_all, kv_valid
        n_kc = kb_all.shape[1] // kc

        def kv_block(state, ki):
            m_prev, l_prev, acc = state
            kb = jax.lax.dynamic_slice_in_dim(kb_all, ki * kc, kc, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(vb_all, ki * kc, kc, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(kv_pos, ki * kc, kc, axis=0)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb,
                           kb).astype(jnp.float32) * scale
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= q_pos[:, None] >= kp[None, :]
            if window:
                mask &= kp[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            if valid_all is not None:
                vm = jax.lax.dynamic_slice_in_dim(valid_all, ki * kc, kc,
                                                  axis=1)
                s = jnp.where(vm[:, None, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m_prev, s.max(-1))
            alpha = jnp.exp(m_prev - m_new)
            pexp = jnp.exp(s - m_new[..., None])
            l_new = l_prev * alpha + pexp.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", pexp, vb.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KV, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qc, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0),
                                      jnp.arange(n_kc))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # [B, KV, G, qc, Dh] -> [B, qc, H, Dh]
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, qc, H, Dh)
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_block, None, jnp.arange(n_qc))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, Dh)


# ------------------------------------------------------ cached decode ----
def use_verify_kernel(cfg: ModelConfig) -> bool:
    """Resolve cfg.verify_kernel: is the fused Pallas kernel the decode/
    verify hot path? "auto" picks it on accelerator backends and keeps the
    XLA einsum path on CPU, where the kernel would run in (slow) interpret
    mode — tests opt in explicitly via cfg.replace(verify_kernel="fused")."""
    mode = getattr(cfg, "verify_kernel", "auto")
    if mode == "xla":
        return False
    if mode == "fused":
        return True
    if mode != "auto":
        raise ValueError(f"verify_kernel must be auto|fused|xla, got {mode}")
    return jax.default_backend() != "cpu"


def fused_dispatch_ok(cfg: ModelConfig, *, mesh_active: bool) -> bool:
    """THE fused-kernel dispatch predicate (minus the per-call ``k_new is
    not None``): kernel enabled, no ring-buffer sliding window, no mesh
    (Pallas calls aren't SPMD-partitioned). ``cached_attention`` and
    ``engine.verify_path()`` both consult this so the reported hot path
    can never drift from the dispatched one."""
    return (use_verify_kernel(cfg) and not cfg.sliding_window
            and not mesh_active)


def _fused_verify_path(q, entry, cfg, q_pos, lengths, k_new, v_new,
                       tree_mask, table=None):
    """Route one cached-attention call through the fused verify kernel.

    The kernel owns the committed-prefix mask (computed in VMEM from
    entry["pos"]/q_pos/lengths), the length-aware kv-block skip, and the
    tree-scratch segment — nothing is repeated, concatenated or
    materialized here. With a page table the kernel reads the pool
    directly: the scalar-prefetched table turns the length-clamped block
    index into a page id, so paged storage costs no gather."""
    from repro.kernels import ops as kernel_ops
    B, W = q.shape[:2]
    if tree_mask is None:  # plain decode: each token attends to itself only
        tree_mask = jnp.broadcast_to(jnp.eye(W, dtype=bool)[None],
                                     (B, W, W))
    ek, ev, ks, vs = cache_lib.KVCache.entry_kernel_kv(entry)
    if table is not None:
        return kernel_ops.verify_attention_paged(
            q, ek, ev, entry["pos"], table, q_pos, lengths, k_new, v_new,
            tree_mask, k_scale=ks, v_scale=vs)
    # the wrapper's own kv-block default (256) sets the skip granularity;
    # cfg.attn_chunk stays the *prefill* block knob — at max_target_len=512
    # it would make the whole cache one block and disable the early-out
    return kernel_ops.verify_attention(
        q, ek, ev, entry["pos"], q_pos, lengths, k_new, v_new, tree_mask,
        k_scale=ks, v_scale=vs)


def cached_attention(q: jax.Array, entry: Dict, cfg: ModelConfig,
                     q_pos: jax.Array, lengths: jax.Array,
                     k_new: Optional[jax.Array] = None,
                     v_new: Optional[jax.Array] = None,
                     tree_mask: Optional[jax.Array] = None,
                     table: Optional[jax.Array] = None) -> jax.Array:
    """Attention of W query tokens against the committed cache plus (for tree
    verification) the W in-flight tree tokens.

    q: [B, W, H, Dh]; q_pos: [B, W] absolute positions; lengths: [B];
    k_new/v_new: [B, W, KV, Dh] the queries' own K/V (tree scratch);
    tree_mask: [B, W, W] ancestor-or-self visibility (None for plain decode);
    table: [B, T] page table when the entry is a paged pool (None for the
    contiguous layout).

    Hot path (cfg.verify_kernel): the fused GQA-native Pallas kernel, which
    reads the cache un-repeated at its storage dtype and skips kv-blocks
    past the committed length (paged pools are read through the
    scalar-prefetched table, no gather). Falls back to the XLA einsum paths
    (the selectable oracle) under a mesh (Pallas calls aren't
    SPMD-partitioned), with sliding windows (ring-buffer slots), or when
    k_new is absent — a paged entry is first flattened to a virtual
    contiguous view by `gather_entry`, so both oracles stay byte-identical
    to the contiguous math.
    """
    B, W, H, Dh = q.shape
    G = cfg.num_q_per_kv
    scale = 1.0 / math.sqrt(Dh)
    if k_new is not None and fused_dispatch_ok(
            cfg, mesh_active=shard_lib.current_mesh() is not None):
        return _fused_verify_path(q, entry, cfg, q_pos, lengths, k_new,
                                  v_new, tree_mask, table=table)
    if table is not None:
        entry = cache_lib.make_kv_cache(cfg).gather_entry(entry, table)
    # int8 caches dequantize here (per-layer slice, inside the block scan,
    # so XLA cannot hoist a whole-stack fp32 copy); fp caches pass through
    ek, ev = cache_lib.KVCache.entry_kv(entry)

    if cfg.gqa_grouped and G > 1:
        # §Perf: contract against the cache in KV-head space — the cache is
        # read ONCE instead of materialized G× by repeat_kv.
        KV = cfg.num_kv_heads
        qg = q.reshape(B, W, KV, G, Dh)
        s_cache = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                             ek).astype(jnp.float32) * scale
        if cfg.attn_score_seqshard:
            # §Perf it3: keep scores/probs on the cache's seq sharding so
            # the P·V contraction psums a [B,W,H,Dh] partial instead of
            # all-gathering V (the involuntary-remat path SPMD warns about)
            s_cache = shard(s_cache, "batch", None, None, None, "cache_seq")
        m_cache = cache_lib.visible_mask(entry["pos"], q_pos, lengths,
                                         cfg.sliding_window)
        s_cache = jnp.where(m_cache[:, None, None], s_cache, NEG_INF)
        parts = [s_cache]
        if k_new is not None:
            s_tree = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                                k_new).astype(jnp.float32) * scale
            tm = (jnp.eye(W, dtype=bool)[None] if tree_mask is None
                  else tree_mask)
            s_tree = jnp.where(tm[:, None, None], s_tree, NEG_INF)
            parts.append(s_tree)
        s_all = jnp.concatenate(parts, axis=-1)
        probs = jax.nn.softmax(s_all, axis=-1)
        sc = s_cache.shape[-1]
        pc, pt = probs[..., :sc], probs[..., sc:]
        if cfg.attn_score_seqshard:
            pc = shard(pc, "batch", None, None, None, "cache_seq")
        # §Perf it4: contract P·V at the cache's own precision with f32
        # accumulation — a materialized `v.astype(f32)` gets hoisted by XLA
        # above the per-layer slice, converting the whole stacked cache.
        # Probs are downcast (tiny [B,KV,G,W,S] tensor) instead of V.
        pv = pc.astype(ev.dtype) if ev.dtype != jnp.float32 else pc
        out = jnp.einsum("bkgqs,bskd->bqkgd", pv, ev,
                         preferred_element_type=jnp.float32)
        if cfg.attn_score_seqshard:
            out = shard(out, "batch", None, None, None, None)
        if k_new is not None:
            out = out + jnp.einsum("bkgqs,bskd->bqkgd", pt, v_new,
                                   preferred_element_type=jnp.float32)
        return out.reshape(B, W, H, Dh).astype(q.dtype)

    kc = _repeat_kv(ek, G)  # [B, Sc, H, Dh]
    vc = _repeat_kv(ev, G)
    s_cache = jnp.einsum("bqhd,bshd->bhqs", q, kc).astype(jnp.float32) * scale
    m_cache = cache_lib.visible_mask(entry["pos"], q_pos, lengths, cfg.sliding_window)
    s_cache = jnp.where(m_cache[:, None], s_cache, NEG_INF)

    parts = [s_cache]
    if k_new is not None:
        kt = _repeat_kv(k_new, G)
        s_tree = jnp.einsum("bqhd,bshd->bhqs", q, kt).astype(jnp.float32) * scale
        if tree_mask is None:  # plain decode: attend to self only
            tm = jnp.eye(W, dtype=bool)[None]
        else:
            tm = tree_mask
        s_tree = jnp.where(tm[:, None], s_tree, NEG_INF)
        parts.append(s_tree)

    s_all = jnp.concatenate(parts, axis=-1)
    probs = jax.nn.softmax(s_all, axis=-1)
    pc, pt = probs[..., : kc.shape[1]], probs[..., kc.shape[1]:]
    # §Perf it4 (as on the grouped path): contract P·V at the cache's own
    # precision with f32 accumulation — `vc.astype(f32)` would materialize
    # a full fp32 copy of the (repeated) cache, and XLA hoists that above
    # the per-layer slice, converting the whole stacked cache per step.
    # Probs are downcast (tiny [B,H,W,S] tensor) instead of V.
    pv = pc.astype(vc.dtype) if vc.dtype != jnp.float32 else pc
    out = jnp.einsum("bhqs,bshd->bqhd", pv, vc,
                     preferred_element_type=jnp.float32)
    if k_new is not None:
        # the tree scratch is a tiny fresh tensor — no whole-cache hoisting
        # to dodge, so keep the probs at f32 here (as the grouped path does)
        vt = _repeat_kv(v_new, G)
        out = out + jnp.einsum("bhqs,bshd->bqhd", pt, vt.astype(jnp.float32))
    return out.astype(q.dtype)


# -------------------------------------------------------- layer entry ----
def attention_layer(p: Dict, x: jax.Array, cfg: ModelConfig, *, mode: str,
                    positions: jax.Array, inv_freq: Optional[jax.Array],
                    cache_entry: Optional[Dict] = None,
                    lengths: Optional[jax.Array] = None,
                    tree_mask: Optional[jax.Array] = None,
                    seq_valid: Optional[jax.Array] = None,
                    table: Optional[jax.Array] = None,
                    ) -> Tuple[jax.Array, Optional[Dict], Optional[Tuple]]:
    """One self-attention layer in the given mode.

    mode: 'train' | 'prefill' | 'decode' | 'tree'
    Returns (out, updated_cache_entry, tree_kv) where tree_kv = (k_new, v_new)
    for tree/decode (needed by the engine to commit accepted nodes).
    """
    q = _project_q(p, x, cfg)
    k, v = _project_kv(p, x, cfg)
    if inv_freq is not None:
        q = apply_rope(q, positions, inv_freq)
        k = apply_rope(k, positions, inv_freq)

    def _full(q_, k_, v_, causal):
        if cfg.gqa_grouped and cfg.num_q_per_kv > 1:
            return grouped_flash_attention(q_, k_, v_, cfg, causal=causal,
                                           kv_valid=seq_valid)
        return flash_attention(q_, _repeat_kv(k_, cfg.num_q_per_kv),
                               _repeat_kv(v_, cfg.num_q_per_kv), cfg,
                               causal=causal, kv_valid=seq_valid)

    if mode == "encode":  # bidirectional (whisper encoder)
        out = _full(q, k, v, False)
        return _out_proj(p, out, cfg), None, None

    if mode == "train":
        out = _full(q, k, v, True)
        return _out_proj(p, out, cfg), None, None

    if mode == "prefill":
        out = _full(q, k, v, True)
        valid = None if seq_valid is None else seq_valid
        new_entry = cache_lib.make_kv_cache(cfg).write_tokens(
            cache_entry, k, v, positions, valid=valid, table=table)
        return _out_proj(p, out, cfg), new_entry, None

    if mode in ("decode", "tree"):
        out = cached_attention(q, cache_entry, cfg, positions, lengths,
                               k_new=k, v_new=v,
                               tree_mask=tree_mask if mode == "tree" else None,
                               table=table)
        return _out_proj(p, out, cfg), cache_entry, (k, v)

    raise ValueError(mode)


def attention_tree_extend(p: Dict, x: jax.Array, cfg: ModelConfig, *,
                          positions: jax.Array, inv_freq: Optional[jax.Array],
                          cache_entry: Dict, lengths: jax.Array,
                          scratch_k: jax.Array, scratch_v: jax.Array,
                          offset: int, ext_mask: jax.Array,
                          table: Optional[jax.Array] = None,
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Drafter-side incremental tree growth: Q new nodes are appended to the
    per-layer tree scratch ([B, N, KV, Dh]) at a *static* offset, then attend
    to the committed cache plus the whole scratch under ext_mask [B, Q, N].

    The static offset is the equal-growth property at work: every draft step
    of a ⟨D, W⟩ bucket appends exactly W nodes, so the offsets (1, 1+W,
    1+2W, …) are compile-time constants and the step graph is reusable.
    """
    q = _project_q(p, x, cfg)
    k, v = _project_kv(p, x, cfg)
    if inv_freq is not None:
        q = apply_rope(q, positions, inv_freq)
        k = apply_rope(k, positions, inv_freq)
    scratch_k = jax.lax.dynamic_update_slice_in_dim(scratch_k, k, offset, axis=1)
    scratch_v = jax.lax.dynamic_update_slice_in_dim(scratch_v, v, offset, axis=1)
    out = cached_attention(q, cache_entry, cfg, positions, lengths,
                           k_new=scratch_k, v_new=scratch_v, tree_mask=ext_mask,
                           table=table)
    return _out_proj(p, out, cfg), scratch_k, scratch_v


def cross_attention_layer(p: Dict, x: jax.Array, cfg: ModelConfig,
                          cache_entry: Dict) -> jax.Array:
    """Decoder cross-attention against cached encoder K/V (no mask, no rope)."""
    q = _project_q(p, x, cfg)
    G = cfg.num_q_per_kv
    ck, cv = _repeat_kv(cache_entry["ck"], G), _repeat_kv(cache_entry["cv"], G)
    s = jnp.einsum("bqhd,bshd->bhqs", q, ck).astype(jnp.float32)
    s = s / math.sqrt(cfg.head_dim)
    probs = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, cv.astype(jnp.float32)).astype(x.dtype)
    return _out_proj(p, out, cfg)


def encode_cross_kv(p: Dict, enc_out: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Project encoder output into the decoder layer's cross K/V."""
    return _project_kv(p, enc_out, cfg)
