"""Parameter definition tables.

Each layer declares its parameters as ``ParamDef`` entries (shape + logical
axes + initializer). Both ``init_params`` and the sharding-spec derivation
(`repro.sharding.specs.param_shardings`) consume the same table, so the
parameter pytree and its PartitionSpecs can never drift apart.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    init: str = "normal"              # normal | zeros | ones
    fan_in_dims: Tuple[int, ...] = () # dims whose product is fan-in (default: all but last)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def fan_in(self) -> int:
        dims = self.fan_in_dims or tuple(range(len(self.shape) - 1))
        n = 1
        for d in dims:
            n *= self.shape[d]
        return max(n, 1)


def stacked(defs: Any, n: int, axis_name: str = "layers") -> Any:
    """Prepend a stacking dim (for lax.scan over layer blocks)."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (axis_name,) + d.axes, d.init,
                           tuple(x + 1 for x in (d.fan_in_dims or tuple(range(len(d.shape) - 1))))),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def init_params(defs: Any, key: jax.Array, dtype=jnp.float32) -> Any:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))

    def one(d: ParamDef, k):
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, dtype)
        scale = 1.0 / math.sqrt(d.fan_in())
        return (jax.random.normal(k, d.shape, jnp.float32) * scale).astype(dtype)

    return jax.tree.unflatten(treedef, [one(d, k) for d, k in zip(leaves, keys)])


def abstract_params(defs: Any, dtype=jnp.float32) -> Any:
    """ShapeDtypeStruct pytree for AOT lowering without allocation."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def count_params(defs: Any) -> int:
    total = 0
    for d in jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef)):
        n = 1
        for s in d.shape:
            n *= s
        total += n
    return total
