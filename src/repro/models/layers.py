"""Shared layer primitives: norms, RoPE, MLPs, embeddings."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamDef
from repro.sharding import shard


# ---------------------------------------------------------------- norms ----
def norm_defs(cfg: ModelConfig, d: Optional[int] = None) -> Dict[str, ParamDef]:
    d = d or cfg.d_model
    defs = {"scale": ParamDef((d,), (None,), init="ones")}
    if cfg.norm_type == "layernorm":
        defs["bias"] = ParamDef((d,), (None,), init="zeros")
    return defs


def apply_norm(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
    return y.astype(x.dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (y * scale).astype(x.dtype)


# ----------------------------------------------------------------- rope ----
def rope_frequencies(cfg: ModelConfig, dim: Optional[int] = None) -> jax.Array:
    dim = dim or cfg.head_dim
    exponent = jnp.arange(0, dim, 2, dtype=jnp.float32) / dim
    return 1.0 / (cfg.rope_theta ** exponent)  # [dim/2]


def apply_rope(x: jax.Array, positions: jax.Array, inv_freq: jax.Array) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # [..., S, D/2]
    angles = angles[..., None, :]                                 # [..., S, 1, D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- mlps ----
def mlp_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, cfg.d_ff
    defs = {
        "w_in": ParamDef((d, f), (None, "ff")),
        "w_out": ParamDef((f, d), ("ff", None)),
    }
    if cfg.gated_mlp:
        defs["w_gate"] = ParamDef((d, f), (None, "ff"))
    return defs


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "sq_relu":  # nemotron-4 squared ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def apply_mlp(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = x @ p["w_in"]
    h = shard(h, *(("batch",) + (None,) * (h.ndim - 2) + ("ff",)))
    if cfg.gated_mlp:
        h = _act(x @ p["w_gate"], cfg.mlp_act) * h
    else:
        h = _act(h, cfg.mlp_act)
    out = h @ p["w_out"]
    return shard(out, *(("batch",) + (None,) * (out.ndim - 1)))


# ----------------------------------------------------------- embeddings ----
def embed_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    defs = {"tok": ParamDef((cfg.vocab_padded, cfg.d_model), ("vocab", "d_model"))}
    if cfg.pos_embedding == "learned":
        defs["pos"] = ParamDef((cfg.max_seq_len, cfg.d_model), (None, None))
    return defs


def embed_tokens(p: Dict, tokens: jax.Array, cfg: ModelConfig,
                 positions: Optional[jax.Array] = None) -> jax.Array:
    h = jnp.take(p["tok"], tokens, axis=0)
    if cfg.pos_embedding == "learned":
        assert positions is not None
        h = h + jnp.take(p["pos"], jnp.clip(positions, 0, cfg.max_seq_len - 1), axis=0)
    return shard(h, "batch", None, None)


def lm_head_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    if cfg.tie_embeddings:
        return {}
    return {"w": ParamDef((cfg.d_model, cfg.vocab_padded), ("d_model", "vocab"))}


def apply_lm_head(params: Dict, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embed"]["tok"].T
    else:
        w = params["lm_head"]["w"]
    logits = h @ w
    return shard(logits, *(("batch",) + (None,) * (logits.ndim - 2) + ("vocab",)))
