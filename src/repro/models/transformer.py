"""Transformer block assembly: heterogeneous layer groups scanned over.

A *block* is the repeating unit of `cfg.layers_per_block` layers (1 for
homogeneous archs; 8 for jamba's mamba/attention interleave). Parameters for
all blocks are stacked on a leading axis and the trunk is a `lax.scan` over
blocks, which keeps compile time flat in depth.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, moe, ssm
from repro.models.layers import apply_mlp, apply_norm, mlp_defs, norm_defs


def block_defs(cfg: ModelConfig, encoder: bool = False) -> Dict[str, Any]:
    """ParamDefs for one block (layer0..layerN-1)."""
    out: Dict[str, Any] = {}
    for j in range(1 if encoder else cfg.layers_per_block):
        i = j  # layer kind depends only on position within the block
        layer: Dict[str, Any] = {}
        mixer = "attn" if encoder else cfg.layer_mixer(i)
        layer["mixer_norm"] = norm_defs(cfg)
        if mixer == "attn":
            layer["attn"] = attention.attn_defs(cfg)
            if cfg.is_encoder_decoder and not encoder:
                layer["cross_norm"] = norm_defs(cfg)
                layer["cross"] = attention.attn_defs(cfg, cross=True)
        else:
            layer["ssm"] = ssm.ssm_defs(cfg)
        ffn = "dense" if encoder else cfg.layer_ffn(i)
        if ffn == "dense":
            layer["ffn_norm"] = norm_defs(cfg)
            layer["mlp"] = mlp_defs(cfg)
        elif ffn == "moe":
            layer["ffn_norm"] = norm_defs(cfg)
            layer["moe"] = moe.moe_defs(cfg)
        out[f"layer{j}"] = layer
    return out


def apply_block(bp: Dict, h: jax.Array, cfg: ModelConfig, mode: str,
                ctx: Dict, cache_block: Optional[Dict] = None,
                encoder: bool = False,
                ) -> Tuple[jax.Array, Optional[Dict], Optional[Dict], jax.Array]:
    """Apply one block. Returns (h, new_cache_block, scratch_block, aux_loss)."""
    new_cache: Dict = {}
    scratch: Dict = {}
    aux = jnp.zeros((), jnp.float32)
    n_layers = 1 if encoder else cfg.layers_per_block
    for j in range(n_layers):
        lp = bp[f"layer{j}"]
        entry = None if cache_block is None else cache_block[f"layer{j}"]
        mixer = "attn" if encoder else cfg.layer_mixer(j)

        x = apply_norm(lp["mixer_norm"], h, cfg)
        if mixer == "attn":
            amode = "encode" if encoder else mode
            out, new_entry, kv = attention.attention_layer(
                lp["attn"], x, cfg, mode=amode,
                positions=ctx["positions"], inv_freq=ctx.get("inv_freq"),
                cache_entry=entry, lengths=ctx.get("lengths"),
                tree_mask=ctx.get("tree_mask"), seq_valid=ctx.get("seq_valid"),
                table=ctx.get("table"))
            if mode == "decode" and not encoder:
                # single confirmed token: write through immediately
                from repro.models import cache as cache_lib
                new_entry = cache_lib.make_kv_cache(cfg).write_tokens(
                    entry, kv[0], kv[1], ctx["positions"],
                    table=ctx.get("table"))
                kv = None
            h = h + out
            if cfg.is_encoder_decoder and not encoder:
                if mode == "prefill" and ctx.get("enc_out") is not None:
                    ck, cv = attention.encode_cross_kv(lp["cross"], ctx["enc_out"], cfg)
                    new_entry = dict(new_entry or entry)
                    new_entry["ck"], new_entry["cv"] = ck, cv
                    entry = new_entry
                if mode in ("prefill", "decode", "tree") and entry is not None:
                    xc = apply_norm(lp["cross_norm"], h, cfg)
                    h = h + attention.cross_attention_layer(lp["cross"], xc, cfg, entry)
            if kv is not None:
                scratch[f"layer{j}"] = {"k": kv[0], "v": kv[1]}
        else:
            out, new_entry, sc = ssm.ssm_layer(
                lp["ssm"], x, cfg, mode=mode, cache_entry=entry,
                seq_valid=ctx.get("seq_valid"), tree_paths=ctx.get("tree_paths"))
            h = h + out
            if sc is not None:
                scratch[f"layer{j}"] = sc

        if "mlp" in lp:
            x = apply_norm(lp["ffn_norm"], h, cfg)
            h = h + apply_mlp(lp["mlp"], x, cfg)
        elif "moe" in lp:
            x = apply_norm(lp["ffn_norm"], h, cfg)
            # inference must be dropless: capacity drops couple a token's
            # output to the batch and break cross-mode exactness
            mo, a = moe.apply_moe(lp["moe"], x, cfg,
                                  dropless=(mode != "train"
                                            or bool(ctx.get("moe_dropless"))))
            h = h + mo
            aux = aux + a

        if entry is not None or new_entry is not None:
            new_cache[f"layer{j}"] = new_entry if new_entry is not None else entry

    return h, (new_cache or None), (scratch or None), aux
