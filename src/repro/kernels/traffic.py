"""Analytic HBM-traffic model for verification attention.

The verify megastep is bandwidth-bound, so the paper's latency model (and
the stage-based scheduler built on it) is only as good as its estimate of
the bytes one verification step actually moves. These functions model that
traffic per attention layer, deterministically, from shapes alone — they
feed the kernel microbenchmark (``benchmarks/fig_kernel.py``), the roofline
tables, and the CI bench-regression gate (``kernel_traffic`` metrics in
``benchmarks/fig_serving.py``), where the length-scaling and GQA ratios
would silently regress if someone reintroduced ``repeat_kv`` or dropped the
kv-block skip.

Modeled flows (first-order: operand reads + output writes; scores/probs are
assumed to stay on-chip for the kernel and are charged to the XLA paths
only via the materialized visibility mask):

* ``verify_kernel_bytes`` — the fused GQA-native kernel: K/V read once per
  kv-head at storage precision (int8 payload + fp32 scale groups when
  quantized), only for kv-blocks holding committed tokens (block-granular
  ``ceil(len/block_s)`` early-out), no mask tensor (computed in VMEM from
  ``kv_pos``/``q_pos``), plus the fused tree-scratch segment.
* ``verify_xla_bytes`` — the einsum paths: the whole ``s_cache`` extent
  every step plus the materialized ``[B, W, S]`` visibility mask; with
  ``grouped=False`` additionally the ``repeat_kv`` blow-up (K/V
  materialized G× at fp32 — the pre-kernel default hot path).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def verify_kernel_bytes(*, w: int, kv_heads: int, num_q_per_kv: int,
                        head_dim: int, s_cache: int,
                        lengths: Sequence[int], block_s: int = 256,
                        tree_t: Optional[int] = None,
                        kv_itemsize: int = 4,
                        scale_groups: int = 0) -> int:
    """Modeled HBM bytes for ONE fused verify-attention call (one layer).

    lengths: committed length per batch row (drives the kv-block skip);
    kv_itemsize: cache storage itemsize (4 fp32, 1 int8); scale_groups:
    fp32 scale groups per (slot, kv-head) for int8 caches, 0 for fp.
    """
    h = kv_heads * num_q_per_kv
    t = w if tree_t is None else tree_t
    bs = min(block_s, s_cache)
    total = 0
    for length in lengths:
        live = _ceil_to(min(max(int(length), 0), s_cache), bs) if length else 0
        # committed cache: K+V payload (+ scales) + slot positions, live
        # blocks only
        total += 2 * live * kv_heads * head_dim * kv_itemsize
        total += 2 * live * kv_heads * scale_groups * 4
        total += live * 4                                   # kv_pos int32
        # queries in, output out (fp32), query positions
        total += 2 * w * h * head_dim * 4 + w * 4
        # fused tree segment: scratch K/V (never quantized) + ancestor mask
        total += 2 * t * kv_heads * head_dim * 4 + w * t
    return total


def verify_xla_bytes(*, w: int, kv_heads: int, num_q_per_kv: int,
                     head_dim: int, s_cache: int, batch: int,
                     tree_t: Optional[int] = None,
                     grouped: bool = False) -> int:
    """Modeled HBM bytes for ONE einsum-path cached_attention call (one
    layer): the full ``s_cache`` extent regardless of committed length, the
    materialized ``[B, W, S]`` visibility mask, and — on the ungrouped
    ``repeat_kv`` path — K/V blown up to all ``H`` heads at fp32."""
    h = kv_heads * num_q_per_kv
    t = w if tree_t is None else tree_t
    kv_read_heads = kv_heads if grouped else h
    per_row = (2 * s_cache * kv_read_heads * head_dim * 4     # K+V, full S
               + w * s_cache                                  # [W, S] mask
               + 2 * w * h * head_dim * 4                     # q in, out out
               + 2 * t * kv_heads * head_dim * 4 + w * t)     # tree segment
    return batch * per_row


def bytes_summary(*, w: int, kv_heads: int, num_q_per_kv: int, head_dim: int,
                  s_cache: int, lengths: Sequence[int], block_s: int = 256,
                  kv_itemsize: int = 4, scale_groups: int = 0) -> dict:
    """Kernel vs XLA-path traffic for one shape at given committed lengths,
    plus the two gateable ratios (repeat-kv blow-up recovered; bytes track
    length, not max_len)."""
    common = dict(w=w, kv_heads=kv_heads, num_q_per_kv=num_q_per_kv,
                  head_dim=head_dim, s_cache=s_cache)
    kern = verify_kernel_bytes(lengths=lengths, block_s=block_s,
                               kv_itemsize=kv_itemsize,
                               scale_groups=scale_groups, **common)
    repeated = verify_xla_bytes(batch=len(lengths), grouped=False, **common)
    grouped = verify_xla_bytes(batch=len(lengths), grouped=True, **common)
    return {"kernel_bytes": kern, "xla_repeated_bytes": repeated,
            "xla_grouped_bytes": grouped,
            "repeated_over_kernel": repeated / max(kern, 1),
            "grouped_over_kernel": grouped / max(kern, 1)}


def roofline_time_s(bytes_moved: int, hbm_gbps: float = 819.0) -> float:
    """Bandwidth-bound step-time estimate at a given HBM bandwidth (default:
    a v5e-class 819 GB/s) — what the latency profile's verify term should
    track if the kernel keeps the verify stage memory-bound."""
    return bytes_moved / (hbm_gbps * 1e9)


def block_count(length: int, s_cache: int, block_s: int) -> int:
    """Live kv-blocks the kernel touches for one row at ``length``."""
    bs = min(block_s, s_cache)
    return math.ceil(min(max(length, 0), s_cache) / bs) if length > 0 else 0
