"""Pallas TPU kernel: causal flash attention for prefill/train.

Grid = (batch*heads, q-blocks, kv-blocks) with the kv axis sequential;
online-softmax state lives in VMEM scratch. Fully-masked kv blocks above the
causal diagonal are skipped with pl.when (compute only the lower wedge —
this is the structural fix for the 2x causal FLOP waste of a naive mask,
see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, n_kb: int, block_q: int, block_k: int):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal wedge: kv block fully above the diagonal contributes nothing
    @pl.when(kb * block_k <= qb * block_q + block_q - 1)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)     # [bq, dh]
        k = k_ref[0, :, 0, :].astype(jnp.float32)     # [bk, dh]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        q_pos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev[:, 0], s.max(-1))[:, None]
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kb == n_kb - 1)
    def _done():
        o_ref[0, :, 0, :] = (acc_scr[...] /
                             jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "interpret"))
def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  block_q: int = 256, block_k: int = 256,
                  interpret: bool = True) -> jax.Array:
    """Causal attention. q/k/v: [B, S, H, dh] (kv head-repeated). -> [B,S,H,dh]."""
    B, S, H, dh = q.shape
    bq, bk = min(block_q, S), min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    n_qb, n_kb = S // bq, S // bk
    scale = 1.0 / (dh ** 0.5)

    kernel = functools.partial(_kernel, scale=scale, n_kb=n_kb,
                               block_q=bq, block_k=bk)
    return pl.pallas_call(
        kernel,
        grid=(B * H, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, bq, 1, dh), lambda bh, qb, kb: (bh // H, qb, bh % H, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda bh, qb, kb: (bh // H, kb, bh % H, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda bh, qb, kb: (bh // H, kb, bh % H, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, dh),
                               lambda bh, qb, kb: (bh // H, qb, bh % H, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
