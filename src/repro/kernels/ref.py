"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _expand_kv(q, k, v):
    """Repeat un-repeated [B,S,KV,dh] K/V up to q's H heads (oracle only —
    the kernels never materialize this)."""
    g = q.shape[2] // k.shape[2]
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    return k, v


def _dequant(x, s):
    """int8 payload [..., Dh] + fp32 scale groups [..., G] -> fp32."""
    g = s.shape[-1]
    xf = x.astype(jnp.float32).reshape(*x.shape[:-1], g, -1)
    return (xf * s[..., None]).reshape(x.shape)


def tree_attention_ref(q, k, v, mask):
    """q: [B,W,H,dh]; k/v: [B,S,KV,dh] un-repeated; mask: [B,W,S]."""
    k, v = _expand_kv(q, k, v)
    dh = q.shape[-1]
    s = jnp.einsum("bwhd,bshd->bhws", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(dh))
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhws,bshd->bwhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def tree_attention_int8_ref(q, k, v, k_scale, v_scale, mask):
    """Oracle for the dequantizing int8 kernel: dequantize the int8 K/V
    (per-slot, per-head scale groups along the head dim), then plain tree
    attention. k/v: [B,S,KV,dh] int8; k_scale/v_scale: [B,S,KV,G] fp32."""
    return tree_attention_ref(q, _dequant(k, k_scale), _dequant(v, v_scale),
                              mask)


def committed_mask_ref(kv_pos, q_pos, lengths):
    """[B, W, S] committed-prefix visibility — the mask the fused verify
    kernel computes in VMEM: slot occupied, committed, and strictly before
    the query position."""
    kp = kv_pos[:, None, :]
    qp = q_pos[:, :, None]
    return (kp >= 0) & (kp < lengths[:, None, None]) & (kp < qp)


def verify_attention_ref(q, k, v, kv_pos, q_pos, lengths, k_new, v_new,
                         tree_mask, k_scale=None, v_scale=None):
    """Oracle for the fused verify kernel: dequantize (if int8), concat the
    committed cache with the tree scratch, merge committed-prefix + ancestor
    masks, then plain tree attention. Same contract as
    ``tree_attention.verify_attention``."""
    if k_scale is not None:
        k, v = _dequant(k, k_scale), _dequant(v, v_scale)
    mask = jnp.concatenate(
        [committed_mask_ref(kv_pos, q_pos, lengths), tree_mask], axis=-1)
    kk = jnp.concatenate([k.astype(jnp.float32),
                          k_new.astype(jnp.float32)], axis=1)
    vv = jnp.concatenate([v.astype(jnp.float32),
                          v_new.astype(jnp.float32)], axis=1)
    return tree_attention_ref(q, kk, vv, mask)


def flash_prefill_ref(q, k, v):
    """Causal full attention. q/k/v: [B,S,H,dh]."""
    B, S, H, dh = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(dh))
    causal = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(causal[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_ref(x, dt, A, B, C, initial_state=None):
    """Exact sequential SSD recurrence (token by token).

    x: [b,s,h,p]; dt: [b,s,h]; A: [h]; B/C: [b,s,h,n].
    Returns (y [b,s,h,p], final_state [b,h,p,n]).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    st0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
           else initial_state.astype(jnp.float32))

    def step(st, inp):
        xt, dtt, Bt, Ct = inp
        decay = jnp.exp(dtt * A)                      # [b,h]
        st = st * decay[..., None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dtt, xt.astype(jnp.float32),
            Bt.astype(jnp.float32))
        y = jnp.einsum("bhpn,bhn->bhp", st, Ct.astype(jnp.float32))
        return st, y

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          B.transpose(1, 0, 2, 3), C.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, st0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final
