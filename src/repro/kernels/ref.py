"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_attention_ref(q, k, v, mask):
    """q: [B,W,H,dh]; k/v: [B,S,H,dh]; mask: [B,W,S]."""
    dh = q.shape[-1]
    s = jnp.einsum("bwhd,bshd->bhws", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(dh))
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhws,bshd->bwhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def tree_attention_int8_ref(q, k, v, k_scale, v_scale, mask):
    """Oracle for the dequantizing int8 kernel: dequantize the int8 K/V
    (per-slot, per-head scale groups along the head dim), then plain tree
    attention. k/v: [B,S,H,dh] int8; k_scale/v_scale: [B,S,H,G] fp32."""
    def dq(x, s):
        g = s.shape[-1]
        xf = x.astype(jnp.float32).reshape(*x.shape[:-1], g, -1)
        return (xf * s[..., None]).reshape(x.shape)
    return tree_attention_ref(q, dq(k, k_scale), dq(v, v_scale), mask)


def flash_prefill_ref(q, k, v):
    """Causal full attention. q/k/v: [B,S,H,dh]."""
    B, S, H, dh = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(dh))
    causal = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(causal[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_ref(x, dt, A, B, C, initial_state=None):
    """Exact sequential SSD recurrence (token by token).

    x: [b,s,h,p]; dt: [b,s,h]; A: [h]; B/C: [b,s,h,n].
    Returns (y [b,s,h,p], final_state [b,h,p,n]).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    st0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
           else initial_state.astype(jnp.float32))

    def step(st, inp):
        xt, dtt, Bt, Ct = inp
        decay = jnp.exp(dtt * A)                      # [b,h]
        st = st * decay[..., None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dtt, xt.astype(jnp.float32),
            Bt.astype(jnp.float32))
        y = jnp.einsum("bhpn,bhn->bhp", st, Ct.astype(jnp.float32))
        return st, y

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          B.transpose(1, 0, 2, 3), C.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, st0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final
