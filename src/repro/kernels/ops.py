"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) kernels run in interpret mode — the kernel body
executes step-by-step with real BlockSpec tiling semantics, which validates
indexing/accumulation logic; on TPU the same code lowers to Mosaic.
"""
from __future__ import annotations

import math
import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_prefill as _fp
from repro.kernels import ssd_scan as _ssd
from repro.kernels import tree_attention as _ta


def _interpret() -> bool:
    # REPRO_PALLAS_INTERPRET=1 forces interpret mode regardless of backend
    # (the CI `quant` job sets it so CPU-only runners exercise the kernel
    # bodies); =0 forces Mosaic lowering even on CPU (will fail fast there);
    # unset OR empty falls back to backend inference.
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip()
    if env:
        return env.lower() not in ("0", "false", "off", "no")
    return jax.default_backend() == "cpu"


# kv-block width of the fused verify hot path — the skip granularity of the
# length-aware early-out. One constant shared by the dispatch
# (models/attention.py), the analytic traffic model users
# (benchmarks/fig_kernel.py, fig_serving.kernel_traffic) and the regression
# gate, so the gated length-scaling ratio is the deployed kernel's, not a
# benchmark-only configuration.
VERIFY_BLOCK_S = 128


def block_pad(s: int, block: int) -> Tuple[int, int]:
    """(block_size, pad) so that ``(s + pad) % block_size == 0``.

    The old ``while s % bs: bs //= 2`` fallback silently degraded to
    scalar (bs=1) blocks for odd/prime ``s`` — thousands of grid steps and
    no MXU tiling. Instead keep the block size and pad ``s`` up to the next
    multiple (as ``ssd_scan`` always has); callers mask or slice the pad
    away. ``s <= block`` needs neither: one block of exactly ``s``.
    """
    bs = min(block, s)
    return bs, (-s) % bs


def tree_attention(q, k, v, mask, *, k_scale=None, v_scale=None,
                   block_s: int = 256):
    """Tree-masked verification attention (see tree_attention.py).

    GQA-native contract: k/v are the cache's own **un-repeated**
    [B, S, KV, dh] layout (KV must divide q's H). Pass ``k_scale``/
    ``v_scale`` ([B, S, KV, G] fp32 scale groups along the head dim, with
    int8 k/v — the pair ``repro.quant.quantize_kv`` returns) to route
    through the dequantizing int8 kernel variant; omit for the fp path.
    Non-block-multiple S is padded up (masked False), never degraded to
    scalar blocks.
    """
    if (k_scale is None) != (v_scale is None):
        raise ValueError("pass both k_scale and v_scale, or neither")
    S = k.shape[1]
    bs, pad = block_pad(S, block_s)
    if pad:
        kv_pad = ((0, 0), (0, pad), (0, 0), (0, 0))
        k, v = jnp.pad(k, kv_pad), jnp.pad(v, kv_pad)
        mask = jnp.pad(mask, ((0, 0), (0, 0), (0, pad)))  # padded slots False
        if k_scale is not None:  # neutral scales keep the int8 dequant exact
            k_scale = jnp.pad(k_scale, kv_pad, constant_values=1.0)
            v_scale = jnp.pad(v_scale, kv_pad, constant_values=1.0)
    if k_scale is not None:
        return _ta.tree_attention_int8(q, k, v, k_scale, v_scale, mask,
                                       block_s=bs, interpret=_interpret())
    return _ta.tree_attention(q, k, v, mask, block_s=bs,
                              interpret=_interpret())


def verify_attention(q, k, v, kv_pos, q_pos, lengths, k_new, v_new,
                     tree_mask, *, k_scale=None, v_scale=None,
                     block_s: int = VERIFY_BLOCK_S):
    """Fused, length-aware verification attention — the megastep hot path
    (see tree_attention.verify_attention for the full contract).

    q [B,W,H,dh] against the committed cache k/v [B,S,KV,dh] (+ int8 scales
    when quantized) under the in-kernel committed-prefix mask derived from
    ``kv_pos``/``q_pos``/``lengths``, plus the [B,T,KV,dh] tree scratch
    under ``tree_mask`` [B,W,T]. kv-blocks past each slot's committed
    length are skipped (compute and HBM fetch), so verify traffic scales
    with the live cache, not its max_len extent.
    """
    if (k_scale is None) != (v_scale is None):
        raise ValueError("pass both k_scale and v_scale, or neither")
    S = k.shape[1]
    bs, pad = block_pad(S, block_s)
    if pad:  # pathological cache extents only; padded slots carry pos=-1
        kv_pad = ((0, 0), (0, pad), (0, 0), (0, 0))
        k, v = jnp.pad(k, kv_pad), jnp.pad(v, kv_pad)
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, kv_pad, constant_values=1.0)
            v_scale = jnp.pad(v_scale, kv_pad, constant_values=1.0)
    if k_scale is not None:
        return _ta.verify_attention_int8(
            q, k, v, k_scale, v_scale, kv_pos, q_pos, lengths, k_new, v_new,
            tree_mask, block_s=bs, interpret=_interpret())
    return _ta.verify_attention(q, k, v, kv_pos, q_pos, lengths, k_new,
                                v_new, tree_mask, block_s=bs,
                                interpret=_interpret())


def verify_attention_paged(q, k, v, kv_pos, table, q_pos, lengths, k_new,
                           v_new, tree_mask, *, k_scale=None, v_scale=None):
    """Fused verify over a **paged** cache (see
    tree_attention.verify_attention_paged for the full contract).

    k/v: the shared page pool [P, page_len, KV, dh] (+ scales
    [P, page_len, KV, G] when int8); kv_pos [P, page_len]; table [B, T]
    per-slot page table. No padding path: the pool's page axis IS the block
    axis (one page == one kv-block), so alignment is structural. The skip
    granularity is page_len — small pages trade early-out precision against
    grid length, exactly the contiguous block_s trade-off.
    """
    if (k_scale is None) != (v_scale is None):
        raise ValueError("pass both k_scale and v_scale, or neither")
    if k_scale is not None:
        return _ta.verify_attention_paged_int8(
            q, k, v, k_scale, v_scale, kv_pos, table, q_pos, lengths, k_new,
            v_new, tree_mask, interpret=_interpret())
    return _ta.verify_attention_paged(q, k, v, kv_pos, table, q_pos,
                                      lengths, k_new, v_new, tree_mask,
                                      interpret=_interpret())


def flash_prefill(q, k, v, *, block_q: int = 256, block_k: int = 256):
    """Causal flash attention with wedge skipping (see flash_prefill.py).

    Non-block-multiple S is padded up to a common multiple of both block
    sizes and the pad rows sliced off (padded keys sit above every real
    query's causal horizon, so they never contribute).
    """
    S = q.shape[1]
    bq, _ = block_pad(S, block_q)
    bk, _ = block_pad(S, block_k)
    pad = (-S) % math.lcm(bq, bk)
    if pad:
        qkv_pad = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, qkv_pad)
        k, v = jnp.pad(k, qkv_pad), jnp.pad(v, qkv_pad)
    out = _fp.flash_prefill(q, k, v, block_q=bq, block_k=bk,
                            interpret=_interpret())
    return out[:, :S] if pad else out


def ssd_scan(x, dt, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD using the Pallas per-chunk kernel + host chunk recurrence.

    Same contract as models.ssm.ssd_scan: x [b,s,h,p], dt [b,s,h] (already
    softplus'ed), A [h] (negative), B/C [b,s,h,n] (groups expanded).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    L = min(chunk, s)
    orig_s = s
    if s % L:
        pad = L - s % L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s += pad
    c = s // L

    # [b, h, c, L, ...] layout for the kernel
    xr = x.reshape(b, c, L, h, p).transpose(0, 3, 1, 2, 4)
    dtr = dt.reshape(b, c, L, h).transpose(0, 3, 1, 2)
    Br = B.reshape(b, c, L, h, n).transpose(0, 3, 1, 2, 4)
    Cr = C.reshape(b, c, L, h, n).transpose(0, 3, 1, 2, 4)

    zeros_prev = jnp.zeros((b, h, c, p, n), jnp.float32)
    y_diag, deltas = _ssd.ssd_chunk(xr, dtr, A.astype(jnp.float32), Br, Cr,
                                    zeros_prev, interpret=_interpret())

    # chunk recurrence (tiny, sequential)
    dA_cs = jnp.cumsum(dtr * A[None, :, None, None], axis=-1)   # [b,h,c,L]
    chunk_decay = jnp.exp(dA_cs[..., -1])                        # [b,h,c]
    st0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
           else initial_state.astype(jnp.float32))

    def step(carry, inp):
        delta, dec = inp
        new = carry * dec[..., None, None] + delta
        return new, carry

    final, prev = jax.lax.scan(
        step, st0, (deltas.transpose(2, 0, 1, 3, 4),
                    chunk_decay.transpose(2, 0, 1)))
    prev = prev.transpose(1, 2, 0, 3, 4)                         # [b,h,c,p,n]

    y_off = jnp.einsum("bhcln,bhcpn,bhcl->bhclp", Cr.astype(jnp.float32),
                       prev, jnp.exp(dA_cs))
    y = (y_diag.astype(jnp.float32) + y_off)
    y = y.transpose(0, 2, 3, 1, 4).reshape(b, s, h, p)[:, :orig_s]
    return y.astype(x.dtype), final
