"""Pallas TPU kernel: Mamba2 SSD chunk computation [arXiv:2405.21060].

The SSD algorithm splits into (a) heavy per-chunk dense algebra — the
intra-chunk output block, the chunk-end state contribution, and the
cross-chunk output given the entering state — and (b) a tiny sequential
recurrence over chunk-end states. (a) maps onto the MXU and is implemented
here per (batch, head, chunk) grid cell with everything VMEM-resident;
(b) stays a lax.scan in ops.py (it is O(heads·P·N) per chunk — negligible).

The kernel computes, for one chunk of length L:
    y_diag  = ((C Bᵀ) ∘ decay) (x·dt)        intra-chunk
    state   = Bᵀ ((decay_end·dt) ∘ x)        chunk-end state delta
    y_off   = (C prev_state) ∘ decay_in      cross-chunk (uses scanned state)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, prev_ref,
                  y_ref, st_ref):
    x = x_ref[0, 0, 0].astype(jnp.float32)       # [L, P]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)     # [L]
    A = a_ref[0]                                  # [] scalar (per head)
    Bm = b_ref[0, 0, 0].astype(jnp.float32)      # [L, N]
    Cm = c_ref[0, 0, 0].astype(jnp.float32)      # [L, N]
    prev = prev_ref[0, 0, 0].astype(jnp.float32)  # [P, N] state entering chunk

    dA = dt * A                                # [L]
    cs = jnp.cumsum(dA)                        # [L]
    # decay matrix exp(segsum) lower-tri
    seg = cs[:, None] - cs[None, :]            # [L, L]
    li = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1)
    Lmat = jnp.where(li >= lj, jnp.exp(seg), 0.0)

    CB = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)  # [L, L]
    M = CB * Lmat
    xdt = x * dt[:, None]
    y_diag = jnp.dot(M, xdt, preferred_element_type=jnp.float32)  # [L, P]

    decay_in = jnp.exp(cs)[:, None]            # [L, 1]
    y_off = jnp.dot(Cm, prev.T, preferred_element_type=jnp.float32) * decay_in

    decay_end = jnp.exp(cs[-1] - cs)           # [L]
    st = jnp.dot((Bm * (decay_end * dt)[:, None]).T, x,
                 preferred_element_type=jnp.float32)              # [N, P]

    y_ref[0, 0, 0] = (y_diag + y_off).astype(y_ref.dtype)
    st_ref[0, 0, 0] = st.T.astype(st_ref.dtype)   # [P, N]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
              C: jax.Array, prev_states: jax.Array, *,
              interpret: bool = True):
    """x: [b, h, c, L, P]; dt: [b, h, c, L]; A: [h]; B/C: [b, h, c, L, N];
    prev_states: [b, h, c, P, N] (state entering each chunk, from the host
    scan). Returns (y [b, h, c, L, P], state_deltas [b, h, c, P, N])."""
    b, h, c, L, P = x.shape
    N = B.shape[-1]
    grid = (b * h, c)
    return pl.pallas_call(
        _chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, L, P), lambda bh, ci: (bh // h, bh % h, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, L), lambda bh, ci: (bh // h, bh % h, ci, 0)),
            pl.BlockSpec((1,), lambda bh, ci: (bh % h,)),
            pl.BlockSpec((1, 1, 1, L, N), lambda bh, ci: (bh // h, bh % h, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, L, N), lambda bh, ci: (bh // h, bh % h, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda bh, ci: (bh // h, bh % h, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, L, P), lambda bh, ci: (bh // h, bh % h, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda bh, ci: (bh // h, bh % h, ci, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, c, L, P), x.dtype),
            jax.ShapeDtypeStruct((b, h, c, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A, B, C, prev_states)
