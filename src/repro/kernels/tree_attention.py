"""Pallas TPU kernel: tree-masked verification attention (the paper's
verification hot spot, cf. FastTree [36]).

W tree queries attend to an S-slot committed KV cache under an arbitrary
boolean visibility mask (committed-causality + ancestor mask merged by the
caller). Flash-decode style: grid = (batch, heads, kv-blocks), with the
kv-block axis innermost/sequential; running max / denominator / accumulator
persist in VMEM scratch across kv blocks and the output is normalized in the
final block.

Block shapes: q tile [W, dh] and kv tiles [block_s, dh] live in VMEM; W and
dh are MXU-friendly (multiples of 8×128 after padding by the wrapper).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _vmem(shape, dtype):
    """VMEM scratch allocation (TPU); falls back cleanly in interpret mode."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, n_kb: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)        # [W, dh]
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # [bs, dh]
    v = v_ref[0, :, 0, :].astype(jnp.float32)        # [bs, dh]
    mask = mask_ref[0, :, :]                          # [W, bs]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [W, bs]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                               # [W, 1]
    m_new = jnp.maximum(m_prev[:, 0], s.max(-1))[:, None]
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_scr[...] * alpha + p.sum(-1, keepdims=True)
    acc_new = acc_scr[...] * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(kb == n_kb - 1)
    def _done():
        o_ref[0, :, 0, :] = (acc_scr[...] /
                             jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _qkernel(q_ref, k_ref, v_ref, ks_ref, vs_ref, mask_ref, o_ref,
             m_scr, l_scr, acc_scr, *, scale: float, n_kb: int):
    """int8 variant: K/V tiles arrive as int8 and are dequantized in VMEM —
    fp32 scales per kv slot (sub-grouped along the head dim) broadcast over
    their channel groups — so HBM traffic on the bandwidth-bound verify hot
    spot is ~4x smaller. Accumulation is identical fp32 online softmax."""
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)         # [W, dh]
    bs, dh = k_ref.shape[1], k_ref.shape[3]
    g = ks_ref.shape[3]                               # scale groups per head
    ks = ks_ref[0, :, 0, :]                           # [bs, G]
    vs = vs_ref[0, :, 0, :]
    # dequant in VMEM: int8 tile -> [bs, G, dh/G] * scale -> [bs, dh]
    k = (k_ref[0, :, 0, :].astype(jnp.float32).reshape(bs, g, dh // g)
         * ks[:, :, None]).reshape(bs, dh)
    v = (v_ref[0, :, 0, :].astype(jnp.float32).reshape(bs, g, dh // g)
         * vs[:, :, None]).reshape(bs, dh)
    mask = mask_ref[0, :, :]                          # [W, bs]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev[:, 0], s.max(-1))[:, None]
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_scr[...] * alpha + p.sum(-1, keepdims=True)
    acc_new = acc_scr[...] * alpha + jnp.dot(p, v,
                                             preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(kb == n_kb - 1)
    def _done():
        o_ref[0, :, 0, :] = (acc_scr[...] /
                             jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def tree_attention_int8(q: jax.Array, k: jax.Array, v: jax.Array,
                        k_scale: jax.Array, v_scale: jax.Array,
                        mask: jax.Array, *, block_s: int = 256,
                        interpret: bool = True) -> jax.Array:
    """q: [B, W, H, dh] fp; k/v: [B, S, H, dh] int8 (head-repeated);
    k_scale/v_scale: [B, S, H, G] fp32 per-slot, per-head scale groups
    (G divides dh); mask: [B, W, S]. Returns [B, W, H, dh] at q's dtype."""
    assert k.dtype == jnp.int8 and v.dtype == jnp.int8, (k.dtype, v.dtype)
    B, W, H, dh = q.shape
    S = k.shape[1]
    G = k_scale.shape[-1]
    assert dh % G == 0, (dh, G)
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)
    n_kb = S // bs
    scale = 1.0 / (dh ** 0.5)

    kernel = functools.partial(_qkernel, scale=scale, n_kb=n_kb)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, 1, n_kb),
        in_specs=[
            pl.BlockSpec((1, W, 1, dh), lambda bh, _, kb: (bh // H, 0, bh % H, 0)),
            pl.BlockSpec((1, bs, 1, dh), lambda bh, _, kb: (bh // H, kb, bh % H, 0)),
            pl.BlockSpec((1, bs, 1, dh), lambda bh, _, kb: (bh // H, kb, bh % H, 0)),
            pl.BlockSpec((1, bs, 1, G), lambda bh, _, kb: (bh // H, kb, bh % H, 0)),
            pl.BlockSpec((1, bs, 1, G), lambda bh, _, kb: (bh // H, kb, bh % H, 0)),
            pl.BlockSpec((1, W, bs), lambda bh, _, kb: (bh // H, 0, kb)),
        ],
        out_specs=pl.BlockSpec((1, W, 1, dh), lambda bh, _, kb: (bh // H, 0, bh % H, 0)),
        out_shape=jax.ShapeDtypeStruct((B, W, H, dh), q.dtype),
        scratch_shapes=[
            _vmem((W, 1), jnp.float32),
            _vmem((W, 1), jnp.float32),
            _vmem((W, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, k_scale, v_scale, mask)
    return out


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def tree_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   mask: jax.Array, *, block_s: int = 256,
                   interpret: bool = True) -> jax.Array:
    """q: [B, W, H, dh]; k/v: [B, S, H, dh] (kv already head-repeated);
    mask: [B, W, S] visibility (tree + causality merged). Returns [B, W, H, dh].
    """
    B, W, H, dh = q.shape
    S = k.shape[1]
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)
    n_kb = S // bs
    scale = 1.0 / (dh ** 0.5)

    kernel = functools.partial(_kernel, scale=scale, n_kb=n_kb)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, 1, n_kb),
        in_specs=[
            pl.BlockSpec((1, W, 1, dh), lambda bh, _, kb: (bh // H, 0, bh % H, 0)),
            pl.BlockSpec((1, bs, 1, dh), lambda bh, _, kb: (bh // H, kb, bh % H, 0)),
            pl.BlockSpec((1, bs, 1, dh), lambda bh, _, kb: (bh // H, kb, bh % H, 0)),
            pl.BlockSpec((1, W, bs), lambda bh, _, kb: (bh // H, 0, kb)),
        ],
        out_specs=pl.BlockSpec((1, W, 1, dh), lambda bh, _, kb: (bh // H, 0, bh % H, 0)),
        out_shape=jax.ShapeDtypeStruct((B, W, H, dh), q.dtype),
        scratch_shapes=[
            _vmem((W, 1), jnp.float32),
            _vmem((W, 1), jnp.float32),
            _vmem((W, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, mask)
    return out
