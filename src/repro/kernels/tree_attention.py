"""Pallas TPU kernels: tree-masked verification attention (the paper's
verification hot spot, cf. FastTree [36] / SpecInfer's tree kernel).

Both kernels are **GQA-native**: the grid runs over (batch, KV heads,
kv-blocks) and each kv-head processes a ``[G·W, dh]`` query tile (G =
num_q_per_kv query heads folded into the row axis), so every K/V tile is
read from HBM exactly once per group instead of being materialized G× by
``repeat_kv``. K/V arrive un-repeated as ``[B, S, KV, dh]`` — the cache's
own layout. Flash-decode style: the kv-block axis is innermost/sequential;
running max / denominator / accumulator persist in VMEM scratch across kv
blocks and the output is normalized in the final block.

Two entry points:

* ``tree_attention`` / ``tree_attention_int8`` — generic visibility-mask
  variant (caller supplies ``[B, W, S]`` bool); the standalone op and the
  oracle-diff target.
* ``verify_attention`` / ``verify_attention_int8`` — the serving hot path.
  Fully fused and **length-aware**: per-slot committed ``lengths`` are
  scalar-prefetched so (a) kv-blocks past ``ceil(len/block_s)`` are skipped
  with ``pl.when`` AND their HBM fetch is elided by clamping the block
  index map to the last live block (Pallas skips the copy when the block
  index repeats — the flash-decoding early-out), and (b) the committed-
  prefix causal mask is computed *in kernel* from ``kv_pos``/``q_pos``
  instead of a materialized ``[B, W, S]`` mask (itself O(B·W·max_len) HBM
  per layer). The W in-flight tree tokens (``k_new``/``v_new`` scratch) are
  folded into the same online-softmax pass as a final grid step under the
  ``[W, T]`` ancestor mask — no concat, no second dispatch.

Shapes stay static: ``lengths`` is a traced operand and the grid is sized
by ``S``/``block_s``, so the zero-recompile executable-cache contract of
the megastep survives untouched.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _vmem(shape, dtype):
    """VMEM scratch allocation (TPU); falls back cleanly in interpret mode."""
    return pltpu.VMEM(shape, dtype)


def _dequant_tile(x_ref, s_ref):
    """int8 tile [bs, dh] * fp32 scale groups [bs, G] -> fp32 [bs, dh]."""
    bs, dh = x_ref.shape[1], x_ref.shape[3]
    g = s_ref.shape[3]
    x = x_ref[0, :, 0, :].astype(jnp.float32).reshape(bs, g, dh // g)
    return (x * s_ref[0, :, 0, :][:, :, None]).reshape(bs, dh)


def _flash_update(s, v, m_scr, l_scr, acc_scr):
    """One online-softmax accumulation step. s: [rows, bs]; v: [bs, dh]."""
    m_prev = m_scr[...]                               # [rows, 1]
    m_new = jnp.maximum(m_prev[:, 0], s.max(-1))[:, None]
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    m_scr[...] = m_new
    l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)


def _normalize_out(o_ref, m_scr, l_scr, acc_scr):
    o_ref[0, 0] = (acc_scr[...] /
                   jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


# ------------------------------------------------ generic-mask variant ----
def _masked_kernel(*refs, scale: float, n_kb: int, g: int, w: int,
                   quantized: bool):
    if quantized:
        (q_ref, k_ref, v_ref, ks_ref, vs_ref, mask_ref, o_ref,
         m_scr, l_scr, acc_scr) = refs
    else:
        (q_ref, k_ref, v_ref, mask_ref, o_ref, m_scr, l_scr, acc_scr) = refs
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)               # [G·W, dh]
    if quantized:
        k = _dequant_tile(k_ref, ks_ref)              # [bs, dh]
        v = _dequant_tile(v_ref, vs_ref)
    else:
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
    mask = mask_ref[0]                                # [W, bs]
    bs = k.shape[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[None], s.reshape(g, w, bs), NEG_INF).reshape(g * w, bs)
    _flash_update(s, v, m_scr, l_scr, acc_scr)

    @pl.when(kb == n_kb - 1)
    def _done():
        _normalize_out(o_ref, m_scr, l_scr, acc_scr)


def _masked_call(q, k, v, mask, scales, *, block_s: int, interpret: bool):
    B, W, H, dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    G = H // KV
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)
    n_kb = S // bs
    scale = 1.0 / (dh ** 0.5)
    # fold the G query heads of each kv-head into the row axis: [B,KV,G·W,dh]
    qt = q.reshape(B, W, KV, G, dh).transpose(0, 2, 3, 1, 4).reshape(
        B, KV, G * W, dh)

    kernel = functools.partial(_masked_kernel, scale=scale, n_kb=n_kb,
                               g=G, w=W, quantized=scales is not None)
    in_specs = [
        pl.BlockSpec((1, 1, G * W, dh), lambda b, h, kb: (b, h, 0, 0)),
        pl.BlockSpec((1, bs, 1, dh), lambda b, h, kb: (b, kb, h, 0)),
        pl.BlockSpec((1, bs, 1, dh), lambda b, h, kb: (b, kb, h, 0)),
    ]
    args = [qt, k, v]
    if scales is not None:
        gs = scales[0].shape[-1]
        in_specs += [
            pl.BlockSpec((1, bs, 1, gs), lambda b, h, kb: (b, kb, h, 0)),
            pl.BlockSpec((1, bs, 1, gs), lambda b, h, kb: (b, kb, h, 0)),
        ]
        args += list(scales)
    in_specs.append(pl.BlockSpec((1, W, bs), lambda b, h, kb: (b, 0, kb)))
    args.append(mask)

    out = pl.pallas_call(
        kernel,
        grid=(B, KV, n_kb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G * W, dh), lambda b, h, kb: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G * W, dh), q.dtype),
        scratch_shapes=[
            _vmem((G * W, 1), jnp.float32),
            _vmem((G * W, 1), jnp.float32),
            _vmem((G * W, dh), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
    return out.reshape(B, KV, G, W, dh).transpose(0, 3, 1, 2, 4).reshape(
        B, W, H, dh)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def tree_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   mask: jax.Array, *, block_s: int = 256,
                   interpret: bool = True) -> jax.Array:
    """q: [B, W, H, dh]; k/v: [B, S, KV, dh] **un-repeated** (KV divides H);
    mask: [B, W, S] visibility (tree + causality merged by the caller).
    Returns [B, W, H, dh] at q's dtype."""
    return _masked_call(q, k, v, mask, None, block_s=block_s,
                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def tree_attention_int8(q: jax.Array, k: jax.Array, v: jax.Array,
                        k_scale: jax.Array, v_scale: jax.Array,
                        mask: jax.Array, *, block_s: int = 256,
                        interpret: bool = True) -> jax.Array:
    """int8 variant: k/v [B, S, KV, dh] int8 with fp32 per-slot scale groups
    k_scale/v_scale [B, S, KV, G] (G divides dh), dequantized in VMEM so the
    bandwidth-bound hot spot reads ~4x fewer HBM bytes. Accumulation is
    identical fp32 online softmax."""
    assert k.dtype == jnp.int8 and v.dtype == jnp.int8, (k.dtype, v.dtype)
    assert q.shape[-1] % k_scale.shape[-1] == 0, (q.shape, k_scale.shape)
    return _masked_call(q, k, v, mask, (k_scale, v_scale), block_s=block_s,
                        interpret=interpret)


# --------------------------------------------- fused verify (hot path) ----
def _verify_kernel(len_ref, *refs, scale: float, n_kb: int, block_s: int,
                   g: int, w: int, t: int, quantized: bool):
    if quantized:
        (q_ref, k_ref, v_ref, ks_ref, vs_ref, kpos_ref, qpos_ref,
         kn_ref, vn_ref, tm_ref, o_ref, m_scr, l_scr, acc_scr) = refs
    else:
        (q_ref, k_ref, v_ref, kpos_ref, qpos_ref,
         kn_ref, vn_ref, tm_ref, o_ref, m_scr, l_scr, acc_scr) = refs
    b = pl.program_id(0)
    kb = pl.program_id(2)
    length = len_ref[b]

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)               # [G·W, dh]

    # committed-cache blocks: skipped entirely (compute AND fetch — the
    # index map clamps dead blocks onto the last live one, which Pallas
    # does not re-copy) once past the committed length
    @pl.when((kb < n_kb) & (kb * block_s < length))
    def _cache_block():
        if quantized:
            k = _dequant_tile(k_ref, ks_ref)          # [bs, dh]
            v = _dequant_tile(v_ref, vs_ref)
        else:
            k = k_ref[0, :, 0, :].astype(jnp.float32)
            v = v_ref[0, :, 0, :].astype(jnp.float32)
        kp = kpos_ref[0]                              # [bs]
        qp = qpos_ref[0]                              # [W]
        # committed-prefix visibility, computed in VMEM instead of read
        # from a materialized [B, W, S] mask
        mask = ((kp[None, :] >= 0) & (kp[None, :] < length)
                & (kp[None, :] < qp[:, None]))        # [W, bs]
        bs = k.shape[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask[None], s.reshape(g, w, bs),
                      NEG_INF).reshape(g * w, bs)
        _flash_update(s, v, m_scr, l_scr, acc_scr)

    # final grid step: the W in-flight tree tokens under the ancestor mask,
    # fused into the same online softmax; output normalized here
    @pl.when(kb == n_kb)
    def _tree_segment():
        kt = kn_ref[0, :, 0, :].astype(jnp.float32)   # [T, dh]
        vt = vn_ref[0, :, 0, :].astype(jnp.float32)
        tm = tm_ref[0]                                # [W, T]
        s = jnp.dot(q, kt.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(tm[None], s.reshape(g, w, t), NEG_INF).reshape(g * w, t)
        _flash_update(s, vt, m_scr, l_scr, acc_scr)
        _normalize_out(o_ref, m_scr, l_scr, acc_scr)


def _verify_call(q, k, v, kv_pos, q_pos, lengths, k_new, v_new, tree_mask,
                 scales, *, block_s: int, interpret: bool):
    B, W, H, dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    T = k_new.shape[1]
    assert H % KV == 0, (H, KV)
    G = H // KV
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)
    n_kb = S // bs
    scale = 1.0 / (dh ** 0.5)
    qt = q.reshape(B, W, KV, G, dh).transpose(0, 2, 3, 1, 4).reshape(
        B, KV, G * W, dh)
    lengths = lengths.astype(jnp.int32)

    def live(lens, b):
        # index of the last kv-block holding committed tokens (>= 0)
        return jnp.maximum(pl.cdiv(lens[b], bs), 1) - 1

    def cache_ix(b, h, kb, lens):
        # clamp dead blocks (and the tree step) onto the last live block so
        # their HBM fetch degenerates to a no-op repeat
        return (b, jnp.minimum(kb, live(lens, b)), h, 0)

    kernel = functools.partial(_verify_kernel, scale=scale, n_kb=n_kb,
                               block_s=bs, g=G, w=W, t=T,
                               quantized=scales is not None)
    in_specs = [
        pl.BlockSpec((1, 1, G * W, dh), lambda b, h, kb, lens: (b, h, 0, 0)),
        pl.BlockSpec((1, bs, 1, dh), cache_ix),
        pl.BlockSpec((1, bs, 1, dh), cache_ix),
    ]
    args = [qt, k, v]
    if scales is not None:
        gs = scales[0].shape[-1]
        in_specs += [pl.BlockSpec((1, bs, 1, gs), cache_ix),
                     pl.BlockSpec((1, bs, 1, gs), cache_ix)]
        args += list(scales)
    in_specs += [
        pl.BlockSpec((1, bs),
                     lambda b, h, kb, lens: (b, jnp.minimum(kb, live(lens, b)))),
        pl.BlockSpec((1, W), lambda b, h, kb, lens: (b, 0)),
        pl.BlockSpec((1, T, 1, dh), lambda b, h, kb, lens: (b, 0, h, 0)),
        pl.BlockSpec((1, T, 1, dh), lambda b, h, kb, lens: (b, 0, h, 0)),
        pl.BlockSpec((1, W, T), lambda b, h, kb, lens: (b, 0, 0)),
    ]
    args += [kv_pos, q_pos, k_new, v_new, tree_mask]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, n_kb + 1),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G * W, dh),
                               lambda b, h, kb, lens: (b, h, 0, 0)),
        scratch_shapes=[
            _vmem((G * W, 1), jnp.float32),
            _vmem((G * W, 1), jnp.float32),
            _vmem((G * W, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G * W, dh), q.dtype),
        interpret=interpret,
    )(lengths, *args)
    return out.reshape(B, KV, G, W, dh).transpose(0, 3, 1, 2, 4).reshape(
        B, W, H, dh)


def _verify_call_paged(q, k_pool, v_pool, pos_pool, table, q_pos, lengths,
                       k_new, v_new, tree_mask, scales, *, interpret: bool):
    """Paged-pool variant of ``_verify_call``: same kernel body, but the
    kv-block axis walks each slot's **page table** instead of a contiguous
    row. The table joins ``lengths`` as a second scalar-prefetch operand so
    the block index map can resolve ``virtual block -> pool page`` on the
    scalar core before the DMA is issued; the length clamp then degenerates
    dead virtual blocks onto the last live page exactly as the contiguous
    path does (repeat -> no re-fetch). One page == one kv-block, so the
    early-out skip granularity is ``page_len``.
    """
    B, W, H, dh = q.shape
    page_len, KV = k_pool.shape[1], k_pool.shape[2]
    Tp = table.shape[1]      # pages per slot == virtual kv-blocks
    Tn = k_new.shape[1]      # in-flight tree nodes
    assert H % KV == 0, (H, KV)
    G = H // KV
    scale = 1.0 / (dh ** 0.5)
    qt = q.reshape(B, W, KV, G, dh).transpose(0, 2, 3, 1, 4).reshape(
        B, KV, G * W, dh)
    lengths = lengths.astype(jnp.int32)
    table = table.astype(jnp.int32)

    def live(lens, b):
        # last virtual block holding committed tokens (>= 0)
        return jnp.maximum(pl.cdiv(lens[b], page_len), 1) - 1

    def page_ix(b, h, kb, lens, tbl):
        # scalar-prefetched page-table lookup; dead/tree blocks clamp onto
        # the last live page (repeated index -> Pallas skips the copy).
        # Reset slots point every row at the trash page, also harmless.
        return (tbl[b, jnp.minimum(kb, live(lens, b))], 0, h, 0)

    def pos_ix(b, h, kb, lens, tbl):
        return (tbl[b, jnp.minimum(kb, live(lens, b))], 0)

    kernel = functools.partial(_verify_kernel, scale=scale, n_kb=Tp,
                               block_s=page_len, g=G, w=W, t=Tn,
                               quantized=scales is not None)

    def paged_kernel(len_ref, tbl_ref, *refs):
        del tbl_ref  # consumed by the index maps only
        kernel(len_ref, *refs)

    in_specs = [
        pl.BlockSpec((1, 1, G * W, dh),
                     lambda b, h, kb, lens, tbl: (b, h, 0, 0)),
        pl.BlockSpec((1, page_len, 1, dh), page_ix),
        pl.BlockSpec((1, page_len, 1, dh), page_ix),
    ]
    args = [qt, k_pool, v_pool]
    if scales is not None:
        gs = scales[0].shape[-1]
        in_specs += [pl.BlockSpec((1, page_len, 1, gs), page_ix),
                     pl.BlockSpec((1, page_len, 1, gs), page_ix)]
        args += list(scales)
    in_specs += [
        pl.BlockSpec((1, page_len), pos_ix),
        pl.BlockSpec((1, W), lambda b, h, kb, lens, tbl: (b, 0)),
        pl.BlockSpec((1, Tn, 1, dh), lambda b, h, kb, lens, tbl: (b, 0, h, 0)),
        pl.BlockSpec((1, Tn, 1, dh), lambda b, h, kb, lens, tbl: (b, 0, h, 0)),
        pl.BlockSpec((1, W, Tn), lambda b, h, kb, lens, tbl: (b, 0, 0)),
    ]
    args += [pos_pool, q_pos, k_new, v_new, tree_mask]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, Tp + 1),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G * W, dh),
                               lambda b, h, kb, lens, tbl: (b, h, 0, 0)),
        scratch_shapes=[
            _vmem((G * W, 1), jnp.float32),
            _vmem((G * W, 1), jnp.float32),
            _vmem((G * W, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        paged_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G * W, dh), q.dtype),
        interpret=interpret,
    )(lengths, table, *args)
    return out.reshape(B, KV, G, W, dh).transpose(0, 3, 1, 2, 4).reshape(
        B, W, H, dh)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def verify_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_pos: jax.Array, q_pos: jax.Array, lengths: jax.Array,
                     k_new: jax.Array, v_new: jax.Array,
                     tree_mask: jax.Array, *, block_s: int = 256,
                     interpret: bool = True) -> jax.Array:
    """Fused, length-aware verification attention (the megastep hot path).

    q: [B, W, H, dh] tree queries; k/v: [B, S, KV, dh] the committed cache,
    un-repeated; kv_pos: [B, S] absolute position per slot (-1 empty);
    q_pos: [B, W] query positions; lengths: [B] committed lengths (drives
    kv-block skipping — HBM traffic scales with the committed length, not
    S); k_new/v_new: [B, T, KV, dh] in-flight tree-node K/V; tree_mask:
    [B, W, T] ancestor-or-self. Returns [B, W, H, dh] at q's dtype.
    """
    return _verify_call(q, k, v, kv_pos, q_pos, lengths, k_new, v_new,
                        tree_mask, None, block_s=block_s, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def verify_attention_int8(q: jax.Array, k: jax.Array, v: jax.Array,
                          k_scale: jax.Array, v_scale: jax.Array,
                          kv_pos: jax.Array, q_pos: jax.Array,
                          lengths: jax.Array, k_new: jax.Array,
                          v_new: jax.Array, tree_mask: jax.Array, *,
                          block_s: int = 256,
                          interpret: bool = True) -> jax.Array:
    """``verify_attention`` over an int8 cache: k/v int8 payload with fp32
    scale groups [B, S, KV, G] dequantized in VMEM; the tree-scratch K/V
    (in-flight, never quantized) stay at their own dtype."""
    assert k.dtype == jnp.int8 and v.dtype == jnp.int8, (k.dtype, v.dtype)
    return _verify_call(q, k, v, kv_pos, q_pos, lengths, k_new, v_new,
                        tree_mask, (k_scale, v_scale), block_s=block_s,
                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def verify_attention_paged(q: jax.Array, k: jax.Array, v: jax.Array,
                           kv_pos: jax.Array, table: jax.Array,
                           q_pos: jax.Array, lengths: jax.Array,
                           k_new: jax.Array, v_new: jax.Array,
                           tree_mask: jax.Array, *,
                           interpret: bool = True) -> jax.Array:
    """``verify_attention`` over a **paged** cache: k/v are the shared page
    pool ``[P, page_len, KV, dh]`` (kv_pos ``[P, page_len]``) and ``table``
    ``[B, T]`` maps each slot's virtual kv-block to its pool page. Both
    ``lengths`` and ``table`` are scalar-prefetched so the indirection is
    resolved in the index map — the kernel body is byte-identical to the
    contiguous hot path with ``block_s = page_len``."""
    return _verify_call_paged(q, k, v, kv_pos, table, q_pos, lengths, k_new,
                              v_new, tree_mask, None, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def verify_attention_paged_int8(q: jax.Array, k: jax.Array, v: jax.Array,
                                k_scale: jax.Array, v_scale: jax.Array,
                                kv_pos: jax.Array, table: jax.Array,
                                q_pos: jax.Array, lengths: jax.Array,
                                k_new: jax.Array, v_new: jax.Array,
                                tree_mask: jax.Array, *,
                                interpret: bool = True) -> jax.Array:
    """Paged verify over an int8 pool (scales ``[P, page_len, KV, G]``)."""
    assert k.dtype == jnp.int8 and v.dtype == jnp.int8, (k.dtype, v.dtype)
    return _verify_call_paged(q, k, v, kv_pos, table, q_pos, lengths, k_new,
                              v_new, tree_mask, (k_scale, v_scale),
                              interpret=interpret)
