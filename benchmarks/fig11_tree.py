"""Fig. 11 analogue: (a) measured AAL per tree structure vs verification
budget; (b) theoretical speedup (Eq. 3 with the measured latency profile)."""
from __future__ import annotations


from benchmarks import common
from repro.core import static_trees
from repro.core.objective import speedup_objective


def run(quick: bool = True):
    tb = common.testbed(0.5)   # moderate-acceptance corpus: trees matter here
    prof = common.measure_profile(tb)
    prompt, lengths = common.prompts_for(tb, B=2)
    max_new = 64 if quick else 128
    ra = static_trees.measure_rank_accept(
        tb.drafter, tb.d_params, tb.verifier, tb.v_params, prompt, lengths,
        k=4, iters=16)
    budgets = (4, 8, 16) if quick else (4, 8, 16, 32, 64)
    rows = []
    for budget in budgets:
        # every structure drafts to depth <= 8 and is verified with at most
        # `budget` tokens — the paper's equal-verification-budget setting;
        # EGT drafts deep (D=8) and prunes the best `budget`-node subtree.
        cases = {
            "chain": common.structure_spec("chain", depth=min(budget - 1, 8)),
            "kary2": common.structure_spec("kary2", depth=3),
            "sequoia": common.structure_spec("sequoia", budget=budget,
                                             depth=8, rank_accept=ra),
            "egt_w2": common.structure_spec("egt", depth=8, width=2),
            "egt_w4": common.structure_spec("egt", depth=8, width=4),
        }
        for name, (spec, _) in cases.items():
            v = min(budget, spec.num_nodes)
            eng = common.make_engine(tb, profile=prof)
            s = common.run_generate(eng, prompt, lengths, max_new,
                                    spec=spec, verify_v=v)
            theo = speedup_objective(prof, s["aal"], spec.depth,
                                     max(spec.width, 1), v)
            rows.append({"budget": budget, "structure": name,
                         "aal": s["aal"], "tpot_ms": s["tpot_ms"],
                         "theoretical_speedup": theo})
    out = {"rows": rows, "rank_accept": list(map(float, ra))}
    common.save("fig11_tree", out)
    return out


if __name__ == "__main__":
    res = run()
    for r in res["rows"]:
        print({k: (round(v, 3) if isinstance(v, float) else v)
               for k, v in r.items()})
