"""Roofline table: aggregate the dry-run JSONs into the per-(arch × shape)
report of EXPERIMENTS.md §Roofline (single-pod numbers)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN = os.path.join(os.path.dirname(__file__), "results", "dryrun")

COLS = ("arch", "shape", "mesh", "chips", "compute_s", "memory_s",
        "collective_s", "dominant", "useful_flops_ratio")


def load_records(mesh: str = None, variants: bool = False) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        if ("__it" in os.path.basename(path)) != variants:
            continue  # §Perf hillclimb variants are reported separately
        with open(path) as f:
            r = json.load(f)
        if not r.get("ok"):
            continue
        if mesh and r["mesh"] != mesh:
            continue
        recs.append(r)
    return recs


def row(r: Dict) -> Dict:
    rf = r["roofline"]
    return {
        "arch": r["arch"] + r.get("variant", ""),
        "shape": r["shape"], "kind": r["kind"], "mesh": r["mesh"],
        "chips": r["chips"],
        "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
        "collective_s": rf["collective_s"], "dominant": rf["dominant"],
        "model_flops": r["model_flops_global"],
        "hlo_flops": r["hlo_flops_global"],
        "useful": r["useful_flops_ratio"],
        "coll_bytes_dev": r["collective_bytes_per_device"],
        "step_bound_s": max(rf["compute_s"], rf["memory_s"],
                            rf["collective_s"]),
    }


def markdown_table(recs: List[Dict]) -> str:
    lines = ["| arch | shape | kind | compute s | memory s | collective s | "
             "dominant | useful FLOPs |",
             "|---|---|---|---|---|---|---|---|"]
    for r in map(row, recs):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | {r['dominant']} | {r['useful']:.2f} |")
    return "\n".join(lines)


def kernel_markdown() -> str:
    """Verify-kernel HBM-traffic section from the fig_kernel sweep (empty
    string when the microbenchmark hasn't been run)."""
    path = os.path.join(os.path.dirname(__file__), "results",
                        "fig_kernel.json")
    if not os.path.exists(path):
        return ""
    from benchmarks.fig_kernel import markdown_table
    with open(path) as f:
        res = json.load(f)
    return ("\n## Verify-kernel HBM traffic "
            f"(modeled, backend={res.get('backend', '?')})\n\n"
            + markdown_table(res)
            + f"\n\nrepeat-KV blow-up recovered: "
              f"{res['gqa_bytes_ratio']:.2f}x; bytes scale with committed "
              f"length: {res['len_scaling_ratio']:.2f}x.\n")


def run(quick: bool = True):
    recs = load_records(mesh="pod16x16")
    table = markdown_table(recs)
    failures = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if not r.get("ok"):
            failures.append({"case": os.path.basename(path),
                             "error": r.get("error", "?")})
    kernel_md = kernel_markdown()
    out = {"rows": [row(r) for r in recs], "n_single_pod": len(recs),
           "n_multi_pod": len(load_records(mesh="pod2x16x16")),
           "has_kernel_table": bool(kernel_md),
           "failures": failures}
    with open(os.path.join(os.path.dirname(__file__), "results",
                           "roofline_table.md"), "w") as f:
        f.write(table + "\n" + kernel_md)
    return out


if __name__ == "__main__":
    res = run()
    print(markdown_table(load_records(mesh="pod16x16")))
    print(f"\nsingle-pod: {res['n_single_pod']}  multi-pod: "
          f"{res['n_multi_pod']}  failures: {len(res['failures'])}")
