"""Bench-regression gate: diff a fig_serving.json artifact against the
committed baseline and FAIL (exit 1) on a >10% drop in any gated metric.

The gate only reads metrics that are deterministic on CI runners:

  * emulated-clock throughput and AAL from ``adaptive_sweep`` (step costs
    are profile-charged, not wall-clock, so runner speed cancels out);
  * AAL and the fixed-cache-bytes slot ratio from ``quant_sweep`` (the
    sweep drains an upfront queue — no wall-clock admission races);
  * every ``recompiles_after_warmup`` anywhere in the artifact must be 0
    (compile stability is a hard invariant, not a percentage);
  * the ``telemetry`` sweep's absolute contracts (HARD_BOUNDS): telemetry
    enabled must leave greedy outputs token-exact, exported traces must
    validate, emulated-clock snapshots must be bit-reproducible, and the
    measured telemetry self-time must stay under 2% of decode time. These
    are baseline-independent — a missing key fails the gate rather than
    passing vacuously.

Wall-clock throughputs (the ``servers``/``mesh_sweep`` rows) are recorded
in the artifact for humans but NOT gated — shared CI runners jitter far
beyond 10% and a gate on them would train everyone to ignore red.

Usage:
  python benchmarks/check_regression.py \
      --baseline benchmarks/results/baseline_serving.json \
      --current benchmarks/results/fig_serving.json
  # refresh the committed baseline from a trusted run:
  python benchmarks/check_regression.py --write-baseline \
      --current benchmarks/results/fig_serving.json \
      --baseline benchmarks/results/baseline_serving.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Tuple

# dotted path into fig_serving.json -> direction ("higher" is better for
# every gated metric today; the field keeps the gate honest if that changes)
GATED_METRICS: Tuple[Tuple[str, str], ...] = (
    ("adaptive_sweep.adaptive.throughput_tok_s", "higher"),
    ("adaptive_sweep.adaptive.aal", "higher"),
    ("adaptive_sweep.adaptive_over_best_pinned", "higher"),
    ("quant_sweep.none.aal", "higher"),
    ("quant_sweep.int8-kv.aal", "higher"),
    ("quant_sweep.slots_ratio", "higher"),
    # verify-kernel HBM traffic (analytic model, fully deterministic):
    # reintroducing repeat_kv on the hot path or dropping the kv-block
    # early-out collapses these toward 1.0 and fails the gate
    ("kernel_traffic.gqa_bytes_ratio", "higher"),
    ("kernel_traffic.len_scaling_ratio", "higher"),
    # async front-end (emulated clock, deterministic): the fraction of
    # tokens delivered within SLO through the 2-replica router, and its
    # margin over the single scale-up replica at equal slot count
    ("frontend_sweep.router.goodput_under_slo", "higher"),
    ("frontend_sweep.router_over_single", "higher"),
    # chunked prefill on the bimodal short/long trace (emulated clock,
    # deterministic): interleaved chunk quanta must keep beating the
    # monolithic head-of-line stall on tail latency AND on throughput
    ("chunked_prefill_sweep.chunked.throughput_tok_s", "higher"),
    ("chunked_prefill_sweep.p95_speedup", "higher"),
    ("chunked_prefill_sweep.p99_speedup", "higher"),
    # paged KV cache on the shared-prefix trace (emulated clock,
    # deterministic): the prefix store must keep hitting and the paged
    # pool's high-water usage must stay far under the contiguous pin
    ("paged_sweep.prefix_hit_rate", "higher"),
    ("paged_sweep.slots_at_fixed_hbm_ratio", "higher"),
    # chaos sweep (emulated clock, seeded fault schedule): goodput paid
    # under faults — backoff, replays and degraded steps cost emulated
    # time, and that cost must not silently grow
    ("fault_sweep.goodput_under_faults", "higher"),
)
DEFAULT_THRESHOLD = 0.10

# Relative tolerance for HARD_BOUNDS float comparisons. Floats that SHOULD
# sit exactly at a bound (token_exact == 1.0) may reach it through float
# accumulation, so "==" means "within GATE_RTOL". The strict ops stay
# strict AND exclude the tolerance band: a margin metric that lands within
# GATE_RTOL of its bound (e.g. router_over_single == 1.0 + 1e-16) is noise
# posing as a win, and the gate fails it deterministically instead of
# flapping with the rounding mode. These semantics are asserted in
# tests/test_regression_gate.py.
GATE_RTOL = 1e-9


def _near(val: float, bound: float) -> bool:
    return abs(val - bound) <= GATE_RTOL * max(1.0, abs(val), abs(bound))

# absolute contracts from the telemetry sweep — not relative-to-baseline
# (determinism and exactness are 1.0 or broken; the overhead budget is the
# documented <2% contract). Checked against the CURRENT artifact only, so
# the committed baseline never needs regenerating for these.
HARD_BOUNDS: Tuple[Tuple[str, str, float], ...] = (
    ("telemetry.token_exact", "==", 1.0),
    ("telemetry.trace_valid", "==", 1.0),
    ("telemetry.emulated_snapshot_deterministic", "==", 1.0),
    ("telemetry.overhead_frac", "<", 0.02),
    # the async front-end's acceptance criteria are absolute: two identical
    # emulated drives must be byte-identical WITH the event loop in the
    # path, and routing over 2 replicas must strictly beat the single
    # scale-up replica on goodput under SLO at equal slot count
    ("frontend_sweep.deterministic", "==", 1.0),
    ("frontend_sweep.router_over_single", ">", 1.0),
    # chunked prefill: greedy decode must be token-exact vs monolithic,
    # p95 on the bimodal trace must strictly beat monolithic, and chunking
    # must not give back throughput to buy the tail
    ("chunked_prefill_sweep.token_exact", "==", 1.0),
    ("chunked_prefill_sweep.p95_speedup", ">", 1.0),
    ("chunked_prefill_sweep.throughput_ratio", ">", 1.0),
    # paged KV cache: greedy decode must be token-exact vs the contiguous
    # layout, the prefix store must actually skip prefill work, and the
    # pool's high-water bytes must fit >1.5x more slots than the
    # contiguous layout pins into the same HBM
    ("paged_sweep.token_exact", "==", 1.0),
    ("paged_sweep.prefix_hit_rate", ">", 0.0),
    ("paged_sweep.slots_at_fixed_hbm_ratio", ">", 1.5),
    # fault tolerance: every request served through the seeded chaos
    # schedule must finish with the exact tokens of the fault-free run,
    # nothing may be lost or shed, recovery must not cost a compile, and
    # the faulted drive itself must be byte-reproducible
    ("fault_sweep.replay_token_exact", "==", 1.0),
    ("fault_sweep.lost_requests", "==", 0.0),
    ("fault_sweep.recompiles_after_recovery", "==", 0.0),
    ("fault_sweep.deterministic", "==", 1.0),
)

# fault counters walked like recompile counters (any depth, any sweep): a
# fault_sweep artifact whose schedule injected faults but whose replica
# counters never moved means the injection silently missed the serving
# path — the chaos gate would be passing vacuously
FAULT_COUNTERS: Tuple[str, ...] = ("faults_seen", "replays")


def lookup(blob: Dict, dotted: str) -> Any:
    cur: Any = blob
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(dotted)
        cur = cur[part]
    return cur


def _walk_counter(node: Any, path: str, name: str,
                  out: List[Tuple[str, int]]):
    """Collect every occurrence of counter ``name`` anywhere in the
    artifact (same traversal the recompile invariant uses)."""
    if isinstance(node, dict):
        for k, v in node.items():
            p = f"{path}.{k}" if path else str(k)
            if k == name:
                out.append((p, int(v)))
            else:
                _walk_counter(v, p, name, out)
    elif isinstance(node, list):  # sweeps recorded as row lists still count
        for i, v in enumerate(node):
            _walk_counter(v, f"{path}[{i}]", name, out)


def _walk_recompiles(node: Any, path: str, out: List[Tuple[str, int]]):
    _walk_counter(node, path, "recompiles_after_warmup", out)


def compare(baseline: Dict, current: Dict,
            threshold: float = DEFAULT_THRESHOLD) -> List[str]:
    """Return the list of failures (empty == gate passes)."""
    failures: List[str] = []
    metrics = baseline.get("metrics")
    if not isinstance(metrics, dict) or not metrics:
        return ["baseline has no 'metrics' table — refusing to pass vacuously"]
    thr = float(baseline.get("threshold", threshold))
    for key, base_val in metrics.items():
        direction = dict(GATED_METRICS).get(key, "higher")
        try:
            cur_val = float(lookup(current, key))
        except KeyError:
            failures.append(f"{key}: missing from the current artifact")
            continue
        base_val = float(base_val)
        if direction == "higher":
            floor = base_val * (1.0 - thr)
            if cur_val < floor:
                failures.append(
                    f"{key}: {cur_val:.4g} < {floor:.4g} "
                    f"(baseline {base_val:.4g}, -{thr:.0%} allowed)")
        else:
            ceil = base_val * (1.0 + thr)
            if cur_val > ceil:
                failures.append(
                    f"{key}: {cur_val:.4g} > {ceil:.4g} "
                    f"(baseline {base_val:.4g}, +{thr:.0%} allowed)")
    recompiles: List[Tuple[str, int]] = []
    _walk_recompiles(current, "", recompiles)
    if not recompiles:
        failures.append("no recompiles_after_warmup found in the artifact — "
                        "the compile-stability invariant went unmeasured")
    for path, val in recompiles:
        if val != 0:
            failures.append(f"{path}: {val} recompiles after warmup (must be 0)")
    if "fault_sweep" in current:
        # the chaos artifact must carry live fault counters: walked like
        # recompiles so new replica rows are picked up automatically
        fs = current["fault_sweep"]
        try:
            injected = int(lookup(fs, "faults_injected"))
        except KeyError:
            injected = 0
            failures.append("fault_sweep.faults_injected: missing — the "
                            "chaos schedule went unmeasured")
        for name in FAULT_COUNTERS:
            hits: List[Tuple[str, int]] = []
            _walk_counter(fs, "fault_sweep", name, hits)
            if not hits:
                failures.append(
                    f"fault_sweep carries no '{name}' counters — replica "
                    f"fault accounting went unmeasured")
            elif injected > 0 and sum(v for _, v in hits) == 0:
                failures.append(
                    f"fault_sweep injected {injected} faults but every "
                    f"'{name}' counter is 0 — injection silently missed "
                    f"the serving path")
    for key, op, bound in HARD_BOUNDS:
        try:
            val = float(lookup(current, key))
        except KeyError:
            failures.append(f"{key}: missing from the current artifact — "
                            f"hard bound {op} {bound:g} went unmeasured")
            continue
        near = _near(val, bound)
        ok = {"==": near,
              "<": val < bound and not near,
              ">": val > bound and not near}[op]
        if not ok:
            failures.append(
                f"{key}: {val:.8g} violates the hard bound ({op} {bound:g}"
                f", rtol {GATE_RTOL:g})")
    return failures


def extract_baseline(current: Dict,
                     threshold: float = DEFAULT_THRESHOLD) -> Dict:
    metrics = {}
    for key, _ in GATED_METRICS:
        metrics[key] = float(lookup(current, key))
    return {"threshold": threshold, "metrics": metrics}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--threshold", type=float, default=None,
                    help="override the baseline's relative tolerance")
    ap.add_argument("--write-baseline", action="store_true",
                    help="extract the gated metrics from --current and "
                         "write them to --baseline instead of checking")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    if args.write_baseline:
        blob = extract_baseline(
            current,
            DEFAULT_THRESHOLD if args.threshold is None else args.threshold)
        with open(args.baseline, "w") as f:
            json.dump(blob, f, indent=1)
            f.write("\n")
        print(f"baseline written to {args.baseline}:")
        for k, v in blob["metrics"].items():
            print(f"  {k} = {v:.4g}")
        return 0

    with open(args.baseline) as f:
        baseline = json.load(f)
    if args.threshold is not None:
        baseline = {**baseline, "threshold": args.threshold}
    failures = compare(baseline, current)
    if failures:
        print("BENCH REGRESSION GATE FAILED:", file=sys.stderr)
        for fail in failures:
            print(f"  - {fail}", file=sys.stderr)
        return 1
    thr = baseline.get("threshold", DEFAULT_THRESHOLD)
    print(f"bench regression gate passed "
          f"({len(baseline['metrics'])} metrics within {thr:.0%}, "
          f"all recompile counters 0)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
