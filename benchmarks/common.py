"""Shared benchmark infrastructure.

All figure benchmarks run on the CPU testbed: a verifier/drafter pair
trained on the same Markov corpus (the laptop-scale analogue of
llama-2-7b / llama-68m on web text — see serving/testbed.py). Latency
profiles (Fig. 5 curves) are MEASURED on this runtime and feed the engine's
objective, exactly as the paper profiles its GPUs. Results are written as
JSON under benchmarks/results/.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.egt import DraftSpec, egt_spec, template_spec
from repro.core.engine import (EngineConfig, SpeculativeEngine,
                               generate_autoregressive)
from repro.core.objective import LatencyProfile
from repro.core import static_trees
from repro.data.pipeline import MarkovSource
from repro.serving.testbed import Testbed, TestbedSpec, build_testbed

RESULTS = os.path.join(os.path.dirname(__file__), "results")

# the three "datasets": Markov sources at different entropies, standing in
# for C4 / Wikipedia / CNN-Daily (which differ exactly in drafter/verifier
# agreement — the quantity that matters to speculation). 0.03 gives ~0.97
# rank-0 acceptance (easy), 0.5/1.5 progressively harder.
DATASETS = {"c4": 0.03, "wiki": 0.5, "cnndm": 1.5}


def save(name: str, payload: Dict) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def load(name: str) -> Optional[Dict]:
    path = os.path.join(RESULTS, f"{name}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return None


_TB: Dict[float, Testbed] = {}


def testbed(concentration: float = 0.03) -> Testbed:
    # train_steps matches the test fixture so the on-disk cache is shared
    if concentration not in _TB:
        _TB[concentration] = build_testbed(
            TestbedSpec(train_steps=160, concentration=concentration))
    return _TB[concentration]


def prompts_for(tb: Testbed, B: int = 2, S: int = 16, seed: int = 0):
    rng = np.random.default_rng(seed)
    src = MarkovSource(vocab=tb.spec.vocab,
                       concentration=tb.data_cfg.concentration,
                       seed=tb.data_cfg.seed)
    toks = src.sample_fast(rng, B, S)
    return jnp.asarray(toks), jnp.full((B,), S, jnp.int32)


def make_engine(tb: Testbed, profile: Optional[LatencyProfile] = None,
                **cfg_kw) -> SpeculativeEngine:
    return SpeculativeEngine(tb.drafter, tb.d_params, tb.verifier,
                             tb.v_params, profile=profile,
                             config=EngineConfig(**cfg_kw))


# ------------------------------------------------------- latency profiling --
def measure_profile(tb: Testbed, widths=(1, 2, 4, 8, 16, 32, 64),
                    repeat: int = 3, cache_name: str = "profile") -> LatencyProfile:
    """Measure T_verify(W) and T_draft(W) on this runtime (the Fig. 5 pass)."""
    cached = load(cache_name)
    if cached is not None:
        return LatencyProfile(**cached)
    from repro.models.cache import make_kv_cache

    def bench_model(model, params) -> List[float]:
        times = []
        B, L = 2, 256
        prompt, lengths = prompts_for(tb)
        cache = make_kv_cache(model.cfg).init(B, L)
        _, cache, _ = model.prefill(params, prompt, lengths, cache)
        for w in widths:
            toks = jnp.zeros((B, w), jnp.int32)
            deps = jnp.broadcast_to(jnp.arange(w)[None], (B, w)).astype(jnp.int32)
            mask = jnp.tril(jnp.ones((w, w), bool))[None].repeat(B, 0)
            fn = jax.jit(lambda p, t, d, m, c: model.tree_verify(p, t, d, m, c))
            fn(params, toks, deps, mask, cache)[0].block_until_ready()
            ts = []
            for _ in range(repeat):
                t0 = time.perf_counter()
                fn(params, toks, deps, mask, cache)[0].block_until_ready()
                ts.append(time.perf_counter() - t0)
            times.append(float(np.median(ts)))
        return times

    v_times = bench_model(tb.verifier, tb.v_params)
    d_times = bench_model(tb.drafter, tb.d_params)
    prof = LatencyProfile(list(widths), v_times, list(widths), d_times,
                          step_overhead=min(d_times) * 0.2)
    save(cache_name, prof.__dict__)
    return prof


# ------------------------------------------------------------ structures ---
def structure_spec(kind: str, *, depth: int = 4, width: int = 4,
                   budget: int = 16, rank_accept=None
                   ) -> Tuple[DraftSpec, int]:
    """Build (DraftSpec, default verify width) for a named tree structure."""
    if kind == "egt":
        return egt_spec(depth, width), budget
    if kind == "chain":
        p, r = static_trees.chain(depth)
        return template_spec(p, r), min(budget, depth + 1)
    if kind.startswith("kary"):
        k = int(kind[4:] or 2)
        p, r = static_trees.kary(k, depth)
        return template_spec(p, r), min(budget, len(p))
    if kind == "sequoia":
        assert rank_accept is not None
        p, r = static_trees.sequoia(rank_accept, budget, max_depth=depth)
        return template_spec(p, r), len(p)
    raise ValueError(kind)


def run_generate(eng: SpeculativeEngine, prompt, lengths, max_new: int,
                 spec=None, verify_v=None, warm: bool = True) -> Dict:
    """Generate and report steady-state TPOT (compile excluded via warmup)."""
    if warm:
        eng.generate(prompt, lengths, max(4, max_new // 8), spec=spec,
                     verify_v=verify_v)
    seq, stats = eng.generate(prompt, lengths, max_new, spec=spec,
                              verify_v=verify_v)
    s = stats.summary()
    s["tpot_ms"] = 1e3 * s["time_s"] / max(s["tokens"], 1)
    return s


def ar_baseline(tb: Testbed, prompt, lengths, max_new: int) -> Dict:
    # warm
    generate_autoregressive(tb.verifier, tb.v_params, prompt, lengths, 4)
    _, info = generate_autoregressive(tb.verifier, tb.v_params, prompt,
                                      lengths, max_new)
    return info
