"""Fig. 5 analogue: verifier/drafter latency vs number of tokens verified in
parallel, measured on this runtime. Feeds the engine's latency objective."""
from __future__ import annotations

from benchmarks import common


def run(quick: bool = True):
    tb = common.testbed()
    widths = (1, 2, 4, 8, 16, 32) if quick else (1, 2, 4, 8, 16, 32, 64, 128)
    prof = common.measure_profile(tb, widths=widths)
    rows = [{"width": w, "t_verify_ms": 1e3 * tv, "t_draft_ms": 1e3 * td}
            for w, tv, td in zip(prof.verify_widths, prof.verify_times,
                                 prof.draft_times)]
    payload = {"rows": rows,
               "note": "t_verify(1)/t_verify(W) is the parallel-verification "
                       "free-lunch region; the knee is where Eq.3 stops "
                       "paying for wider verification"}
    common.save("fig5_latency_curve", payload)
    return payload


if __name__ == "__main__":
    for r in run()["rows"]:
        print(r)
