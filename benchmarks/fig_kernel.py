"""Verify-kernel microbenchmark: HBM traffic + iteration time vs committed
length.

For a fixed GQA verification shape, sweep the committed cache length and
record, per length:

  * modeled HBM bytes for the fused length-aware kernel (block-granular
    early-out, un-repeated K/V, in-kernel mask) — ``repro.kernels.traffic``;
  * modeled bytes for the two XLA einsum paths (grouped, and the
    repeat_kv baseline the kernel replaces);
  * the roofline time the kernel bytes imply at a v5e-class bandwidth;
  * measured wall time per ``ops.verify_attention`` call. On CPU the kernel
    runs in interpret mode, so wall numbers only sanity-check the trend
    (flat-ish in length it would NOT be if blocks weren't skipped); on TPU
    they are the real thing. Wall time is recorded, never gated.

The modeled-bytes rows are deterministic and feed the bench-regression gate
via ``kernel_traffic`` in fig_serving.json; this standalone sweep writes
``results/fig_kernel.json`` and a markdown table consumed by
``benchmarks/roofline.py``.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.kernels import ops
from repro.kernels.traffic import bytes_summary, roofline_time_s

from repro.kernels.ops import VERIFY_BLOCK_S

# a llama-2-7b-at-GQA-scale verification shape: 2 kv-heads x 4 query heads
# per group, 8-node trees against a 512-slot cache; the block width is the
# hot path's own, so the modeled rows describe the deployed kernel
SHAPE = dict(batch=4, w=8, kv_heads=2, num_q_per_kv=4, head_dim=64,
             s_cache=512, block_s=VERIFY_BLOCK_S)
LENGTHS = (0, 64, 128, 256, 384, 512)


def _inputs(length: int, key=0):
    B, W = SHAPE["batch"], SHAPE["w"]
    KV, G, dh = SHAPE["kv_heads"], SHAPE["num_q_per_kv"], SHAPE["head_dim"]
    S = SHAPE["s_cache"]
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    q = jax.random.normal(ks[0], (B, W, KV * G, dh))
    k = jax.random.normal(ks[1], (B, S, KV, dh))
    v = jax.random.normal(ks[2], (B, S, KV, dh))
    k_new = jax.random.normal(ks[3], (B, W, KV, dh))
    v_new = jax.random.normal(ks[4], (B, W, KV, dh))
    lens = jnp.full((B,), length, jnp.int32)
    pos = jnp.arange(S)[None]
    kv_pos = jnp.where(pos < lens[:, None], pos, -1).astype(jnp.int32)
    q_pos = lens[:, None] + jnp.broadcast_to(jnp.arange(W)[None] % 4, (B, W))
    tm = jnp.broadcast_to(jnp.tril(jnp.ones((W, W), bool))[None], (B, W, W))
    return q, k, v, kv_pos, q_pos, lens, k_new, v_new, tm


def measure_iter_s(length: int, reps: int = 5) -> float:
    args = _inputs(length)
    out = ops.verify_attention(*args, block_s=SHAPE["block_s"])
    jax.block_until_ready(out)          # compile outside the timed region
    t0 = time.perf_counter()
    for _ in range(reps):
        out = ops.verify_attention(*args, block_s=SHAPE["block_s"])
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(time_it: bool = True) -> Dict:
    B = SHAPE["batch"]
    rows: List[Dict] = []
    for length in LENGTHS:
        s = bytes_summary(w=SHAPE["w"], kv_heads=SHAPE["kv_heads"],
                          num_q_per_kv=SHAPE["num_q_per_kv"],
                          head_dim=SHAPE["head_dim"],
                          s_cache=SHAPE["s_cache"],
                          lengths=[length] * B, block_s=SHAPE["block_s"])
        row = {"length": length, **s,
               "roofline_s": roofline_time_s(s["kernel_bytes"])}
        if time_it:
            row["iter_s"] = measure_iter_s(length)
        rows.append(row)
    full, first = rows[-1], next(r for r in rows if r["length"] > 0)
    out = {"shape": SHAPE, "backend": jax.default_backend(),
           "interpret_mode": jax.default_backend() == "cpu",
           "rows": rows,
           # the two headline ratios (same definitions the gate uses):
           # repeat_kv blow-up recovered at full length, and bytes tracking
           # committed length instead of the max_len extent
           "gqa_bytes_ratio": full["repeated_over_kernel"],
           "len_scaling_ratio": (full["kernel_bytes"]
                                 / max(first["kernel_bytes"], 1))}
    common.save("fig_kernel", out)
    return out


def markdown_table(res: Dict) -> str:
    lines = ["| length | kernel MB | grouped-XLA MB | repeat-KV MB | "
             "roofline µs |" + (" iter ms |" if "iter_s" in res["rows"][0]
                                else ""),
             "|---|---|---|---|---|" + ("---|" if "iter_s" in res["rows"][0]
                                        else "")]
    for r in res["rows"]:
        line = (f"| {r['length']} | {r['kernel_bytes'] / 2**20:.2f} | "
                f"{r['xla_grouped_bytes'] / 2**20:.2f} | "
                f"{r['xla_repeated_bytes'] / 2**20:.2f} | "
                f"{r['roofline_s'] * 1e6:.1f} |")
        if "iter_s" in r:
            line += f" {r['iter_s'] * 1e3:.2f} |"
        lines.append(line)
    return "\n".join(lines)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-time", action="store_true",
                    help="modeled bytes only (skip wall-clock reps)")
    cli = ap.parse_args()
    res = run(time_it=not cli.no_time)
    print(markdown_table(res))
    print(f"\nGQA repeat-KV blow-up recovered: "
          f"{res['gqa_bytes_ratio']:.2f}x at full length "
          f"(num_q_per_kv={SHAPE['num_q_per_kv']})")
    print(f"bytes scale with committed length: "
          f"{res['len_scaling_ratio']:.2f}x from first live block to "
          f"max_len (vs 1.0x for the max_len-extent XLA paths)")
