"""Fig. 4 analogue: what static-graph execution buys, and what dynamic
shapes cost under a compiling runtime.

(a) bucket-replay vs recompile-storm: a DISCO-style fully dynamic tree
    changes operator shapes every iteration — under XLA every new shape is
    a fresh compile. EGT's bucket set keeps shapes static.
(b) the same static tree executed with host-synced stages vs the fused
    megastep (kernel-launch/CPU-logic overhead analogue).
"""
from __future__ import annotations

import time


from benchmarks import common
from repro.core.egt import egt_spec


def run(quick: bool = True):
    tb = common.testbed()
    prof = common.measure_profile(tb)
    prompt, lengths = common.prompts_for(tb, B=2)
    iters = 6 if quick else 16

    # --- (a) static bucket replay ------------------------------------------
    eng = common.make_engine(tb, profile=prof)
    spec = egt_spec(4, 2)
    eng.generate(prompt, lengths, 4, spec=spec, verify_v=6)      # compile
    t0 = time.perf_counter()
    _, st = eng.generate(prompt, lengths, iters * 4, spec=spec, verify_v=6)
    static_time = (time.perf_counter() - t0) / max(st.tokens_generated, 1)

    # --- (a') dynamic shapes: a new ⟨D, W, V⟩ every iteration --------------
    eng_dyn = common.make_engine(tb, profile=prof)
    shapes = [(2, 2, 3), (3, 2, 5), (4, 2, 6), (2, 3, 4), (3, 3, 7),
              (5, 2, 8), (4, 3, 9), (2, 4, 5)]
    t0 = time.perf_counter()
    toks = 0
    for i in range(iters):
        d, w, v = shapes[i % len(shapes)]
        _, st = eng_dyn.generate(prompt, lengths, 4, spec=egt_spec(d, w),
                                 verify_v=v)
        toks += st.tokens_generated
    dynamic_time = (time.perf_counter() - t0) / max(toks, 1)

    # --- (b) fused vs staged on the same static tree -----------------------
    res_plans = {}
    for plan in ("fused", "staged_device", "staged"):
        e = common.make_engine(tb, profile=prof, plan=plan)
        s = common.run_generate(e, prompt, lengths, 24, spec=spec, verify_v=6)
        res_plans[plan] = s["tpot_ms"]

    out = {
        "static_bucket_s_per_tok": static_time,
        "dynamic_shape_s_per_tok": dynamic_time,
        "recompile_storm_slowdown": dynamic_time / static_time,
        "plan_tpot_ms": res_plans,
        "fused_vs_staged_speedup": res_plans["staged"] / res_plans["fused"],
    }
    common.save("fig4_runtime", out)
    return out


if __name__ == "__main__":
    res = run()
    print("recompile-storm slowdown: %.1fx" % res["recompile_storm_slowdown"])
    print("plan tpot:", {k: round(v, 2) for k, v in res["plan_tpot_ms"].items()})
