"""Fig. 10 analogue: end-to-end per-token latency (TPOT) across systems and
datasets.

Systems (each = tree algorithm × runtime treatment, per Table 1):
  ar          — plain autoregressive decoding (the denominator).
  specinfer   — static k-ary tree, STAGED host runtime (uncompiled control
                flow: the paper finds SpecInfer's runtime is its bottleneck).
  sequoia     — dataset-profiled static tree, compiled staged-device runtime
                (Sequoia uses TorchInductor but keeps per-stage dispatch).
  vllm-spec   — sequence (chain) speculation, fully compiled fused runtime.
  yggdrasil   — EGT + latency objective + pruning + fused megastep.
"""
from __future__ import annotations


from benchmarks import common
from repro.core import static_trees


def run(quick: bool = True):
    max_new = 48 if quick else 128
    B = 2
    rows = []
    for ds, conc in common.DATASETS.items():
        tb = common.testbed(conc)
        prof = common.measure_profile(tb, cache_name=f"profile_{ds}")
        prompt, lengths = common.prompts_for(tb, B=B)
        ra = static_trees.measure_rank_accept(
            tb.drafter, tb.d_params, tb.verifier, tb.v_params,
            prompt, lengths, k=4, iters=16)

        ar = common.ar_baseline(tb, prompt, lengths, max_new)
        rows.append({"dataset": ds, "system": "ar",
                     "tpot_ms": ar["tpot_ms"], "aal": 1.0})

        def bench(name, spec, v, plan, **cfg):
            eng = common.make_engine(tb, profile=prof, plan=plan, **cfg)
            s = common.run_generate(eng, prompt, lengths, max_new,
                                    spec=spec, verify_v=v)
            rows.append({"dataset": ds, "system": name,
                         "tpot_ms": s["tpot_ms"], "aal": s["aal"]})

        spec, v = common.structure_spec("kary2", depth=3)
        bench("specinfer", spec, v, "staged")
        spec, v = common.structure_spec("sequoia", budget=12, depth=6,
                                        rank_accept=ra)
        bench("sequoia", spec, v, "staged_device")
        spec, v = common.structure_spec("chain", depth=4)
        bench("vllm-spec", spec, v, "fused")
        spec, v = common.structure_spec("egt", depth=4, width=4, budget=10)
        bench("yggdrasil", spec, v, "fused")

    # speedups vs specinfer & vs ar, per dataset
    out = {"rows": rows, "speedup_vs_specinfer": {}, "speedup_vs_ar": {}}
    for ds in common.DATASETS:
        d = {r["system"]: r["tpot_ms"] for r in rows if r["dataset"] == ds}
        out["speedup_vs_specinfer"][ds] = {
            s: d["specinfer"] / d[s] for s in d if s != "ar"}
        out["speedup_vs_ar"][ds] = {s: d["ar"] / d[s] for s in d}
    common.save("fig10_e2e", out)
    return out


if __name__ == "__main__":
    res = run()
    for ds, sp in res["speedup_vs_ar"].items():
        print(ds, {k: round(v, 2) for k, v in sp.items()})
