"""Fig. 13 analogue: EGT parameter sensitivity — per-token latency over the
⟨D_draft, W_draft, W_verify⟩ grid."""
from __future__ import annotations

from benchmarks import common
from repro.core.egt import egt_spec


def run(quick: bool = True):
    tb = common.testbed(0.5)   # moderate-acceptance corpus: trees matter here
    prof = common.measure_profile(tb)
    prompt, lengths = common.prompts_for(tb, B=2)
    max_new = 32 if quick else 96
    depths = (2, 4, 8)
    widths = (1, 2, 4)
    verifies = (4, 8, 16)
    rows = []
    for d in depths:
        for w in widths:
            spec = egt_spec(d, w)
            for v in verifies:
                if v > spec.num_nodes:   # invalid configs excluded (paper)
                    continue
                eng = common.make_engine(tb, profile=prof)
                s = common.run_generate(eng, prompt, lengths, max_new,
                                        spec=spec, verify_v=v)
                rows.append({"D": d, "W": w, "V": v, "tpot_ms": s["tpot_ms"],
                             "aal": s["aal"]})
    best = min(rows, key=lambda r: r["tpot_ms"])
    out = {"rows": rows, "best": best}
    common.save("fig13_sensitivity", out)
    return out


if __name__ == "__main__":
    res = run()
    print("best:", res["best"])
    for r in res["rows"]:
        print(r)
