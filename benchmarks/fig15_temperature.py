"""Fig. 15 analogue: sampling-temperature sensitivity, Yggdrasil (EGT) vs
Sequoia-style static tree. Stochastic acceptance (rejection sampling) at
t > 0, greedy at t = 0."""
from __future__ import annotations

from benchmarks import common
from repro.core import static_trees


def run(quick: bool = True):
    tb = common.testbed(0.5)   # moderate-acceptance corpus: trees matter here
    prof = common.measure_profile(tb)
    prompt, lengths = common.prompts_for(tb, B=2)
    max_new = 32 if quick else 96
    ra = static_trees.measure_rank_accept(
        tb.drafter, tb.d_params, tb.verifier, tb.v_params, prompt, lengths,
        k=4, iters=16)
    temps = (0.0, 0.5, 1.0)
    rows = []
    for t in temps:
        for system in ("sequoia", "yggdrasil"):
            if system == "sequoia":
                spec, v = common.structure_spec("sequoia", budget=12,
                                                depth=8, rank_accept=ra)
                plan = "staged_device"
            else:
                spec, v = common.structure_spec("egt", depth=4, width=4,
                                                budget=10)
                plan = "fused"
            eng = common.make_engine(tb, profile=prof, plan=plan,
                                     temperature=t)
            s = common.run_generate(eng, prompt, lengths, max_new,
                                    spec=spec, verify_v=v)
            rows.append({"temperature": t, "system": system,
                         "tpot_ms": s["tpot_ms"], "aal": s["aal"]})
    ratio = {}
    for t in temps:
        d = {r["system"]: r["tpot_ms"] for r in rows if r["temperature"] == t}
        ratio[t] = d["sequoia"] / d["yggdrasil"]
    out = {"rows": rows, "yggdrasil_over_sequoia": ratio}
    common.save("fig15_temperature", out)
    return out


if __name__ == "__main__":
    res = run()
    for r in res["rows"]:
        print({k: (round(v, 3) if isinstance(v, float) else v)
               for k, v in r.items()})
    print("speedup vs sequoia:", res["yggdrasil_over_sequoia"])
