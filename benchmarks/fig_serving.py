"""Serving benchmark: continuous batching vs run-to-completion batching.

A Poisson arrival trace (exponential interarrivals) is replayed in wall
clock against both servers on the CPU testbed:

  * ``BatchedServer``    — requests wait until a full batch forms, then the
    batch runs to completion (stragglers hold the batch; arrivals during a
    batch wait for the next one).
  * ``ContinuousServer`` — fixed slot pool, one megastep per scheduler
    tick, finished slots refilled mid-flight from the admission queue.

Reported per server: sustained throughput (tok/s over the makespan) and
p50/p95 request latency (arrival -> completion). The continuous row also
reports slot occupancy, AAL and recompiles-after-warmup (must be 0 — the
whole point of the static-shape megastep is surviving slot churn without
recompiling). Results land in benchmarks/results/fig_serving.json.

When more than one device is visible (real chips, or CPU devices emulated
with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``), the run also
sweeps data×model mesh shapes over the continuous server and records
per-shape throughput/latency under ``mesh_sweep`` — the per-PR record of
how sharding the speculative megastep behaves as the mesh changes. Every
sharded run must still report zero recompiles after warmup.

``quant_sweep`` compares the quantized serving path (int8 KV caches, and
int8-kv+w8 weight-only on top) against fp32 on an identical request set
driven queue-upfront (no wall-clock admission races, so token flow and AAL
are deterministic given the seeds): per mode it records cache bytes per
slot, the max concurrent slots a fixed cache-byte budget sustains (the
budget is what the fp32 pool uses — the ≥1.8x headline), throughput, the
AAL delta vs fp32 and recompiles-after-warmup (must stay 0: quantization
changes dtypes at trace time, never shapes at step time).

``adaptive_sweep`` compares adaptive bucket scheduling (a precompiled
ladder + the online controller) against every pinned ladder bucket on a
mixed short/long Poisson trace. Decode/prefill costs come from an
emulated-timing profile (the occupancy-aware step model of
objective.step_latency) driven on an emulated clock: CPU wall time is
dominated by interpreter overhead and cannot distinguish buckets, while
the emulated clock reproduces the saturation-knee economics the controller
schedules against. Adaptive must match or beat the best pinned bucket and
report zero recompiles after warmup despite switching buckets mid-trace.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from benchmarks import common
from repro.core.buckets import Bucket, buckets_for_depths
from repro.core.egt import egt_spec
from repro.core.engine import EngineConfig, SpeculativeEngine
from repro.core.objective import LatencyProfile
from repro.data.pipeline import MarkovSource
from repro.quant import QuantConfig
from repro.serving.config import ServeConfig
from repro.serving.continuous import ContinuousServer, slots_at_budget
from repro.serving.controller import BucketController
from repro.serving.emulation import drive_trace
from repro.serving.faults import FaultEvent, FaultPlan
from repro.serving.frontend import drive_frontend_trace
from repro.serving.server import BatchedServer, Request
from repro.telemetry import EmulatedClock, Telemetry, validate_chrome_trace


SPEC, VERIFY_V = egt_spec(4, 2), 6
# adaptive ladder: shallow/cheap through deep/expensive — the knee of the
# emulated profile makes the shallow bucket win at full pool and the deep
# ones win as the pool drains
ADAPTIVE_LADDER = (Bucket(2, 2, 4), Bucket(4, 2, 7), Bucket(8, 2, 13))


def make_trace(tb, n: int, rate_hz: float, max_new: int, seed: int = 0):
    """Poisson arrivals: [(arrival_s, Request)] sorted by arrival."""
    rng = np.random.default_rng(seed)
    src = MarkovSource(vocab=tb.spec.vocab,
                       concentration=tb.data_cfg.concentration,
                       seed=tb.data_cfg.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    out = []
    for uid in range(n):
        plen = int(rng.integers(8, 20))
        out.append((float(arrivals[uid]),
                    Request(uid=uid, prompt=src.sample(rng, plen),
                            max_new=max_new)))
    return out


def _engine(tb, mesh=None) -> SpeculativeEngine:
    return SpeculativeEngine(
        tb.drafter, tb.d_params, tb.verifier, tb.v_params,
        buckets=buckets_for_depths((4,), width=2, verify_frac=0.75),
        depth_options=(4,), config=EngineConfig(), mesh=mesh)


def feasible_mesh_shapes() -> List[Tuple[int, int]]:
    """data×model shapes the visible devices support: full data-parallel,
    full model-parallel, and the balanced split when it exists."""
    n = len(jax.devices())
    if n < 2:
        return []
    shapes = [(n, 1), (1, n)]
    if n % 2 == 0 and n > 2:
        shapes.append((n // 2, 2))
    return shapes


def _request_stats(done: Dict[int, Request], t0: float) -> Dict:
    lat = np.asarray([r.t_finish - r.t_submit for r in done.values()])
    toks = int(sum(len(r.result) for r in done.values()))
    makespan = max(r.t_finish for r in done.values()) - t0
    return {"requests": len(done), "tokens": toks,
            "makespan_s": float(makespan),
            "throughput_tok_s": toks / max(makespan, 1e-9),
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p95_s": float(np.percentile(lat, 95)),
            "latency_mean_s": float(lat.mean())}


def drive_continuous(tb, trace, batch: int, prompt_pad: int,
                     mesh=None) -> Dict:
    eng = _engine(tb, mesh=mesh)
    server = ContinuousServer(eng, batch_size=batch, prompt_pad=prompt_pad,
                              spec=SPEC, verify_v=VERIFY_V)
    server.warmup()
    pending: List = list(trace)
    t0 = time.perf_counter()
    while pending or server.queue or any(s is not None for s in server.slots):
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            arr, req = pending.pop(0)
            req.t_submit = t0 + arr
            server.submit(req)
        if server.queue or any(s is not None for s in server.slots):
            server.step()
        elif pending:
            time.sleep(min(pending[0][0] - now, 0.002))
    m = server.metrics.summary()
    return {**_request_stats(server.done, t0),
            "occupancy": m["occupancy"], "aal": m["aal"],
            "refills": m["refills"],
            "mesh_devices": m["mesh_devices"],
            "recompiles_after_warmup": m["recompiles_after_warmup"]}


def drive_batched(tb, trace, batch: int, prompt_pad: int) -> Dict:
    eng = _engine(tb)
    server = BatchedServer(eng, batch_size=batch, prompt_pad=prompt_pad)
    # warm the compile caches outside the timed trace, like warmup()
    wreq = Request(uid=-1, prompt=trace[0][1].prompt.copy(),
                   max_new=trace[0][1].max_new)
    server.submit(wreq)
    server.run()
    server.done.clear()
    pending: List = list(trace)
    t0 = time.perf_counter()
    while pending or server.queue:
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            arr, req = pending.pop(0)
            req.t_submit = t0 + arr
            server.submit(req)
        if len(server.queue) >= batch or (server.queue and not pending):
            server.step()
        elif pending:
            time.sleep(min(pending[0][0] - now, 0.002))
    return _request_stats(server.done, t0)


def make_mixed_trace(tb, n: int, rate_hz: float, short_new: int = 6,
                     long_new: int = 48, p_short: float = 0.7,
                     seed: int = 1, prompt_lo: int = 6, prompt_hi: int = 12):
    """Poisson arrivals with bimodal output lengths: mostly short requests
    (chat-style) plus a tail of long ones. Shorts retire fast and keep the
    pool churning; stragglers leave it half-empty — the occupancy swings
    adaptive scheduling exploits."""
    rng = np.random.default_rng(seed)
    src = MarkovSource(vocab=tb.spec.vocab,
                       concentration=tb.data_cfg.concentration,
                       seed=tb.data_cfg.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    out = []
    for uid in range(n):
        plen = int(rng.integers(prompt_lo, prompt_hi))
        max_new = short_new if rng.random() < p_short else long_new
        out.append((float(arrivals[uid]),
                    Request(uid=uid, prompt=src.sample(rng, plen),
                            max_new=max_new)))
    return out


def emulated_profile() -> LatencyProfile:
    """Emulated-timing profile with a pronounced saturation knee: flat
    (memory-bound) until 16 concurrent tree tokens, then steeply linear —
    so bucket cost depends on occupancy the way a real accelerator's does."""
    return LatencyProfile.synthetic(base_verify=1.0, slope=1.0,
                                    draft_frac=0.1, saturate_at=16,
                                    overhead=0.2)


def drive_emulated(tb, trace, batch: int, prompt_pad: int,
                   profile: LatencyProfile,
                   ladder: Optional[Tuple[Bucket, ...]] = None,
                   pinned: Optional[Bucket] = None) -> Dict:
    """Drive a trace on an emulated clock (serving.emulation): real token
    flow through the real engine, profile-charged step costs. Arrival times
    are in emulated seconds. Exactly one of ``ladder`` (adaptive) /
    ``pinned`` (one bucket) must be given."""
    eng = SpeculativeEngine(
        tb.drafter, tb.d_params, tb.verifier, tb.v_params, profile=profile,
        buckets=buckets_for_depths((4,), width=2, verify_frac=0.75),
        depth_options=(4,), config=EngineConfig())
    if ladder is not None:
        # min_dwell=0: profile-mode scores are noise-free (the EMAs move
        # slowly), so reacting to an occupancy change the step it happens
        # costs nothing and avoids paying a deep-bucket step at full pool
        server = ContinuousServer(
            eng, batch_size=batch, prompt_pad=prompt_pad, buckets=ladder,
            controller=BucketController(ladder, profile=profile,
                                        min_dwell=0, hysteresis=0.05))
    else:
        server = ContinuousServer(eng, batch_size=batch,
                                  prompt_pad=prompt_pad,
                                  spec=egt_spec(pinned.depth, pinned.width),
                                  verify_v=pinned.verify)
    emu = drive_trace(server, trace, profile)
    lat = np.asarray(list(emu["latencies_s"].values()))
    m = server.metrics.summary()
    return {"tokens": server.metrics.tokens_out,
            "busy_s": emu["busy_s"],
            "makespan_s": emu["makespan_s"],
            "throughput_tok_s": (server.metrics.tokens_out
                                 / max(emu["busy_s"], 1e-9)),
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p95_s": float(np.percentile(lat, 95)),
            "aal": m["aal"],
            "bucket_switches": m["bucket_switches"],
            "buckets": m["buckets"],
            "recompiles_after_warmup": m["recompiles_after_warmup"]}


def adaptive_sweep(tb, n: int, rate_hz: float, batch: int,
                   prompt_pad: int = 12,
                   ladder: Tuple[Bucket, ...] = ADAPTIVE_LADDER) -> Dict:
    """Adaptive ladder vs every pinned ladder bucket on the same mixed
    short/long trace (emulated clock). Adaptive should match or beat the
    best pinned bucket: it runs the shallow bucket while the pool is full
    and the deep ones as it drains. prompt_pad defaults low so the prefill
    charge stays under the profile knee and decode costs dominate."""
    profile = emulated_profile()
    mk = lambda: make_mixed_trace(tb, n, rate_hz)   # noqa: E731 — requests
    # are stateful (result/timestamps), so each drive gets a fresh trace
    out: Dict = {"ladder": ["x".join(map(str, b.key())) for b in ladder],
                 "trace": {"n": n, "rate_hz": rate_hz, "mixed": "70% short"}}
    out["adaptive"] = drive_emulated(tb, mk(), batch, prompt_pad, profile,
                                     ladder=ladder)
    out["pinned"] = {
        "x".join(map(str, b.key())): drive_emulated(tb, mk(), batch,
                                                    prompt_pad, profile,
                                                    pinned=b)
        for b in ladder}
    best = max(out["pinned"], key=lambda k: out["pinned"][k]["throughput_tok_s"])
    out["best_pinned"] = best
    out["adaptive_over_best_pinned"] = (
        out["adaptive"]["throughput_tok_s"]
        / max(out["pinned"][best]["throughput_tok_s"], 1e-9))
    return out


def quant_sweep(tb, n: int, max_new: int, batch: int,
                prompt_pad: int = 16) -> Dict:
    """Quantized vs fp32 continuous serving on one request set (submitted
    upfront; deterministic drain). Keys per mode: throughput, AAL,
    kv_bytes_per_slot, slots at the fp32 pool's cache-byte budget,
    recompiles. Top-level: slots_ratio (int8 over fp32 at fixed bytes) and
    aal_delta (int8-kv minus fp32 — ~0: greedy int8-KV decode is
    token-exact on this testbed, see tests/test_quant.py)."""
    src = MarkovSource(vocab=tb.spec.vocab,
                       concentration=tb.data_cfg.concentration,
                       seed=tb.data_cfg.seed)
    # prompts fixed up front so every mode serves the IDENTICAL request set
    # (a shared stateful rng inside requests() would drift per mode and the
    # AAL delta would measure workload, not quantization)
    plens = np.random.default_rng(11).integers(8, 14, size=n)
    prompts = [src.sample(np.random.default_rng(100 + uid), int(plens[uid]))
               for uid in range(n)]

    def requests():
        return [Request(uid=uid, prompt=prompts[uid].copy(), max_new=max_new)
                for uid in range(n)]

    out: Dict = {"config": {"n": n, "max_new": max_new, "batch": batch}}
    engines = {}
    for mode in ("none", "int8-kv", "int8-kv+w8"):
        eng = SpeculativeEngine(
            tb.drafter, tb.d_params, tb.verifier, tb.v_params,
            buckets=buckets_for_depths((4,), width=2, verify_frac=0.75),
            depth_options=(4,),
            config=EngineConfig(quant=QuantConfig.parse(mode)))
        engines[mode] = eng
        server = ContinuousServer(eng, batch_size=batch,
                                  prompt_pad=prompt_pad,
                                  spec=SPEC, verify_v=VERIFY_V)
        server.warmup()
        for req in requests():
            server.submit(req)
        server.serve()
        m = server.metrics.summary()
        out[mode] = {
            "throughput_tok_s": m["throughput_tok_s"],
            "tokens": m["tokens"],
            "aal": m["aal"],
            "kv_bytes_per_slot": m["kv_bytes_per_slot"],
            "recompiles_after_warmup": m["recompiles_after_warmup"],
        }
    # fixed HBM budget = what the fp32 pool pins at this batch size; the
    # quantized engines fit slots_ratio x as many slots into the same bytes
    budget = batch * out["none"]["kv_bytes_per_slot"]
    out["cache_byte_budget"] = budget
    for mode, eng in engines.items():
        out[mode]["slots_at_budget"] = slots_at_budget(eng, budget)
    out["slots_ratio"] = (out["int8-kv"]["slots_at_budget"]
                          / max(out["none"]["slots_at_budget"], 1))
    out["aal_delta"] = out["int8-kv"]["aal"] - out["none"]["aal"]
    return out


def kernel_traffic(tb) -> Dict:
    """Deterministic verify-kernel metrics for the regression gate.

    The byte numbers come from the analytic traffic model
    (repro.kernels.traffic) at a fixed GQA shape — pure arithmetic, so the
    gate is runner-independent: ``gqa_bytes_ratio`` (repeat_kv blow-up the
    fused kernel recovers at full length, ~num_q_per_kv x) and
    ``len_scaling_ratio`` (kernel bytes track the committed length; the
    XLA paths are flat at the max_len extent, ratio 1.0). The recompile
    probe then drives the REAL fused-kernel megastep through slot churn on
    the testbed: its ``recompiles_after_warmup`` must stay 0 like every
    other counter in the artifact.
    """
    from repro.kernels.ops import VERIFY_BLOCK_S
    from repro.kernels.traffic import bytes_summary
    shape = dict(w=8, kv_heads=2, num_q_per_kv=4, head_dim=64, s_cache=512)
    block_s = VERIFY_BLOCK_S  # the hot path's own skip granularity
    full = bytes_summary(**shape, lengths=[512] * 4, block_s=block_s)
    short = bytes_summary(**shape, lengths=[128] * 4, block_s=block_s)
    out: Dict = {
        "shape": {**shape, "batch": 4, "block_s": block_s},
        "kernel_bytes_len128": short["kernel_bytes"],
        "kernel_bytes_len512": full["kernel_bytes"],
        "xla_repeated_bytes": full["xla_repeated_bytes"],
        "gqa_bytes_ratio": full["repeated_over_kernel"],
        "len_scaling_ratio": (full["kernel_bytes"]
                              / max(short["kernel_bytes"], 1)),
    }
    eng = SpeculativeEngine(
        tb.drafter, tb.d_params, tb.verifier, tb.v_params,
        buckets=buckets_for_depths((4,), width=2, verify_frac=0.75),
        depth_options=(4,), config=EngineConfig(verify_kernel="fused"))
    state = eng.init_decode_state(2)
    prompt = np.arange(1, 9, dtype=np.int32)
    # warm every executable the churn loop replays (megastep, slot
    # prefill, slot reset), then any further compile is a regression
    state = eng.prefill_into_slot(state, 0, prompt, len(prompt))
    state = eng.prefill_into_slot(state, 1, prompt, len(prompt))
    state, _ = eng.decode_step(state, spec=SPEC, verify_v=VERIFY_V)
    state = eng.reset_state_slot(state, 0)
    state = eng.prefill_into_slot(state, 0, prompt, len(prompt))
    warm = eng.executable_count()
    for i in range(3):
        state = eng.reset_state_slot(state, i % 2)
        state = eng.prefill_into_slot(state, i % 2, prompt, len(prompt))
        state, _ = eng.decode_step(state, spec=SPEC, verify_v=VERIFY_V)
    out["kernel_path"] = {
        "verify_path": eng.verify_path(),
        "recompiles_after_warmup": eng.executable_count() - warm,
    }
    return out


def _trace_lifecycle_checks(trace: Dict) -> Dict[str, bool]:
    """Scan an exported Chrome trace for the acceptance-criterion shapes:
    per-megastep draft/verify/accept stage spans (staged plan) and at least
    one full request lifecycle (queued span -> active span -> retired
    instant on one ``req:*`` track)."""
    tid_name: Dict[int, str] = {}
    per_tid_names: Dict[int, set] = {}
    all_names: set = set()
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            tid_name[ev["tid"]] = ev["args"]["name"]
        else:
            per_tid_names.setdefault(ev["tid"], set()).add(ev["name"])
            all_names.add(ev["name"])
    lifecycle = any(name.startswith("req:")
                    and {"queued", "active", "retired"}
                    <= per_tid_names.get(tid, set())
                    for tid, name in tid_name.items())
    return {"stage_spans": {"draft", "verify", "accept",
                            "commit"} <= all_names,
            "request_lifecycle": lifecycle}


def telemetry_sweep(tb, n: int, max_new: int, batch: int,
                    prompt_pad: int = 16, rate_hz: float = 0.6) -> Dict:
    """The observability layer's gated contracts, measured end-to-end:

      * token_exact       — the emulated Poisson trace served with telemetry
                            fully enabled emits the exact token sequences of
                            the telemetry-off run (greedy decode);
      * overhead_frac     — telemetry self-time (every tracer/registry call
                            carries a perf_counter pair) over wall decode
                            time on an upfront-drained queue, gated < 2%;
      * emulated_snapshot_deterministic — two identical emulated drives
                            export byte-identical registry snapshots AND
                            Chrome traces (the clock-mixing fix: no wall
                            timestamps leak into emulated artifacts);
      * trace_valid       — a staged-plan run's Chrome-trace export passes
                            ``validate_chrome_trace`` and contains the
                            stage spans + one full request lifecycle; the
                            trace is saved to results/serving_trace.json
                            for the CI artifact upload.

    All four land in HARD_BOUNDS in check_regression.py.
    """
    profile = emulated_profile()

    def spec_engine(plan: str = "fused") -> SpeculativeEngine:
        return SpeculativeEngine(
            tb.drafter, tb.d_params, tb.verifier, tb.v_params,
            profile=profile,
            buckets=buckets_for_depths((4,), width=2, verify_frac=0.75),
            depth_options=(4,), config=EngineConfig(plan=plan))

    def emu_drive(telemetry: Optional[Telemetry]) -> ContinuousServer:
        # fresh engine per drive: shared compile caches would make the two
        # determinism runs' compile counters (snapshotted via callback
        # gauges) differ
        server = ContinuousServer(spec_engine(), batch_size=batch,
                                  prompt_pad=prompt_pad, spec=SPEC,
                                  verify_v=VERIFY_V, telemetry=telemetry)
        drive_trace(server, make_trace(tb, n, rate_hz, max_new, seed=7),
                    profile)
        return server

    out: Dict = {"config": {"n": n, "max_new": max_new, "batch": batch,
                            "rate_hz": rate_hz}}

    # -- token exactness: telemetry off vs fully on, same emulated trace --
    srv_off = emu_drive(None)
    tel_on = Telemetry(clock=EmulatedClock())
    srv_on = emu_drive(tel_on)
    out["token_exact"] = float(
        set(srv_off.done) == set(srv_on.done)
        and all(np.array_equal(srv_off.done[u].result, srv_on.done[u].result)
                for u in srv_off.done))
    out["off"] = {"recompiles_after_warmup":
                  srv_off.metrics.summary()["recompiles_after_warmup"]}
    out["on"] = {"recompiles_after_warmup":
                 srv_on.metrics.summary()["recompiles_after_warmup"]}

    # -- emulated determinism: a second identical drive must export the --
    # -- byte-identical snapshot and trace                              --
    tel_on2 = Telemetry(clock=EmulatedClock())
    emu_drive(tel_on2)

    def exports(tel: Telemetry) -> Tuple[str, str]:
        snap = json.dumps(tel.registry.snapshot(), sort_keys=True,
                          default=float)
        return snap, json.dumps(tel.tracer.to_chrome_trace(), sort_keys=True)

    s1, t1 = exports(tel_on)
    s2, t2 = exports(tel_on2)
    out["emulated_snapshot_deterministic"] = float(s1 == s2 and t1 == t2)

    # -- overhead: wall-clock upfront-drained queue, self-time / decode --
    src = MarkovSource(vocab=tb.spec.vocab,
                       concentration=tb.data_cfg.concentration,
                       seed=tb.data_cfg.seed)
    plens = np.random.default_rng(23).integers(8, 14, size=n)
    prompts = [src.sample(np.random.default_rng(700 + uid), int(plens[uid]))
               for uid in range(n)]
    tel_wall = Telemetry()
    srv_wall = ContinuousServer(spec_engine(), batch_size=batch,
                                prompt_pad=prompt_pad, spec=SPEC,
                                verify_v=VERIFY_V, telemetry=tel_wall)
    srv_wall.warmup()
    for uid in range(n):
        srv_wall.submit(Request(uid=uid, prompt=prompts[uid].copy(),
                                max_new=max_new))
    srv_wall.serve()
    decode_s = srv_wall.metrics.iter_times.total
    out["overhead_seconds"] = tel_wall.overhead_seconds()
    out["decode_seconds"] = decode_s
    out["overhead_frac"] = tel_wall.overhead_seconds() / max(decode_s, 1e-9)
    out["wall"] = {"recompiles_after_warmup":
                   srv_wall.metrics.summary()["recompiles_after_warmup"]}

    # -- staged-plan mini-run: host-visible draft/verify/accept/commit --
    # -- spans + a full request lifecycle, exported and validated      --
    tel_staged = Telemetry()
    srv_staged = ContinuousServer(spec_engine(plan="staged"),
                                  batch_size=2, prompt_pad=prompt_pad,
                                  spec=SPEC, verify_v=VERIFY_V,
                                  telemetry=tel_staged)
    srv_staged.warmup()
    for uid in range(2):
        srv_staged.submit(Request(uid=uid, prompt=prompts[uid].copy(),
                                  max_new=min(max_new, 8)))
    srv_staged.serve()
    trace = tel_staged.tracer.to_chrome_trace()
    errs = validate_chrome_trace(trace)
    checks = _trace_lifecycle_checks(trace)
    out["trace_errors"] = errs[:5]
    out["trace_checks"] = checks
    out["trace_valid"] = float(not errs and all(checks.values()))
    out["staged"] = {"recompiles_after_warmup":
                     srv_staged.metrics.summary()["recompiles_after_warmup"]}
    common.save("serving_trace", trace)
    return out


def make_bimodal_prompt_trace(tb, n: int, rate_hz: float,
                              prompt_short: Tuple[int, int] = (6, 12),
                              prompt_long: Tuple[int, int] = (36, 46),
                              p_short: float = 0.7, max_new: int = 12,
                              seed: int = 5):
    """Poisson arrivals with bimodal PROMPT lengths: mostly short chat-style
    prompts plus a tail of long documents. Under monolithic prefill every
    admission — short or long — stalls the pool for one prompt-pad-width
    verifier call (the head-of-line killer); chunked prefill pays per chunk
    actually run, so this trace is where the lane earns its p95/p99 gate."""
    rng = np.random.default_rng(seed)
    src = MarkovSource(vocab=tb.spec.vocab,
                       concentration=tb.data_cfg.concentration,
                       seed=tb.data_cfg.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    out = []
    for uid in range(n):
        lo, hi = prompt_short if rng.random() < p_short else prompt_long
        plen = int(rng.integers(lo, hi))
        out.append((float(arrivals[uid]),
                    Request(uid=uid, prompt=src.sample(rng, plen),
                            max_new=max_new)))
    return out


def chunked_prefill_sweep(tb, n: int, rate_hz: float = 0.4, batch: int = 4,
                          prompt_pad: int = 48,
                          chunks: Tuple[int, ...] = (8, 16)) -> Dict:
    """Chunked vs monolithic prefill on the bimodal prompt trace (emulated
    clock, byte-deterministic). Monolithic charges every admission one
    prompt-pad-width verifier call — deep past the emulated profile's
    saturation knee — while chunked charges the chunk widths the lane
    actually ran. Gated: p95/p99 strictly better than monolithic, chunking
    must not give back throughput, greedy decode token-exact vs monolithic
    on an identical upfront request set, and zero recompiles across
    chunk-count churn (every admission re-enters the lane)."""
    profile = emulated_profile()

    def server(chunked: bool) -> ContinuousServer:
        eng = SpeculativeEngine(
            tb.drafter, tb.d_params, tb.verifier, tb.v_params,
            profile=profile,
            buckets=buckets_for_depths((4,), width=2, verify_frac=0.75),
            depth_options=(4,), config=EngineConfig())
        return ContinuousServer(eng, batch_size=batch,
                                prompt_pad=prompt_pad, spec=SPEC,
                                verify_v=VERIFY_V,
                                prefill_chunks=chunks if chunked else None)

    out: Dict = {"config": {"n": n, "rate_hz": rate_hz, "batch": batch,
                            "prompt_pad": prompt_pad, "chunks": list(chunks),
                            "trace": "70% short / 30% long prompts"}}
    for name, chunked in (("monolithic", False), ("chunked", True)):
        srv = server(chunked)
        emu = drive_trace(srv, make_bimodal_prompt_trace(tb, n, rate_hz),
                          profile)
        lat = np.asarray(list(emu["latencies_s"].values()))
        m = srv.metrics.summary()
        out[name] = {
            "tokens": m["tokens"],
            "busy_s": emu["busy_s"],
            "makespan_s": emu["makespan_s"],
            "throughput_tok_s": m["tokens"] / max(emu["makespan_s"], 1e-9),
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p95_s": float(np.percentile(lat, 95)),
            "latency_p99_s": float(np.percentile(lat, 99)),
            "aal": m["aal"],
            "prefill_chunks": m["prefill_chunks"],
            "prefill_chunk_tokens": m["prefill_chunk_tokens"],
            "recompiles_after_warmup": m["recompiles_after_warmup"],
        }
    out["p95_speedup"] = (out["monolithic"]["latency_p95_s"]
                          / max(out["chunked"]["latency_p95_s"], 1e-9))
    out["p99_speedup"] = (out["monolithic"]["latency_p99_s"]
                          / max(out["chunked"]["latency_p99_s"], 1e-9))
    out["throughput_ratio"] = (out["chunked"]["throughput_tok_s"]
                               / max(out["monolithic"]["throughput_tok_s"],
                                     1e-9))

    # greedy token-exactness: the IDENTICAL upfront request set drained both
    # ways on fresh engines — chunked prefill must change scheduling only,
    # never a single emitted token
    src = MarkovSource(vocab=tb.spec.vocab,
                       concentration=tb.data_cfg.concentration,
                       seed=tb.data_cfg.seed)
    plens = np.random.default_rng(31).integers(6, prompt_pad - 2, size=n)
    prompts = [src.sample(np.random.default_rng(900 + uid), int(plens[uid]))
               for uid in range(n)]

    def drain(chunked: bool) -> ContinuousServer:
        srv = server(chunked)
        srv.warmup()
        for uid in range(n):
            srv.submit(Request(uid=uid, prompt=prompts[uid].copy(),
                               max_new=12))
        srv.serve()
        return srv

    s_mono, s_chunk = drain(False), drain(True)
    out["token_exact"] = float(
        set(s_mono.done) == set(s_chunk.done)
        and all(np.array_equal(s_mono.done[u].result, s_chunk.done[u].result)
                for u in s_mono.done))
    out["exactness_check"] = {
        "monolithic": {"recompiles_after_warmup":
                       s_mono.metrics.summary()["recompiles_after_warmup"]},
        "chunked": {"recompiles_after_warmup":
                    s_chunk.metrics.summary()["recompiles_after_warmup"]},
    }
    return out


def make_shared_prefix_trace(tb, n: int, rate_hz: float, prefix_tokens: int,
                             max_new: int = 12, seed: int = 9):
    """Poisson arrivals where every request opens with the SAME system
    prefix (``prefix_tokens`` long) and ends in a short unique tail — the
    multi-tenant chat regime the paged prefix store targets. Requests are
    stateful, so every drive builds its own copy; the fresh per-call rng
    keeps the contiguous and paged drives on the byte-identical workload."""
    rng = np.random.default_rng(seed)
    src = MarkovSource(vocab=tb.spec.vocab,
                       concentration=tb.data_cfg.concentration,
                       seed=tb.data_cfg.seed)
    prefix = src.sample(rng, prefix_tokens)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    out = []
    for uid in range(n):
        tail = src.sample(rng, int(rng.integers(4, 10)))
        out.append((float(arrivals[uid]),
                    Request(uid=uid,
                            prompt=np.concatenate([prefix, tail]),
                            max_new=max_new)))
    return out


def paged_sweep(tb, n: int, rate_hz: float = 0.5, batch: int = 4,
                page_len: int = 8, prefix_pages: int = 2,
                prompt_pad: int = 32) -> Dict:
    """Paged vs contiguous KV cache on the shared-prefix Poisson trace
    (emulated clock, chunked admission — both deterministic). Every request
    opens with the same two-page system prefix, so after the first
    admission the prefix store serves those pages from residency and the
    lane skips their prefill (copy-on-write: divergent tails land in
    private pages).

    Gated in check_regression.py: greedy decode token-exact vs the
    contiguous drive, ``prefix_hit_rate`` > 0 (the store actually hits),
    ``slots_at_fixed_hbm_ratio`` > 1.5 — the bytes the contiguous pool
    pins for this batch over what the paged pool ACTUALLY used at its
    high-water mark (shared prefix pages counted once) — and zero
    recompiles despite page alloc/free churn on every slot recycle."""
    profile = emulated_profile()
    engines: Dict[str, SpeculativeEngine] = {}
    servers: Dict[str, ContinuousServer] = {}

    def drive(layout: str) -> Dict:
        cfg = (EngineConfig(cache_layout="paged", page_len=page_len)
               if layout == "paged" else EngineConfig())
        eng = SpeculativeEngine(
            tb.drafter, tb.d_params, tb.verifier, tb.v_params,
            profile=profile,
            buckets=buckets_for_depths((4,), width=2, verify_frac=0.75),
            depth_options=(4,), config=cfg)
        srv = ContinuousServer(eng, batch_size=batch, prompt_pad=prompt_pad,
                               spec=SPEC, verify_v=VERIFY_V,
                               prefill_chunks=(8, 16))
        engines[layout], servers[layout] = eng, srv
        return drive_trace(srv, make_shared_prefix_trace(
            tb, n, rate_hz, prefix_pages * page_len), profile)

    out: Dict = {"config": {"n": n, "rate_hz": rate_hz, "batch": batch,
                            "page_len": page_len,
                            "prefix_tokens": prefix_pages * page_len,
                            "prompt_pad": prompt_pad}}
    for layout in ("contiguous", "paged"):
        emu = drive(layout)
        lat = np.asarray(list(emu["latencies_s"].values()))
        m = servers[layout].metrics.summary()
        out[layout] = {
            "tokens": m["tokens"],
            "makespan_s": emu["makespan_s"],
            "throughput_tok_s": m["tokens"] / max(emu["makespan_s"], 1e-9),
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p95_s": float(np.percentile(lat, 95)),
            "aal": m["aal"],
            "refills": m["refills"],
            "recompiles_after_warmup": m["recompiles_after_warmup"],
        }
    mp = servers["paged"].metrics.summary()
    out["paged"].update({
        "prefix_lookups": mp["prefix_lookups"],
        "prefix_hits": mp["prefix_hits"],
        "prefix_hit_tokens": mp["prefix_hit_tokens"],
        "peak_pages_in_use": mp["peak_pages_in_use"],
    })
    out["prefix_hit_rate"] = mp["prefix_hit_rate"]

    s_c, s_p = servers["contiguous"], servers["paged"]
    out["token_exact"] = float(
        set(s_c.done) == set(s_p.done)
        and all(np.array_equal(s_c.done[u].result, s_p.done[u].result)
                for u in s_c.done))

    # HBM headline: a contiguous slot pins max_target_len rows whether the
    # request uses them or not; a paged slot occupies only its live pages
    # and shared prefix pages are stored once. Page bytes come from the
    # engine's own repricing (the marginal second page, so any fixed
    # per-slot overhead cancels out).
    contig_slot = engines["contiguous"].cache_bytes_per_slot()["total"]
    ep = engines["paged"]
    page_bytes = (ep.cache_bytes_per_slot(live_tokens=2 * page_len)["total"]
                  - ep.cache_bytes_per_slot(live_tokens=page_len)["total"])
    out["contiguous_pool_bytes"] = batch * contig_slot
    out["paged_peak_bytes"] = mp["peak_pages_in_use"] * page_bytes
    out["slots_at_fixed_hbm_ratio"] = (
        batch * contig_slot / max(mp["peak_pages_in_use"] * page_bytes, 1))
    return out


def make_slo_trace(tb, n: int, rate_hz: float, deadline_s: float = 40.0,
                   short_new: int = 8, long_new: int = 32,
                   p_short: float = 0.7, sessions: int = 4, seed: int = 3):
    """Bimodal Poisson arrivals with per-request SLO deadlines and session
    ids — rows ``(arrival_emu_s, Request, extras)`` for the front-end's
    emulated drive. Same seed -> byte-identical trace (requests are
    stateful, so every drive builds its own copy)."""
    rng = np.random.default_rng(seed)
    src = MarkovSource(vocab=tb.spec.vocab,
                       concentration=tb.data_cfg.concentration,
                       seed=tb.data_cfg.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    out = []
    for uid in range(n):
        plen = int(rng.integers(6, 12))
        max_new = short_new if rng.random() < p_short else long_new
        out.append((float(arrivals[uid]),
                    Request(uid=uid, prompt=src.sample(rng, plen),
                            max_new=max_new),
                    {"deadline_s": deadline_s,
                     "session": f"sess-{uid % sessions}"}))
    return out


def _build_frontend(tb, profile: LatencyProfile, replicas: int, batch: int):
    # built through the same ServeConfig helpers the launcher uses, so the
    # bench measures exactly the topology `--server frontend` serves
    cfg = ServeConfig(server="frontend", replicas=replicas, batch=batch,
                      depth=SPEC.depth, width=SPEC.width, prompt_pad=12)
    return cfg.build_frontend(tb, profile=profile)


def frontend_sweep(tb, n: int, rate_hz: float = 0.25,
                   deadline_s: float = 40.0) -> Dict:
    """Goodput-under-SLO: one async front-end, two topologies, same trace.

      * ``single`` — 1 replica x batch 4 (scale-UP: all slots share one
        engine, so a full pool runs 4x6=24 concurrent tree tokens — past
        the emulated profile's saturation knee at 16, ~9 emu-s per step);
      * ``router`` — 2 replicas x batch 2 (scale-OUT: 2x6=12 tokens per
        replica stays under the knee, ~1.3 emu-s per step) behind the
        session-affine router, with a drain + scale-up event mid-trace.

    Same slot count, same requests, same deadlines — the router side must
    deliver a strictly higher fraction of tokens within SLO
    (``router_over_single`` > 1, hard-bounded in check_regression.py), and
    two identical router drives must produce the byte-identical artifact
    (``deterministic``). Every replica must report zero recompiles across
    admission, affinity re-pins and the drain/scale-up cycle."""
    profile = emulated_profile()
    mk = lambda: make_slo_trace(tb, n, rate_hz, deadline_s=deadline_s)
    events = ((15.0, "drain", 1), (30.0, "scale_up", 1))
    single = drive_frontend_trace(_build_frontend(tb, profile, 1, 4),
                                  mk(), profile)
    router = drive_frontend_trace(_build_frontend(tb, profile, 2, 2),
                                  mk(), profile, events=events)
    rerun = drive_frontend_trace(_build_frontend(tb, profile, 2, 2),
                                 mk(), profile, events=events)
    blob = lambda r: json.dumps(r, sort_keys=True, default=float)
    return {
        "config": {"n": n, "rate_hz": rate_hz, "deadline_s": deadline_s,
                   "events": [list(e) for e in events],
                   "spec": {"depth": SPEC.depth, "width": SPEC.width}},
        "single": single,
        "router": router,
        "deterministic": float(blob(router) == blob(rerun)),
        "router_over_single": (router["goodput_under_slo"]
                               / max(single["goodput_under_slo"], 1e-9)),
    }


FAULT_SEEDS = (101, 202, 303)


def _fault_plan(seed: int) -> FaultPlan:
    """One deterministic chaos schedule per seed: every fault kind fires
    once, at a seeded jitter inside its own window, alternating replicas.
    The windows are disjoint so recovery from one fault is underway (or
    done) before the next lands — the sweep measures fail->replay->recover
    cycles, not a pile-up that sheds the whole trace."""
    rng = np.random.default_rng(seed)
    t = lambda lo, hi: float(rng.uniform(lo, hi))        # noqa: E731
    return FaultPlan([
        FaultEvent(t(4.0, 8.0), "crash", 0),
        FaultEvent(t(10.0, 14.0), "hang", 1, duration_s=2.0),
        FaultEvent(t(16.0, 20.0), "nan", 0),
        FaultEvent(t(22.0, 26.0), "pool_exhaust", 1, duration_s=3.0,
                   pages=2),
        FaultEvent(t(28.0, 30.0), "error", 0, duration_s=0.5),
    ], seed=seed)


def fault_sweep(tb, n: int = 10, rate_hz: float = 0.3,
                deadline_s: float = 40.0,
                seeds: Tuple[int, ...] = FAULT_SEEDS) -> Dict:
    """Chaos gate: the 2-replica front-end under a seeded fault schedule
    (crash, hang, NaN logits, paged-pool exhaustion, transient error) vs
    the fault-free drive of the byte-identical trace.

    Hard-bounded in check_regression.py:

      * ``replay_token_exact`` — every request completes with the exact
        tokens of the fault-free run (greedy decode + verifier gating make
        the replayed prefix resume deterministically), for every seed;
      * ``lost_requests`` — nothing is shed or dropped across any
        fail->evacuate->replay->recover cycle;
      * ``recompiles_after_recovery`` — replays re-enter the warmed
        prefill-chunk lanes; a fault must never cost a compile;
      * ``deterministic`` — the faulted drive re-run with an identically
        rebuilt plan produces the byte-identical artifact.

    ``goodput_under_faults`` (mean over seeds) is baseline-gated: faults
    cost real emulated time (backoff, replays), and that cost must not
    silently grow."""
    profile = emulated_profile()

    def front():
        # paged layout so pool_exhaust has a free list to steal from;
        # step_timeout must cover the hang budget; one extra retry of
        # headroom over the single-replay schedule
        cfg = ServeConfig(server="frontend", replicas=2, batch=2,
                          depth=SPEC.depth, width=SPEC.width, prompt_pad=12,
                          prefill_chunk="4,8", cache_layout="paged",
                          page_len=8, retry_budget=3, step_timeout=2.0)
        return cfg.build_frontend(tb, profile=profile)

    out: Dict = {"config": {"n": n, "rate_hz": rate_hz,
                            "deadline_s": deadline_s, "seeds": list(seeds),
                            "fault_kinds": ["crash", "hang", "nan",
                                            "pool_exhaust", "error"]},
                 "seeds": {}}
    blob = lambda r: json.dumps(r, sort_keys=True, default=float)  # noqa: E731
    exact, det, lost, recompiles = [], [], 0, 0
    goodput, clean_goodput, injected, replays = [], [], 0, 0
    for seed in seeds:
        mk = lambda: make_slo_trace(tb, n, rate_hz, deadline_s=deadline_s,  # noqa: E731
                                    seed=seed)
        clean = drive_frontend_trace(front(), mk(), profile)
        faulty = drive_frontend_trace(front(), mk(), profile,
                                      faults=_fault_plan(seed))
        rerun = drive_frontend_trace(front(), mk(), profile,
                                     faults=_fault_plan(seed))
        reps = faulty["router"]["replicas"]
        row = {
            "clean": {k: clean[k] for k in
                      ("completed", "goodput_under_slo", "makespan_s",
                       "results_digest")},
            "faulty": {k: faulty[k] for k in
                       ("completed", "sheds", "faults", "replica_failures",
                        "replays", "goodput_under_slo", "makespan_s",
                        "results_digest")},
            "faults": faulty["faults"],
            "replicas": reps,
            "token_exact": float(faulty["results_digest"]
                                 == clean["results_digest"]),
            "deterministic": float(blob(faulty) == blob(rerun)),
            # shed counts as lost: the gate's contract is that no fault
            # schedule may cost a request its completion
            "lost_requests": faulty["submitted"] - faulty["completed"],
        }
        out["seeds"][str(seed)] = row
        exact.append(row["token_exact"])
        det.append(row["deterministic"])
        lost += row["lost_requests"]
        recompiles = max(recompiles, max(
            int(r["recompiles_after_warmup"]) for r in reps.values()))
        goodput.append(faulty["goodput_under_slo"])
        clean_goodput.append(clean["goodput_under_slo"])
        injected += faulty["faults"]["faults_injected"]
        replays += faulty["replays"]
    out.update({
        "replay_token_exact": min(exact),
        "deterministic": min(det),
        "lost_requests": int(lost),
        "recompiles_after_recovery": int(recompiles),
        "goodput_under_faults": float(np.mean(goodput)),
        "clean_goodput": float(np.mean(clean_goodput)),
        "faults_injected": int(injected),
        "replays": int(replays),
    })
    return out


def sweep_meshes(tb, n: int, rate_hz: float, max_new: int, batch: int,
                 prompt_pad: int,
                 shapes: Optional[List[Tuple[int, int]]] = None,
                 baseline: Optional[Dict] = None) -> Dict:
    """Continuous serving across data×model mesh shapes (same trace per
    shape), keyed "DxM"; "unsharded" is the single-device baseline row
    (pass ``baseline`` to reuse an already-measured run of the same
    trace/rate instead of re-driving it)."""
    out: Dict[str, Dict] = {
        "unsharded": baseline if baseline is not None else drive_continuous(
            tb, make_trace(tb, n, rate_hz, max_new), batch, prompt_pad)}
    for d, m in (feasible_mesh_shapes() if shapes is None else shapes):
        mesh = jax.make_mesh((d, m), ("data", "model"))
        out[f"{d}x{m}"] = drive_continuous(
            tb, make_trace(tb, n, rate_hz, max_new), batch, prompt_pad,
            mesh=mesh)
    return out


def run(quick: bool = True, mesh_sweep: bool = True):
    n = 12 if quick else 48
    max_new = 24 if quick else 64
    batch, prompt_pad = 4, 24
    tb = common.testbed()

    out = {"config": {"n_requests": n, "max_new": max_new, "batch": batch,
                      "devices": len(jax.devices()),
                      "spec": {"depth": SPEC.depth, "width": SPEC.width,
                               "verify_v": VERIFY_V}},
           "servers": {}}
    # rate chosen so the pool is load-bearing: a few arrivals per batch-time
    for rate_hz in ((4.0,) if quick else (2.0, 8.0)):
        trace_c = make_trace(tb, n, rate_hz, max_new)
        trace_b = make_trace(tb, n, rate_hz, max_new)
        res = {"continuous": drive_continuous(tb, trace_c, batch, prompt_pad),
               "batched": drive_batched(tb, trace_b, batch, prompt_pad)}
        res["latency_p50_speedup"] = (res["batched"]["latency_p50_s"]
                                      / max(res["continuous"]["latency_p50_s"], 1e-9))
        out["servers"][f"rate_{rate_hz:g}hz"] = res
    shapes = feasible_mesh_shapes()
    if mesh_sweep and shapes:   # single-device hosts have nothing to sweep
        # quick mode already measured the identical unsharded 4 Hz run above
        base = out["servers"].get("rate_4hz", {}).get("continuous")
        out["mesh_sweep"] = sweep_meshes(tb, n, 4.0, max_new, batch,
                                         prompt_pad, shapes=shapes,
                                         baseline=base)
    # adaptive vs pinned buckets on a mixed-length trace (emulated clock;
    # rate in emulated Hz — inter-arrivals comparable to a few step costs
    # so occupancy actually swings)
    out["adaptive_sweep"] = adaptive_sweep(tb, n, rate_hz=0.6, batch=batch)
    # int8 KV / weight quantization vs fp32 at fixed cache bytes
    out["quant_sweep"] = quant_sweep(tb, max(6, n // 2), max_new, batch)
    # fused verify-kernel traffic model + kernel-path recompile probe
    out["kernel_traffic"] = kernel_traffic(tb)
    # observability contracts: token-exactness, overhead, determinism,
    # trace validity (also writes results/serving_trace.json)
    out["telemetry"] = telemetry_sweep(tb, max(6, n // 2), max_new, batch)
    # async front-end: scale-out router vs scale-up single replica on
    # goodput under SLO (emulated clock; drain/scale-up event mid-trace)
    out["frontend_sweep"] = frontend_sweep(tb, n)
    # chaos gate: seeded crash/hang/NaN/pool-exhaust/error schedule against
    # the 2-replica front-end — token-exact replay, zero lost requests,
    # zero recompiles through fail->recover (emulated clock)
    out["fault_sweep"] = fault_sweep(tb)
    # chunked prefill lane vs monolithic head-of-line stall on a bimodal
    # short/long prompt trace (emulated clock) + greedy exactness check
    out["chunked_prefill_sweep"] = chunked_prefill_sweep(tb, n)
    # paged KV cache vs contiguous on shared-prefix traffic: exactness,
    # prefix-store hit rate, and the high-water HBM ratio (emulated clock)
    out["paged_sweep"] = paged_sweep(tb, n)
    common.save("fig_serving", out)
    return out


def _print_faults(fl: Dict) -> None:
    print(f"faults [{','.join(map(str, fl['config']['seeds']))}]: "
          f"token_exact={fl['replay_token_exact']:.0f}  "
          f"lost={fl['lost_requests']}  "
          f"deterministic={fl['deterministic']:.0f}  "
          f"recompiles={fl['recompiles_after_recovery']}  "
          f"injected={fl['faults_injected']}  replays={fl['replays']}  "
          f"goodput {fl['goodput_under_faults']:.3f} "
          f"(clean {fl['clean_goodput']:.3f})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="bigger trace (48 requests, 2 arrival rates)")
    ap.add_argument("--no-mesh-sweep", action="store_true",
                    help="skip the data×model mesh-shape sweep")
    ap.add_argument("--faults-only", action="store_true",
                    help="run only the chaos fault_sweep and write "
                         "results/fig_faults.json (the CI chaos job)")
    cli = ap.parse_args()
    if cli.faults_only:
        fl = fault_sweep(common.testbed())
        common.save("fig_faults", {"fault_sweep": fl})
        _print_faults(fl)
        raise SystemExit(0)
    res = run(quick=not cli.full, mesh_sweep=not cli.no_mesh_sweep)
    for rate, r in res["servers"].items():
        c, b = r["continuous"], r["batched"]
        print(f"{rate}: continuous {c['throughput_tok_s']:.0f} tok/s "
              f"p50={c['latency_p50_s'] * 1e3:.0f}ms p95={c['latency_p95_s'] * 1e3:.0f}ms "
              f"occ={c['occupancy']:.2f} recompiles={c['recompiles_after_warmup']} | "
              f"batched {b['throughput_tok_s']:.0f} tok/s "
              f"p50={b['latency_p50_s'] * 1e3:.0f}ms p95={b['latency_p95_s'] * 1e3:.0f}ms")
    for shape, c in res.get("mesh_sweep", {}).items():
        print(f"mesh {shape}: {c['throughput_tok_s']:.0f} tok/s "
              f"p50={c['latency_p50_s'] * 1e3:.0f}ms "
              f"p95={c['latency_p95_s'] * 1e3:.0f}ms "
              f"devices={c['mesh_devices']} "
              f"recompiles={c['recompiles_after_warmup']}")
    adp = res.get("adaptive_sweep")
    if adp:
        a = adp["adaptive"]
        print(f"adaptive [{','.join(adp['ladder'])}]: "
              f"{a['throughput_tok_s']:.2f} tok/emu-s  "
              f"switches={a['bucket_switches']}  "
              f"recompiles={a['recompiles_after_warmup']}")
        for bk, p in adp["pinned"].items():
            print(f"  pinned {bk}: {p['throughput_tok_s']:.2f} tok/emu-s")
        print(f"  adaptive / best pinned ({adp['best_pinned']}): "
              f"{adp['adaptive_over_best_pinned']:.2f}x")
    qs = res.get("quant_sweep")
    if qs:
        for mode in ("none", "int8-kv", "int8-kv+w8"):
            r = qs[mode]
            print(f"quant {mode}: {r['throughput_tok_s']:.0f} tok/s  "
                  f"aal={r['aal']:.2f}  "
                  f"kv_bytes/slot={r['kv_bytes_per_slot']}  "
                  f"slots@budget={r['slots_at_budget']}  "
                  f"recompiles={r['recompiles_after_warmup']}")
        print(f"  int8-kv slots at fixed cache bytes: "
              f"{qs['slots_ratio']:.2f}x fp32  "
              f"(aal delta {qs['aal_delta']:+.3f})")
    kt = res.get("kernel_traffic")
    if kt:
        print(f"verify kernel: repeat-KV bytes recovered "
              f"{kt['gqa_bytes_ratio']:.2f}x  length scaling "
              f"{kt['len_scaling_ratio']:.2f}x  "
              f"recompiles={kt['kernel_path']['recompiles_after_warmup']}")
    tm = res.get("telemetry")
    if tm:
        print(f"telemetry: token_exact={tm['token_exact']:.0f}  "
              f"overhead={tm['overhead_frac'] * 100:.2f}% of decode  "
              f"deterministic={tm['emulated_snapshot_deterministic']:.0f}  "
              f"trace_valid={tm['trace_valid']:.0f}")
    cp = res.get("chunked_prefill_sweep")
    if cp:
        c, mo = cp["chunked"], cp["monolithic"]
        print(f"chunked prefill {cp['config']['chunks']}: "
              f"p95 {c['latency_p95_s']:.1f} vs {mo['latency_p95_s']:.1f} "
              f"emu-s ({cp['p95_speedup']:.2f}x)  "
              f"p99 {cp['p99_speedup']:.2f}x  "
              f"thpt {cp['throughput_ratio']:.2f}x  "
              f"token_exact={cp['token_exact']:.0f}  "
              f"chunks={c['prefill_chunks']}  "
              f"recompiles={c['recompiles_after_warmup']}")
    pg = res.get("paged_sweep")
    if pg:
        p = pg["paged"]
        print(f"paged cache (page_len={pg['config']['page_len']}): "
              f"token_exact={pg['token_exact']:.0f}  "
              f"prefix_hit_rate={pg['prefix_hit_rate']:.2f} "
              f"({p['prefix_hits']} hits / {p['prefix_hit_tokens']} tok)  "
              f"hbm_ratio={pg['slots_at_fixed_hbm_ratio']:.2f}x "
              f"(peak {p['peak_pages_in_use']} pages)  "
              f"recompiles={p['recompiles_after_warmup']}")
    fs = res.get("frontend_sweep")
    if fs:
        s, r = fs["single"], fs["router"]
        print(f"frontend: router 2x2 goodput={r['goodput_under_slo']:.3f} "
              f"vs single 1x4 {s['goodput_under_slo']:.3f} "
              f"({fs['router_over_single']:.2f}x)  "
              f"deterministic={fs['deterministic']:.0f}  "
              f"repins={r['router']['repins']}  "
              f"affinity_hits={r['router']['affinity_hits']}")
    if res.get("fault_sweep"):
        _print_faults(res["fault_sweep"])
