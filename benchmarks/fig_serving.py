"""Serving benchmark: continuous batching vs run-to-completion batching.

A Poisson arrival trace (exponential interarrivals) is replayed in wall
clock against both servers on the CPU testbed:

  * ``BatchedServer``    — requests wait until a full batch forms, then the
    batch runs to completion (stragglers hold the batch; arrivals during a
    batch wait for the next one).
  * ``ContinuousServer`` — fixed slot pool, one megastep per scheduler
    tick, finished slots refilled mid-flight from the admission queue.

Reported per server: sustained throughput (tok/s over the makespan) and
p50/p95 request latency (arrival -> completion). The continuous row also
reports slot occupancy, AAL and recompiles-after-warmup (must be 0 — the
whole point of the static-shape megastep is surviving slot churn without
recompiling). Results land in benchmarks/results/fig_serving.json.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks import common
from repro.core.buckets import buckets_for_depths
from repro.core.egt import egt_spec
from repro.core.engine import EngineConfig, SpeculativeEngine
from repro.data.pipeline import MarkovSource
from repro.serving.continuous import ContinuousServer
from repro.serving.server import BatchedServer, Request


SPEC, VERIFY_V = egt_spec(4, 2), 6


def make_trace(tb, n: int, rate_hz: float, max_new: int, seed: int = 0):
    """Poisson arrivals: [(arrival_s, Request)] sorted by arrival."""
    rng = np.random.default_rng(seed)
    src = MarkovSource(vocab=tb.spec.vocab,
                       concentration=tb.data_cfg.concentration,
                       seed=tb.data_cfg.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    out = []
    for uid in range(n):
        plen = int(rng.integers(8, 20))
        out.append((float(arrivals[uid]),
                    Request(uid=uid, prompt=src.sample(rng, plen),
                            max_new=max_new)))
    return out


def _engine(tb) -> SpeculativeEngine:
    return SpeculativeEngine(
        tb.drafter, tb.d_params, tb.verifier, tb.v_params,
        buckets=buckets_for_depths((4,), width=2, verify_frac=0.75),
        depth_options=(4,), config=EngineConfig())


def _request_stats(done: Dict[int, Request], t0: float) -> Dict:
    lat = np.asarray([r.t_finish - r.t_submit for r in done.values()])
    toks = int(sum(len(r.result) for r in done.values()))
    makespan = max(r.t_finish for r in done.values()) - t0
    return {"requests": len(done), "tokens": toks,
            "makespan_s": float(makespan),
            "throughput_tok_s": toks / max(makespan, 1e-9),
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p95_s": float(np.percentile(lat, 95)),
            "latency_mean_s": float(lat.mean())}


def drive_continuous(tb, trace, batch: int, prompt_pad: int) -> Dict:
    eng = _engine(tb)
    server = ContinuousServer(eng, batch_size=batch, prompt_pad=prompt_pad,
                              spec=SPEC, verify_v=VERIFY_V)
    server.warmup()
    pending: List = list(trace)
    t0 = time.perf_counter()
    while pending or server.queue or any(s is not None for s in server.slots):
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            arr, req = pending.pop(0)
            req.t_submit = t0 + arr
            server.submit(req)
        if server.queue or any(s is not None for s in server.slots):
            server.step()
        elif pending:
            time.sleep(min(pending[0][0] - now, 0.002))
    m = server.metrics.summary()
    return {**_request_stats(server.done, t0),
            "occupancy": m["occupancy"], "aal": m["aal"],
            "refills": m["refills"],
            "recompiles_after_warmup": m["recompiles_after_warmup"]}


def drive_batched(tb, trace, batch: int, prompt_pad: int) -> Dict:
    eng = _engine(tb)
    server = BatchedServer(eng, batch_size=batch, prompt_pad=prompt_pad)
    # warm the compile caches outside the timed trace, like warmup()
    wreq = Request(uid=-1, prompt=trace[0][1].prompt.copy(),
                   max_new=trace[0][1].max_new)
    server.submit(wreq)
    server.run()
    server.done.clear()
    pending: List = list(trace)
    t0 = time.perf_counter()
    while pending or server.queue:
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            arr, req = pending.pop(0)
            req.t_submit = t0 + arr
            server.submit(req)
        if len(server.queue) >= batch or (server.queue and not pending):
            server.step()
        elif pending:
            time.sleep(min(pending[0][0] - now, 0.002))
    return _request_stats(server.done, t0)


def run(quick: bool = True):
    n = 12 if quick else 48
    max_new = 24 if quick else 64
    batch, prompt_pad = 4, 24
    tb = common.testbed()

    out = {"config": {"n_requests": n, "max_new": max_new, "batch": batch,
                      "spec": {"depth": SPEC.depth, "width": SPEC.width,
                               "verify_v": VERIFY_V}},
           "servers": {}}
    # rate chosen so the pool is load-bearing: a few arrivals per batch-time
    for rate_hz in ((4.0,) if quick else (2.0, 8.0)):
        trace_c = make_trace(tb, n, rate_hz, max_new)
        trace_b = make_trace(tb, n, rate_hz, max_new)
        res = {"continuous": drive_continuous(tb, trace_c, batch, prompt_pad),
               "batched": drive_batched(tb, trace_b, batch, prompt_pad)}
        res["latency_p50_speedup"] = (res["batched"]["latency_p50_s"]
                                      / max(res["continuous"]["latency_p50_s"], 1e-9))
        out["servers"][f"rate_{rate_hz:g}hz"] = res
    common.save("fig_serving", out)
    return out


if __name__ == "__main__":
    res = run()
    for rate, r in res["servers"].items():
        c, b = r["continuous"], r["batched"]
        print(f"{rate}: continuous {c['throughput_tok_s']:.0f} tok/s "
              f"p50={c['latency_p50_s'] * 1e3:.0f}ms p95={c['latency_p95_s'] * 1e3:.0f}ms "
              f"occ={c['occupancy']:.2f} recompiles={c['recompiles_after_warmup']} | "
              f"batched {b['throughput_tok_s']:.0f} tok/s "
              f"p50={b['latency_p50_s'] * 1e3:.0f}ms p95={b['latency_p95_s'] * 1e3:.0f}ms")
