"""Benchmark harness entry point: one benchmark per paper table/figure.

``python -m benchmarks.run [--full] [--only fig10,...]``
prints one CSV block per benchmark and writes JSON to benchmarks/results/.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = ("fig5_latency_curve", "fig4_runtime", "fig11_tree", "fig10_e2e",
           "fig12_breakdown", "fig13_sensitivity", "fig14_objective",
           "fig15_temperature", "fig_serving", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="long mode (more tokens / wider sweeps)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark subset")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)
    failed = []
    for name in names:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        print(f"== {name} ==", flush=True)
        try:
            res = mod.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            failed.append(name)
            continue
        dt = time.perf_counter() - t0
        rows = res.get("rows", [])
        if rows:
            keys = list(rows[0])
            print(",".join(map(str, keys)))
            for r in rows:
                print(",".join(f"{r.get(k):.4g}" if isinstance(r.get(k), float)
                               else str(r.get(k)) for k in keys))
        extras = {k: v for k, v in res.items() if k != "rows"}
        for k, v in extras.items():
            print(f"# {k}: {v}")
        print(f"# {name} done in {dt:.1f}s\n", flush=True)
    if failed:
        print("FAILED:", ",".join(failed))
        sys.exit(1)
    print("all benchmarks complete")


if __name__ == "__main__":
    main()
