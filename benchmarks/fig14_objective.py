"""Fig. 14 analogue: optimizing the latency-aware speedup objective (Eq. 3)
vs optimizing AAL directly, with dynamic bucket selection (paper: +8%)."""
from __future__ import annotations

from benchmarks import common
from repro.core.buckets import buckets_for_depths
from repro.core.engine import EngineConfig, SpeculativeEngine


def run(quick: bool = True):
    max_new = 48 if quick else 128
    buckets = (buckets_for_depths((2, 4, 8), width=2, verify_frac=0.75)
               + buckets_for_depths((4, 8), width=4, verify_frac=0.5))
    rows = []
    for ds, conc in common.DATASETS.items():
        tb = common.testbed(conc)
        prof = common.measure_profile(tb, cache_name=f"profile_{ds}")
        prompt, lengths = common.prompts_for(tb, B=2)
        for objective in ("speedup", "aal"):
            eng = SpeculativeEngine(
                tb.drafter, tb.d_params, tb.verifier, tb.v_params,
                profile=prof, buckets=buckets, depth_options=(2, 4, 8),
                config=EngineConfig(objective=objective))
            s = common.run_generate(eng, prompt, lengths, max_new)
            rows.append({"dataset": ds, "objective": objective,
                         "tpot_ms": s["tpot_ms"], "aal": s["aal"],
                         "buckets_used": list(map(list, set(
                             tuple(b) for b in s.get("buckets", []))))})
    gains = {}
    for ds in common.DATASETS:
        d = {r["objective"]: r["tpot_ms"] for r in rows if r["dataset"] == ds}
        gains[ds] = d["aal"] / d["speedup"]
    out = {"rows": rows, "speedup_objective_gain": gains}
    common.save("fig14_objective", out)
    return out


if __name__ == "__main__":
    res = run()
    print("gain (aal-tpot / speedup-tpot):",
          {k: round(v, 3) for k, v in res["speedup_objective_gain"].items()})
