"""Fig. 12 analogue: cumulative optimization breakdown O1..O5.

  O1  latency-optimal EGT tree, naive staged runtime (host accept + python
      conditional tail draft — the paper's starting point).
  O2  compiled per-stage graphs, acceptance on device (graph compilation).
  O3  + verification-width pruning (Eq. 3-driven subtree extraction).
  O4  + fused megastep (stage-based AoT scheduling: zero host syncs).
  O5  + draft-depth predictor (dynamic bucket selection vs fixed deep tree).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common
from repro.core.buckets import buckets_for_depths
from repro.core.depth_predictor import train_predictor
from repro.core.egt import egt_spec
from repro.core.engine import SpeculativeEngine, EngineConfig


def collect_predictor_data(tb, eng, prompt, lengths, iters=20):
    """Profiling pass: (last-hidden, achieved accept length) pairs."""
    seq, stats = eng.generate(prompt, lengths, iters * 2,
                              spec=egt_spec(8, 2), verify_v=12)
    # use accept lengths as labels against the prefill/step embeddings; for
    # the testbed scale we re-run and capture h_last per iteration
    embs, labels = [], []
    v_logits, vcache, dcache, h_last = eng.prefill(prompt, lengths)
    import jax.numpy as jnp
    root = jnp.argmax(v_logits, -1).astype(jnp.int32)
    step = eng._get_step(egt_spec(8, 2), 12)
    key = jax.random.PRNGKey(0)
    for _ in range(iters):
        key, sk = jax.random.split(key)
        embs.append(np.asarray(h_last))
        dcache, vcache, root, toks, alen, h_last = step(
            eng.d_params, eng.v_params, dcache, vcache, root, sk)
        labels.append(np.asarray(alen))
    return np.concatenate(embs, 0), np.concatenate(labels, 0)


def run(quick: bool = True):
    tb = common.testbed(0.5)   # moderate-acceptance corpus: trees matter here
    prof = common.measure_profile(tb)
    prompt, lengths = common.prompts_for(tb, B=2)
    max_new = 48 if quick else 128
    D, W = 8, 2
    full = egt_spec(D, W)
    rows = []

    def bench(name, plan, spec, v, engine=None, **cfg):
        eng = engine or common.make_engine(tb, profile=prof, plan=plan, **cfg)
        s = common.run_generate(eng, prompt, lengths, max_new, spec=spec,
                                verify_v=v)
        rows.append({"opt": name, "tpot_ms": s["tpot_ms"], "aal": s["aal"]})
        return s

    bench("O1_tree_staged", "staged", full, full.num_nodes)
    bench("O2_compiled", "staged_device", full, full.num_nodes)
    bench("O3_pruning", "staged_device", full, 12)
    bench("O4_fused_sched", "fused", full, 12)

    # O5: depth predictor + dynamic buckets (vs the fixed D=8 tree above)
    eng_prof = common.make_engine(tb, profile=prof, plan="fused")
    embs, alens = collect_predictor_data(tb, eng_prof, prompt, lengths,
                                         iters=12 if quick else 24)
    opts = (2, 4, 8)
    pred, _ = train_predictor(jax.random.PRNGKey(1),
                              jax.numpy.asarray(embs),
                              jax.numpy.asarray(alens), opts,
                              steps=150 if quick else 300)
    eng5 = SpeculativeEngine(
        tb.drafter, tb.d_params, tb.verifier, tb.v_params, profile=prof,
        buckets=buckets_for_depths(opts, width=W, verify_frac=0.75),
        predictor_params=pred, depth_options=opts,
        config=EngineConfig(plan="fused"))
    s = common.run_generate(eng5, prompt, lengths, max_new)
    rows.append({"opt": "O5_depth_predictor", "tpot_ms": s["tpot_ms"],
                 "aal": s["aal"]})

    base = rows[0]["tpot_ms"]
    for r in rows:
        r["cum_speedup_vs_O1"] = base / r["tpot_ms"]
    out = {"rows": rows}
    common.save("fig12_breakdown", out)
    return out


if __name__ == "__main__":
    for r in run()["rows"]:
        print({k: (round(v, 3) if isinstance(v, float) else v)
               for k, v in r.items()})
