"""Quickstart: lossless speculative decoding with Yggdrasil in ~40 lines.

Trains (or restores from cache) a small verifier + an aligned tiny drafter,
then decodes the same prompts autoregressively and speculatively, verifying
the outputs are IDENTICAL and reporting AAL / per-token latency.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.egt import egt_spec
from repro.core.engine import (EngineConfig, SpeculativeEngine,
                               generate_autoregressive)
from repro.data.pipeline import MarkovSource
from repro.serving.testbed import TestbedSpec, build_testbed


def main():
    print("building aligned drafter/verifier pair (cached after first run)…")
    tb = build_testbed(TestbedSpec())

    src = MarkovSource(vocab=tb.spec.vocab,
                       concentration=tb.data_cfg.concentration, seed=0)
    rng = np.random.default_rng(7)
    prompt = jnp.asarray(src.sample_fast(rng, 2, 16))
    lengths = jnp.full((2,), 16, jnp.int32)
    max_new = 48

    print("autoregressive baseline…")
    ar_seq, ar = generate_autoregressive(tb.verifier, tb.v_params, prompt,
                                         lengths, max_new)

    print("speculative decoding (EGT D=4, W=4, V=10, fused megastep)…")
    engine = SpeculativeEngine(tb.drafter, tb.d_params, tb.verifier,
                               tb.v_params, config=EngineConfig(plan="fused"))
    engine.generate(prompt, lengths, 8, spec=egt_spec(4, 4), verify_v=10)
    sp_seq, stats = engine.generate(prompt, lengths, max_new,
                                    spec=egt_spec(4, 4), verify_v=10)

    for b in range(prompt.shape[0]):
        got = sp_seq[b][sp_seq[b] >= 0][:max_new]
        assert (got == ar_seq[b][: len(got)]).all(), "NOT lossless?!"
    s = stats.summary()
    print(f"\nlossless ✓   AAL={s['aal']:.2f} tokens/iteration")
    print(f"AR    TPOT: {ar['tpot_ms']:.1f} ms/token")
    print(f"spec  TPOT: {s['tpot_ms']:.1f} ms/token "
          f"({ar['tpot_ms'] / s['tpot_ms']:.2f}x)")


if __name__ == "__main__":
    main()
