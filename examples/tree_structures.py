"""Compare speculation-tree structures on the same drafter/verifier pair:
sequence chain (Leviathan), k-ary (SpecInfer), dataset-profiled static
(Sequoia-style), and the Equal-Growth Tree — AAL and per-token latency.
Also renders a small EGT as ASCII to show the context-adaptive shape.

  PYTHONPATH=src python examples/tree_structures.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import static_trees
from repro.core.egt import draft_tree, egt_spec, template_spec
from repro.core.engine import EngineConfig, SpeculativeEngine
from repro.data.pipeline import MarkovSource
from repro.serving.testbed import TestbedSpec, build_testbed


def render_tree(parents, tokens, depths):
    """ASCII render of one batch element's draft tree."""
    n = len(parents)
    kids = {i: [] for i in range(-1, n)}
    for i in range(n):
        kids[int(parents[i])].append(i)

    lines = []

    def walk(i, indent):
        lines.append("  " * indent + f"[{i}] tok={int(tokens[i])} "
                                     f"d={int(depths[i])}")
        for c in kids.get(i, []):
            walk(c, indent + 1)

    walk(0, 0)
    return "\n".join(lines)


def main():
    tb = build_testbed(TestbedSpec())
    src = MarkovSource(vocab=tb.spec.vocab,
                       concentration=tb.data_cfg.concentration, seed=0)
    rng = np.random.default_rng(3)
    prompt = jnp.asarray(src.sample_fast(rng, 2, 16))
    lengths = jnp.full((2,), 16, jnp.int32)

    # ---- show one EGT ------------------------------------------------------
    eng = SpeculativeEngine(tb.drafter, tb.d_params, tb.verifier, tb.v_params,
                            config=EngineConfig())
    _, vcache, dcache, _ = eng.prefill(prompt, lengths)
    spec = egt_spec(3, 3)
    res = draft_tree(tb.drafter, tb.d_params, dcache,
                     jnp.zeros((2,), jnp.int32), spec)
    print("one Equal-Growth Tree (D=3, W=3 — note leaves attach anywhere):")
    print(render_tree(np.asarray(res.tree.parents)[0],
                      np.asarray(res.tree.tokens)[0],
                      np.asarray(res.tree.depths)[0]))

    # ---- compare structures ------------------------------------------------
    ra = static_trees.measure_rank_accept(
        tb.drafter, tb.d_params, tb.verifier, tb.v_params, prompt, lengths,
        k=4, iters=16)
    print(f"\nprofiled rank-acceptance: {np.round(ra, 3)}")

    budget = 10
    cases = {}
    p, r = static_trees.chain(6)
    cases["chain(6)"] = (template_spec(p, r), 7)
    p, r = static_trees.kary(2, 3)
    cases["2-ary(d3)"] = (template_spec(p, r), budget)
    p, r = static_trees.sequoia(ra, budget, max_depth=8)
    cases["sequoia(10)"] = (template_spec(p, r), budget)
    cases["EGT(4x4)"] = (egt_spec(4, 4), budget)

    print(f"\n{'structure':<14} {'AAL':>6} {'TPOT ms':>9}")
    for name, (sp, v) in cases.items():
        e = SpeculativeEngine(tb.drafter, tb.d_params, tb.verifier,
                              tb.v_params, config=EngineConfig(plan="fused"))
        e.generate(prompt, lengths, 6, spec=sp, verify_v=v)       # warm
        _, stats = e.generate(prompt, lengths, 40, spec=sp, verify_v=v)
        s = stats.summary()
        print(f"{name:<14} {s['aal']:>6.2f} {s['tpot_ms']:>9.1f}")


if __name__ == "__main__":
    main()
