"""End-to-end driver: TRAIN a verifier and drafter from scratch for a few
hundred steps, then SERVE batched requests through the full Yggdrasil
runtime (depth predictor + latency objective + fused scheduling).

This is the complete lifecycle the paper's system implies: calibrate →
profile → compile buckets → serve.

  PYTHONPATH=src python examples/train_then_serve.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buckets import buckets_for_depths
from repro.core.depth_predictor import train_predictor
from repro.core.egt import egt_spec
from repro.core.engine import EngineConfig, SpeculativeEngine
from repro.data.pipeline import MarkovSource
from repro.serving.server import BatchedServer, Request
from repro.serving.testbed import TestbedSpec, build_testbed


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()

    # ---- 1. train both models on the same corpus --------------------------
    spec = TestbedSpec(train_steps=args.steps)
    t0 = time.perf_counter()
    tb = build_testbed(spec, force=False)
    print(f"verifier+drafter ready in {time.perf_counter() - t0:.1f}s "
          f"(losses: {tb.losses})")

    # ---- 2. profiling pass: collect (embedding, accept-len) pairs ---------
    src = MarkovSource(vocab=spec.vocab, concentration=spec.concentration,
                       seed=0)
    rng = np.random.default_rng(1)
    prompt = jnp.asarray(src.sample_fast(rng, 4, 16))
    lengths = jnp.full((4,), 16, jnp.int32)
    eng = SpeculativeEngine(tb.drafter, tb.d_params, tb.verifier, tb.v_params,
                            config=EngineConfig())
    embs, alens = [], []
    v_logits, vcache, dcache, h_last = eng.prefill(prompt, lengths)
    root = jnp.argmax(v_logits, -1).astype(jnp.int32)
    step = eng._get_step(egt_spec(8, 2), 12)
    key = jax.random.PRNGKey(0)
    for _ in range(15):
        key, sk = jax.random.split(key)
        embs.append(np.asarray(h_last))
        dcache, vcache, root, _, alen, h_last = step(
            eng.d_params, eng.v_params, dcache, vcache, root, sk)
        alens.append(np.asarray(alen))
    print("training depth predictor on profiling data…")
    opts = (2, 4, 8)
    pred, _ = train_predictor(jax.random.PRNGKey(2),
                              jnp.asarray(np.concatenate(embs)),
                              jnp.asarray(np.concatenate(alens)), opts,
                              steps=150)

    # ---- 3. serve ----------------------------------------------------------
    engine = SpeculativeEngine(
        tb.drafter, tb.d_params, tb.verifier, tb.v_params,
        buckets=buckets_for_depths(opts, width=2, verify_frac=0.75),
        predictor_params=pred, depth_options=opts,
        config=EngineConfig(plan="fused"))
    server = BatchedServer(engine, batch_size=4, prompt_pad=24)
    for uid in range(args.requests):
        plen = int(rng.integers(8, 20))
        server.submit(Request(uid=uid, prompt=src.sample(rng, plen),
                              max_new=40))
    done = server.run()
    for uid, req in sorted(done.items()):
        print(f"req {uid}: {len(req.result)} tok  aal={req.stats['aal']:.2f} "
              f"tpot={req.stats['tpot_ms']:.1f}ms  "
              f"buckets={sorted(set(map(tuple, [])))or''}")
    agg = sum(r.stats["tokens"] for r in done.values())
    print(f"\nserved {len(done)} requests, {agg} tokens total — done.")


if __name__ == "__main__":
    main()
