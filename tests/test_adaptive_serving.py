"""Adaptive bucket scheduling on the continuous server: the controller must
react to occupancy swings (≥ 2 bucket switches on a phased trace) while the
zero-recompile contract holds — every decode step replays an executable
compiled at warmup, and on an emulated clock the adaptive schedule beats
pinning either ladder bucket."""
import numpy as np
import pytest

from repro.core.buckets import Bucket
from repro.core.egt import egt_spec
from repro.core.engine import EngineConfig, SpeculativeEngine
from repro.core.objective import LatencyProfile
from repro.serving.continuous import ContinuousServer
from repro.serving.controller import BucketController
from repro.serving.emulation import charged_step
from repro.serving.server import Request
from repro.serving.testbed import Testbed, TestbedSpec, build_testbed

LADDER = (Bucket(2, 2, 4), Bucket(4, 2, 7))
BATCH, PAD = 4, 12
# pronounced saturation knee: shallow bucket wins at full pool, deep wins
# while the pool drains (see objective.step_latency's batch term)
PROFILE = LatencyProfile.synthetic(base_verify=1.0, slope=1.0,
                                   draft_frac=0.1, saturate_at=16,
                                   overhead=0.2)


@pytest.fixture(scope="module")
def tb() -> Testbed:
    return build_testbed(TestbedSpec(train_steps=160))


@pytest.fixture(scope="module")
def engine(tb) -> SpeculativeEngine:
    # shared across tests/servers: the megastep executables compile once per
    # bucket and every later warmup just replays them
    return SpeculativeEngine(tb.drafter, tb.d_params, tb.verifier,
                             tb.v_params, profile=PROFILE,
                             config=EngineConfig())


def _requests(tb, n, max_new, seed=0, uid0=0):
    rng = np.random.default_rng(seed)
    out = []
    for uid in range(uid0, uid0 + n):
        plen = int(rng.integers(6, 12))
        prompt = rng.integers(1, tb.spec.vocab, size=plen).astype(np.int32)
        out.append(Request(uid=uid, prompt=prompt, max_new=max_new))
    return out


def _adaptive_server(engine) -> ContinuousServer:
    return ContinuousServer(
        engine, batch_size=BATCH, prompt_pad=PAD, buckets=LADDER,
        controller=BucketController(LADDER, profile=PROFILE,
                                    min_dwell=0, hysteresis=0.05))


def _drive_phased(tb, server) -> float:
    """One long request (pool nearly empty), then a burst of shorts (pool
    full), then the drain tail — the occupancy swing that forces bucket
    switches. Returns emulated busy time (profile-charged via the same
    serving.emulation helper the benchmark sweep uses)."""
    server.warmup()
    busy = 0.0
    server.submit(_requests(tb, 1, max_new=40, seed=1)[0])
    for _ in range(4):                       # phase A: occupancy 1
        busy += charged_step(server, PROFILE)[0]
    for r in _requests(tb, 6, max_new=6, seed=2, uid0=1):
        server.submit(r)                     # phase B: pool fills
    while server.queue or any(s is not None for s in server.slots):
        busy += charged_step(server, PROFILE)[0]   # phase C: drain tail
    return busy


def test_adaptive_switches_without_recompiles(tb, engine):
    """The acceptance contract: ≥ 2 bucket switches on the phased trace,
    zero recompiles after warmup, and every step replayed a bucket whose
    executable warmup compiled."""
    server = _adaptive_server(engine)
    _drive_phased(tb, server)
    m = server.metrics.summary()
    assert m["completed"] == 7
    assert m["bucket_switches"] >= 2, m["buckets"]
    assert m["recompiles_after_warmup"] == 0, m
    # both ladder buckets actually ran, and nothing outside the ladder did
    used = set(server.metrics.bucket_history)
    assert used == {b.key() for b in LADDER}
    assert used <= server.warmed_buckets
    # warmup compiled the whole ladder
    assert server.warmed_buckets == {b.key() for b in LADDER}
    # per-bucket rollups cover every step
    assert sum(m["buckets"][k]["steps"] for k in m["buckets"]) == m["steps"]


def test_adaptive_beats_pinned_on_emulated_clock(tb, engine):
    """On the same phased trace, the adaptive schedule's emulated busy time
    beats pinning either ladder bucket (it runs shallow at full pool and
    deep on the tail). Throughput = tokens/busy; token totals are equal by
    construction (same requests, same budgets)."""
    adaptive = _adaptive_server(engine)
    busy_adaptive = _drive_phased(tb, adaptive)
    busy_pinned = {}
    for b in LADDER:
        server = ContinuousServer(engine, batch_size=BATCH, prompt_pad=PAD,
                                  spec=egt_spec(b.depth, b.width),
                                  verify_v=b.verify)
        busy_pinned[b.key()] = _drive_phased(tb, server)
        assert server.metrics.tokens_out == adaptive.metrics.tokens_out
        assert server.metrics.summary()["recompiles_after_warmup"] == 0
    assert busy_adaptive < min(busy_pinned.values()), (
        busy_adaptive, busy_pinned)


def test_adaptive_rejects_bad_config(tb, engine):
    with pytest.raises(ValueError):
        ContinuousServer(engine, batch_size=2, prompt_pad=8,
                         buckets=LADDER, spec=egt_spec(2, 2))
    with pytest.raises(ValueError):     # controller without a ladder
        ContinuousServer(engine, batch_size=2, prompt_pad=8,
                         controller=BucketController(LADDER,
                                                     profile=PROFILE))
    with pytest.raises(ValueError):     # controller over DIFFERENT buckets
        ContinuousServer(engine, batch_size=2, prompt_pad=8, buckets=LADDER,
                         controller=BucketController((LADDER[0],
                                                      Bucket(6, 2, 10)),
                                                     profile=PROFILE))
