"""End-to-end system behaviour: the speculative engine must be LOSSLESS —
greedy speculative output ≡ greedy autoregressive output of the verifier —
across execution plans, tree specs and baselines. This is the paper's
correctness contract (speculative decoding is an exact accelerator)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.egt import egt_spec, template_spec
from repro.core.engine import (EngineConfig, SpeculativeEngine,
                               generate_autoregressive)
from repro.core.tree import chain_template, kary_template
from repro.data.pipeline import MarkovSource
from repro.serving.testbed import Testbed, TestbedSpec, build_testbed


@pytest.fixture(scope="module")
def tb() -> Testbed:
    return build_testbed(TestbedSpec(train_steps=160))


def _prompts(tb, B=2, S=12, seed=3):
    rng = np.random.default_rng(seed)
    m = MarkovSource(vocab=tb.spec.vocab,
                     concentration=tb.data_cfg.concentration,
                     seed=tb.data_cfg.seed)
    toks = m.sample_fast(rng, B, S)
    return jnp.asarray(toks), jnp.full((B,), S, jnp.int32)


def _engine(tb, **cfg_kw):
    return SpeculativeEngine(tb.drafter, tb.d_params, tb.verifier,
                             tb.v_params, config=EngineConfig(**cfg_kw))


MAX_NEW = 24


@pytest.mark.parametrize("spec_kind", ["egt", "chain", "kary"])
def test_greedy_lossless(tb, spec_kind):
    prompt, lengths = _prompts(tb)
    ar_seq, _ = generate_autoregressive(tb.verifier, tb.v_params, prompt,
                                        lengths, MAX_NEW)
    if spec_kind == "egt":
        spec, v = egt_spec(4, 3), 8
    elif spec_kind == "chain":
        t = chain_template(4)
        spec, v = template_spec(t["parents"], t["expand_rank"]), 5
    else:
        t = kary_template(2, 3)
        spec, v = template_spec(t["parents"], t["expand_rank"]), 10
    eng = _engine(tb)
    sp_seq, stats = eng.generate(prompt, lengths, MAX_NEW, spec=spec,
                                 verify_v=v)
    for b in range(prompt.shape[0]):
        got = sp_seq[b][sp_seq[b] >= 0][:MAX_NEW]
        want = ar_seq[b][:len(got)]
        np.testing.assert_array_equal(got, want)
    assert stats.aal >= 1.0


@pytest.mark.parametrize("plan", ["fused", "staged", "staged_device"])
def test_plans_agree(tb, plan):
    """All execution plans produce identical greedy output (the scheduling
    runtime only moves WHERE stages run, never WHAT they compute)."""
    prompt, lengths = _prompts(tb, seed=11)
    ar_seq, _ = generate_autoregressive(tb.verifier, tb.v_params, prompt,
                                        lengths, MAX_NEW)
    eng = _engine(tb, plan=plan)
    sp_seq, _ = eng.generate(prompt, lengths, MAX_NEW, spec=egt_spec(3, 2),
                             verify_v=5)
    for b in range(prompt.shape[0]):
        got = sp_seq[b][sp_seq[b] >= 0][:MAX_NEW]
        np.testing.assert_array_equal(got, ar_seq[b][:len(got)])


def test_no_prune_lossless(tb):
    prompt, lengths = _prompts(tb, seed=17)
    ar_seq, _ = generate_autoregressive(tb.verifier, tb.v_params, prompt,
                                        lengths, MAX_NEW)
    eng = _engine(tb, prune=False)
    sp_seq, _ = eng.generate(prompt, lengths, MAX_NEW, spec=egt_spec(3, 3))
    for b in range(prompt.shape[0]):
        got = sp_seq[b][sp_seq[b] >= 0][:MAX_NEW]
        np.testing.assert_array_equal(got, ar_seq[b][:len(got)])


def test_bucket_reuse_no_recompile(tb):
    """EGT's static-shape property: iterating inside one bucket compiles
    exactly once; only a bucket switch compiles a new executable."""
    prompt, lengths = _prompts(tb, seed=23)
    eng = _engine(tb)
    _, st1 = eng.generate(prompt, lengths, 20, spec=egt_spec(3, 2), verify_v=5)
    assert st1.compiles == 1
    _, st2 = eng.generate(prompt, lengths, 20, spec=egt_spec(3, 2), verify_v=5)
    assert st2.compiles == 0                      # replayed executable
    _, st3 = eng.generate(prompt, lengths, 10, spec=egt_spec(4, 2), verify_v=5)
    assert st3.compiles == 1                      # new bucket


def test_dynamic_bucket_selection(tb):
    """Engine picks buckets from predictor+objective when no spec is pinned."""
    from repro.core.buckets import buckets_for_depths
    prompt, lengths = _prompts(tb, seed=29)
    eng = SpeculativeEngine(
        tb.drafter, tb.d_params, tb.verifier, tb.v_params,
        buckets=buckets_for_depths((2, 4), width=2),
        depth_options=(2, 4), config=EngineConfig())
    ar_seq, _ = generate_autoregressive(tb.verifier, tb.v_params, prompt,
                                        lengths, MAX_NEW)
    sp_seq, stats = eng.generate(prompt, lengths, MAX_NEW)
    assert len(stats.buckets) >= 1
    for b in range(prompt.shape[0]):
        got = sp_seq[b][sp_seq[b] >= 0][:MAX_NEW]
        np.testing.assert_array_equal(got, ar_seq[b][:len(got)])


def test_stochastic_mode_runs_and_terminates(tb):
    prompt, lengths = _prompts(tb, seed=31)
    eng = _engine(tb, temperature=0.8)
    seq, stats = eng.generate(prompt, lengths, 16, spec=egt_spec(3, 2),
                              verify_v=5, key=jax.random.PRNGKey(5))
    assert stats.tokens_generated >= 16
    flat = seq[seq >= 0]
    assert ((flat >= 0) & (flat < tb.spec.vocab)).all()


def test_speculation_beats_ar_in_steps(tb):
    """On the aligned testbed the engine must verify >1 token/iteration on
    average — the core premise of speculative decoding."""
    prompt, lengths = _prompts(tb, B=4, seed=37)
    eng = _engine(tb)
    _, stats = eng.generate(prompt, lengths, 32, spec=egt_spec(4, 4),
                            verify_v=12)
    assert stats.aal > 1.3, stats.summary()
