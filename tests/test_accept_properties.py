"""Properties of the host/device accept boundary.

1. Differential: `scheduler.greedy_accept_host` (the staged plan's CPU
   accept stage) must agree with `verify.greedy_accept` (the fused plan's
   in-graph accept) on chain, accept_len, bonus and last node, over
   randomized trees with dead nodes and pruned subtrees. Siblings carry
   DISTINCT tokens — the real drafting invariant (top-k candidates of one
   parent never repeat), and what makes the greedy chain unique so the two
   implementations are comparable.
2. Statistical losslessness: `verify.stochastic_accept` commits tokens
   distributed exactly like the target model on multi-child trees where
   the rejection/residual paths genuinely trigger (chi-square test).

The hypothesis versions explore the input space; the seeded versions run
the same checker everywhere (hypothesis is an optional dev dependency).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pruning, verify
from repro.core.scheduler import greedy_accept_host
from repro.core.tree import TreeArrays

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # optional dev dependency
    HAVE_HYPOTHESIS = False

VOCAB = 6                                 # small => target collisions likely


# ----------------------------------------------------------- generators ----
def _random_tree(rng, max_n=12, kill_frac=0.0) -> TreeArrays:
    """Random topologically-ordered tree with distinct sibling tokens and
    (optionally) dead nodes. Root is always live. Fan-out is capped at
    VOCAB so siblings can actually be distinct — with duplicate sibling
    tokens the host (first-match walk) and device (deepest-accepted) chains
    legitimately diverge, and real drafting never produces duplicates."""
    n = int(rng.integers(2, max_n + 1))
    parents = [-1]
    fanout = [0]
    for i in range(1, n):
        allowed = [p for p in range(i) if fanout[p] < VOCAB]
        p = int(rng.choice(allowed))
        parents.append(p)
        fanout[p] += 1
        fanout.append(0)
    parents = np.asarray(parents, np.int32)
    depths = np.zeros(n, np.int32)
    tokens = np.zeros(n, np.int32)
    tokens[0] = int(rng.integers(0, VOCAB))
    for p in range(n):
        kids = np.nonzero(parents == p)[0]
        if len(kids):
            toks = rng.choice(VOCAB, size=len(kids), replace=False)
            for j, k in enumerate(kids):
                tokens[k] = toks[j]
                depths[k] = depths[p] + 1
    live = rng.random(n) >= kill_frac
    live[0] = True
    path_lp = np.zeros(n, np.float32)
    for i in range(1, n):
        path_lp[i] = path_lp[parents[i]] - float(rng.exponential(1.0))
    return TreeArrays(tokens=jnp.asarray(tokens)[None],
                      parents=jnp.asarray(parents)[None],
                      depths=jnp.asarray(depths)[None],
                      path_lp=jnp.asarray(path_lp)[None],
                      live=jnp.asarray(live)[None])


def _check_host_matches_device(tree: TreeArrays, rng):
    n = int(tree.tokens.shape[1])
    logits = jnp.asarray(rng.normal(size=(1, n, VOCAB)), jnp.float32)
    acc = verify.greedy_accept(tree, logits, n)
    node_idx, alen, bonus, last = greedy_accept_host(
        np.asarray(tree.tokens), np.asarray(tree.parents),
        np.asarray(tree.depths), np.asarray(tree.live),
        np.asarray(jnp.argmax(logits, -1)), n)
    assert int(acc.accept_len[0]) == int(alen[0])
    assert int(acc.bonus[0]) == int(bonus[0])
    assert int(acc.last_node[0]) == int(last[0])
    k = int(alen[0])
    np.testing.assert_array_equal(np.asarray(acc.node_idx)[0, :k],
                                  node_idx[0, :k])


# ------------------------------------------------- differential: seeded ----
@pytest.mark.parametrize("seed", range(40))
def test_greedy_accept_host_device_agree_with_dead_nodes(seed):
    rng = np.random.default_rng(seed)
    tree = _random_tree(rng, kill_frac=0.35)
    _check_host_matches_device(tree, rng)


@pytest.mark.parametrize("seed", range(40))
def test_greedy_accept_host_device_agree_on_pruned_trees(seed):
    """Prune a live tree to a top-k subtree first: the boundary must agree
    on exactly the inputs the staged plan feeds it after O3 pruning."""
    rng = np.random.default_rng(1000 + seed)
    tree = _random_tree(rng, kill_frac=0.0)
    n = int(tree.tokens.shape[1])
    v = int(rng.integers(1, n + 1))
    sub, _ = pruning.topk_prune(tree, v, n)
    _check_host_matches_device(sub, rng)


# --------------------------------------------- differential: hypothesis ----
if HAVE_HYPOTHESIS:
    settings.register_profile("ci", max_examples=25, deadline=None,
                              print_blob=True)
    settings.load_profile("ci")

    @given(st.integers(0, 10 ** 6), st.floats(0.0, 0.6),
           st.integers(2, 14))
    def test_greedy_accept_differential_hypothesis(seed, kill_frac, max_n):
        rng = np.random.default_rng(seed)
        tree = _random_tree(rng, max_n=max_n, kill_frac=kill_frac)
        _check_host_matches_device(tree, rng)

    @given(st.integers(0, 10 ** 6), st.integers(2, 14))
    def test_greedy_accept_pruned_hypothesis(seed, max_n):
        rng = np.random.default_rng(seed)
        tree = _random_tree(rng, max_n=max_n, kill_frac=0.0)
        n = int(tree.tokens.shape[1])
        sub, _ = pruning.topk_prune(tree, int(rng.integers(1, n + 1)), n)
        _check_host_matches_device(sub, rng)


# --------------------------------- stochastic acceptance losslessness ----
def test_stochastic_accept_is_lossless_on_multichild_trees():
    """SpecInfer-style multi-branch rejection sampling: with two children
    drawn i.i.d. from the drafter distribution q, the committed depth-1
    token (accepted child, or the bonus sampled from the twice-updated
    residual when both reject) must be distributed EXACTLY like the target
    p. Chi-square over pooled draws from several seeds; fixed seeds keep
    the test deterministic."""
    vocab, n, draws = 4, 3, 6000
    q = np.array([0.5, 0.3, 0.15, 0.05])     # drafter: confidently wrong
    p = np.array([0.25, 0.25, 0.3, 0.2])     # target
    counts = np.zeros(vocab)
    n_reject_all = n_second_child = 0
    for seed in (0, 1, 2):
        rng = np.random.default_rng(seed)
        kids = rng.choice(vocab, size=(draws, 2), p=q)   # i.i.d. from q
        tree = TreeArrays(
            tokens=jnp.concatenate([jnp.zeros((draws, 1), jnp.int32),
                                    jnp.asarray(kids, jnp.int32)], axis=1),
            parents=jnp.broadcast_to(jnp.array([-1, 0, 0], jnp.int32),
                                     (draws, n)),
            depths=jnp.broadcast_to(jnp.array([0, 1, 1], jnp.int32),
                                    (draws, n)),
            path_lp=jnp.zeros((draws, n), jnp.float32),
            live=jnp.ones((draws, n), bool),
        )
        dp = jnp.broadcast_to(jnp.asarray(q, jnp.float32), (draws, n, vocab))
        tp = jnp.broadcast_to(jnp.asarray(p, jnp.float32), (draws, n, vocab))
        acc = verify.stochastic_accept(tree, dp, tp,
                                       jax.random.PRNGKey(100 + seed),
                                       a_max=2, max_children=2)
        alen = np.asarray(acc.accept_len)
        last = np.asarray(acc.last_node)
        toks = np.asarray(tree.tokens)
        bonus = np.asarray(acc.bonus)
        emitted = np.where(alen >= 2, toks[np.arange(draws), last], bonus)
        np.add.at(counts, emitted, 1)
        n_reject_all += int((alen == 1).sum())
        n_second_child += int((last == 2).sum())

    # the interesting paths genuinely ran: residual updates (both children
    # rejected -> bonus from the twice-subtracted residual) and the
    # second-branch retry
    assert n_reject_all > 100
    assert n_second_child > 100

    total = counts.sum()
    expected = p * total
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    # chi-square critical value, df=3, alpha=0.001
    assert chi2 < 16.27, (chi2, counts / total, p)


def test_stochastic_accept_biased_without_residual_update():
    """Control for the test above: scoring the same draws against the
    DRAFTER distribution (as if acceptance were unconditional) is visibly
    not target-distributed — the chi-square above has teeth."""
    vocab, draws = 4, 18000
    q = np.array([0.5, 0.3, 0.15, 0.05])
    p = np.array([0.25, 0.25, 0.3, 0.2])
    rng = np.random.default_rng(0)
    naive = rng.choice(vocab, size=draws, p=q)   # drafter output, no accept
    counts = np.bincount(naive, minlength=vocab).astype(float)
    expected = p * draws
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    assert chi2 > 16.27
