"""Async serving front-end: RequestHandle lifecycle, SLO-aware routing,
admission control, drain/scale events and the byte-determinism contract
with the event loop in the path.

Host-side scheduling logic (routing, admission, handles, asyncio plumbing)
runs on a deterministic fake engine — no jit, no testbed. The acceptance
criteria (goodput-under-SLO win of scale-out over scale-up, zero recompiles
across drain/scale, byte-identical emulated drives) run on the real
testbed engine at the bottom of the file.
"""
import asyncio
import json

import numpy as np
import pytest

from repro.core.buckets import buckets_for_depths
from repro.core.egt import egt_spec
from repro.core.engine import EngineConfig, SpeculativeEngine
from repro.core.objective import LatencyProfile
from repro.serving import (AdmissionConfig, ContinuousServer, Request,
                           RequestHandle, Router, ServingFrontend,
                           drive_frontend_trace)
from repro.serving.router import ACTIVE, DRAINING, RETIRED
from repro.serving.testbed import Testbed, TestbedSpec, build_testbed


# --------------------------------------------------- deterministic fake ----
class _FakeState:
    def __init__(self, batch_size):
        self.root = np.zeros(batch_size, np.int64)


class _FakeResult:
    def __init__(self, tokens, accept_len, bucket):
        self.tokens = tokens
        self.accept_len = accept_len
        self.bucket = bucket
        self.iter_time = 1e-5

    def mean_accept(self, slots=None):
        a = self.accept_len if slots is None else self.accept_len[slots]
        return float(np.mean(a)) if np.size(a) else 0.0


class _FakeEngine:
    """Enough engine for the full ContinuousServer step loop, host-only:
    every slot emits one deterministic token per step (1000 + step#)."""

    class cfg:
        max_target_len = 4096

    _compile_count = 0
    profile = None

    def __init__(self):
        self._steps = 0

    def init_decode_state(self, batch_size):
        return _FakeState(batch_size)

    def prefill_into_slot(self, state, slot, tokens, length):
        return state

    def reset_state_slot(self, state, slot):
        return state

    def decode_step(self, state, spec=None, verify_v=None):
        self._steps += 1
        B = len(state.root)
        toks = np.full((B, 2), -1, np.int64)
        toks[:, 0] = 1000 + self._steps
        return state, _FakeResult(toks, np.ones(B, np.int64),
                                  (spec.depth, spec.width, verify_v))

    def executable_count(self):
        return 0

    def mesh_info(self):
        return {"devices": 1, "shape": None}


def _fake_server(batch=2):
    return ContinuousServer(_FakeEngine(), batch_size=batch, prompt_pad=4,
                            spec=egt_spec(2, 2))


def _req(uid, max_new=4):
    return Request(uid=uid, prompt=np.array([1, 2, 3]), max_new=max_new)


# ------------------------------------------------------ RequestHandle ------
def test_submit_returns_handle_result_pumps_server():
    srv = _fake_server()
    handles = [srv.submit(_req(u)) for u in range(3)]
    assert all(isinstance(h, RequestHandle) for h in handles)
    assert not handles[0].done()
    out = handles[0].result()          # pumps warmup + steps on demand
    assert handles[0].done()
    np.testing.assert_array_equal(out, handles[0].request.result)
    assert len(out) == 4               # root + 3 steps = max_new
    assert handles[0].tokens == [int(t) for t in out]


def test_handle_sync_streaming_yields_committed_tokens_in_order():
    srv = _fake_server()
    h = srv.submit(_req(0, max_new=5))
    srv.submit(_req(1, max_new=5))
    streamed = list(h)                 # pumps between chunks when dry
    assert h.done()
    assert streamed == [int(t) for t in h.request.result]


def test_serve_returns_done_handles_and_raw_requests_stay_reachable():
    srv = _fake_server()
    hs = {u: srv.submit(_req(u)) for u in range(3)}
    done = srv.serve()
    assert sorted(done) == [0, 1, 2]
    assert all(done[u] is hs[u] and hs[u].done() for u in hs)
    # the run() compatibility shim is gone; raw Requests live on srv.done
    assert not hasattr(srv, "run")
    assert sorted(srv.done) == [0, 1, 2]
    assert all(srv.done[u].result is not None for u in srv.done)


# ------------------------------------------------------------- Router ------
def test_router_spreads_load_and_honours_affinity():
    router = Router([_fake_server(), _fake_server()])
    rep, _ = router.submit(_req(0), session="a")
    assert rep.idx == 0                # empty tie breaks to the lowest idx
    rep, _ = router.submit(_req(1))
    assert rep.idx == 1                # least-loaded beats idx
    rep, _ = router.submit(_req(2), session="a")
    assert rep.idx == 0                # affinity pin beats load
    assert router.metrics.affinity_hits == 1
    assert router.metrics.routed == {0: 2, 1: 1}


def test_router_repins_sessions_off_a_draining_replica():
    router = Router([_fake_server(), _fake_server()])
    rep, _ = router.submit(_req(0), session="a")
    router.submit(_req(1), session="b")
    assert router._pins == {"a": 0, "b": 1}
    router.drain(1)
    rep, _ = router.submit(_req(2), session="b")  # pinned replica going away
    assert rep.idx == 0
    assert router._pins["b"] == 0
    assert router.metrics.repins == 1
    assert router.metrics.drains == 1


def test_drain_retires_in_flight_then_reap_then_scale_up():
    router = Router([_fake_server(), _fake_server()])
    _, h = router.submit(_req(0))
    rep = router.replicas[0]
    router.drain(0)
    assert rep.state == DRAINING
    assert router.reap() == []         # still has work: must keep stepping
    rep.server.serve()                 # in-flight retires on warm executables
    assert h.done() and len(h.tokens) == 4
    assert router.reap() == [0]
    assert rep.state == RETIRED
    router.scale_up(0)
    assert rep.state == ACTIVE
    assert router.metrics.scale_ups == 1
    assert rep.server.metrics.summary()["recompiles_after_warmup"] == 0


def test_est_wait_prices_saturation_knee():
    """With a profile, a replica pushed past the knee must look more
    expensive than an idle one even before queue waves kick in."""
    prof = LatencyProfile.synthetic(base_verify=1.0, slope=1.0,
                                    draft_frac=0.1, saturate_at=16,
                                    overhead=0.2)
    busy, idle = _fake_server(batch=4), _fake_server(batch=4)
    router = Router([busy, idle], profile=prof)
    for u in range(4):
        router.replicas[0].server.submit(_req(u))
    # verify_v = egt_spec(2,2).num_nodes -> 4+ tokens/slot; 4 slots on the
    # busy replica projects past saturate_at=16 while idle stays at batch 1
    assert (router.est_wait(router.replicas[0])
            > router.est_wait(router.replicas[1]))
    rep = router.route()
    assert rep.idx == 1


# ----------------------------------------------------- admission control ---
def test_admission_sheds_past_the_bound():
    fe = ServingFrontend([_fake_server(batch=1)],
                         admission=AdmissionConfig(max_pending=1,
                                                   on_overload="shed"))
    h0 = fe.submit(_req(0))            # dispatched straight into the replica
    h1 = fe.submit(_req(1))            # parked in the front queue
    h2 = fe.submit(_req(2))            # queue full -> shed, terminal handle
    assert not h0.shed and not h1.shed
    assert h2.shed and h2.done() and h2.shed_reason == "overload"
    assert len(h2.result()) == 0       # terminal: empty, never raises
    m = fe.metrics
    assert m.sheds == 1 and m.shed_overload == 1
    assert m.tokens_lost == 4          # the shed request's whole budget
    assert fe.summary()["goodput_under_slo"] < 1.0


def test_admission_parks_under_backpressure_by_default():
    fe = ServingFrontend([_fake_server(batch=1)],
                         admission=AdmissionConfig(max_pending=1))
    for u in range(4):
        fe.submit(_req(u))
    assert fe.metrics.sheds == 0
    assert fe.metrics.parks >= 2       # held, not rejected


def test_priority_dispatch_order():
    fe = ServingFrontend([_fake_server(batch=1)])
    h0 = fe.submit(_req(0))            # occupies the only capacity
    hlow = fe.submit(_req(1), priority=0)
    hhigh = fe.submit(_req(2), priority=5)
    rep = fe.router.replicas[0]
    while not h0.done():
        rep.server.step()
    fe._dispatch()
    assert hhigh.replica == 0          # higher priority released first
    assert hlow.replica is None        # still parked: capacity is one deep


# ------------------------------------------- deadlines under re-routing ----
def test_rerouted_request_keeps_original_deadline_emulated():
    """A request evacuated off a crashed replica and replayed elsewhere is
    the SAME request: t_submit and the deadline stay pinned to the original
    admission, latency is measured from the original submit, and its tokens
    are delivered exactly once."""
    from repro.core.objective import LatencyProfile
    from repro.serving import FaultEvent, FaultPlan, RecoveryConfig
    prof = LatencyProfile.synthetic(base_verify=1.0, slope=1.0,
                                    draft_frac=0.1, saturate_at=16,
                                    overhead=0.2)
    plan = FaultPlan([FaultEvent(2.0, "crash", 0)])
    fe = ServingFrontend([_fake_server(), _fake_server()], profile=prof,
                         recovery=RecoveryConfig(backoff_s=2.0))

    def row(u):
        r = _req(u, max_new=6)
        r.t_submit = float(u)          # pre-stamped arrival time
        return (float(u), r, {"deadline_s": 50.0})

    out = drive_frontend_trace(fe, [row(u) for u in range(6)], prof,
                               faults=plan)
    assert out["replica_failures"] == 1 and out["replays"] >= 1
    assert out["completed"] == 6 and out["sheds"] == 0
    handles = fe.handles()
    replayed = [h for h in handles.values() if h.retries > 0]
    assert replayed
    for u, h in handles.items():
        assert h.request.t_submit == float(u)      # replay never re-stamps
        assert h.deadline is not None
        assert len(h.tokens) == 6                  # full budget, no dupes
    # a replayed request completes ONCE, with latency from the original
    # submit — so it spans the crash + re-route, not just the replay leg
    assert fe.metrics.tokens_delivered == 36
    assert len(fe.metrics.latencies) == 6
    for h in replayed:
        assert h.request.t_finish - h.request.t_submit >= 2.0 - float(
            h.request.uid)


def test_rerouted_request_keeps_original_deadline_asyncio():
    """Same contract on the wall-clock asyncio path, with the fault
    injected by the WallFaultInjector monkeypatch shim."""
    from repro.serving import RecoveryConfig
    from repro.serving.faults import FaultEvent, FaultPlan, WallFaultInjector
    fe = ServingFrontend([_fake_server(), _fake_server()],
                         recovery=RecoveryConfig(backoff_s=0.05))
    hs = [fe.submit(_req(u, max_new=6), deadline_s=60.0) for u in range(5)]
    t0 = [h.request.t_submit for h in hs]
    d0 = [h.deadline for h in hs]
    plan = FaultPlan([FaultEvent(0.0, "crash", 0)])
    with WallFaultInjector(fe.router.replicas, plan):
        summary = asyncio.run(fe.run_until_drained())
    assert plan.faults_injected == 1
    assert summary["replica_failures"] == 1
    assert summary["completed"] == 5 and summary["sheds"] == 0
    assert any(h.retries > 0 for h in hs)
    for h, t, d in zip(hs, t0, d0):
        assert h.request.t_submit == t
        assert h.deadline == d
        assert len(h.tokens) == 6
    assert fe.metrics.tokens_delivered == 30
    # the drain loop may finish before the backoff elapses; a later
    # scheduler tick past recover_at flips the replica back to ACTIVE
    rep = fe.router.replicas[0]
    fe._maybe_recover(rep.recover_at + 1e-3)
    assert rep.state == ACTIVE


# ------------------------------------------------- asyncio wall-clock mode --
def test_run_until_drained_completes_and_streams_async():
    fe = ServingFrontend([_fake_server(), _fake_server()])
    hs = [fe.submit(_req(u), session=f"s{u % 2}") for u in range(5)]

    async def consume(h):
        return [t async for t in h]

    async def main():
        streamed, summary = await asyncio.gather(
            consume(hs[0]), fe.run_until_drained())
        return streamed, summary

    streamed, summary = asyncio.run(main())
    assert all(h.done() for h in hs)
    assert streamed == hs[0].tokens and len(streamed) == 4
    assert summary["completed"] == 5
    assert summary["goodput_under_slo"] == 1.0   # no deadlines -> all in SLO
    assert sum(summary["router"]["routed"].values()) == 5
    for rs in summary["router"]["replicas"].values():
        assert rs["recompiles_after_warmup"] == 0


# ==================================================== real-testbed tests ===
@pytest.fixture(scope="module")
def tb() -> Testbed:
    return build_testbed(TestbedSpec(train_steps=160))


def _profile() -> LatencyProfile:
    # pronounced saturation knee at 16 concurrent tree tokens (the
    # emulated-profile economics of benchmarks/fig_serving.py)
    return LatencyProfile.synthetic(base_verify=1.0, slope=1.0,
                                    draft_frac=0.1, saturate_at=16,
                                    overhead=0.2)


def _frontend(tb, replicas, batch, profile):
    spec = egt_spec(4, 2)

    def engine():
        return SpeculativeEngine(
            tb.drafter, tb.d_params, tb.verifier, tb.v_params,
            profile=profile,
            buckets=buckets_for_depths((4,), width=2, verify_frac=0.75),
            depth_options=(4,), config=EngineConfig())

    servers = [ContinuousServer(engine(), batch_size=batch, prompt_pad=12,
                                spec=spec, verify_v=6)
               for _ in range(replicas)]
    return ServingFrontend(servers, profile=profile)


def _trace(tb, n=8, deadline_s=25.0, sessions=2):
    rng = np.random.default_rng(9)
    rows = []
    for uid in range(n):
        prompt = rng.integers(1, tb.spec.vocab, size=8).astype(np.int32)
        rows.append((float(uid), Request(uid=uid, prompt=prompt, max_new=16),
                     {"deadline_s": deadline_s,
                      "session": f"s{uid % sessions}"}))
    return rows


def test_scale_out_beats_scale_up_on_goodput_under_slo(tb):
    """The tentpole acceptance criterion: at EQUAL slot count, 2 replicas x
    batch 2 behind the router must beat 1 replica x batch 4 on the fraction
    of tokens delivered within deadline — batch 4 runs 24 concurrent tree
    tokens, past the knee, so its steps cost ~7x more."""
    prof = _profile()
    single = drive_frontend_trace(_frontend(tb, 1, 4, prof),
                                  _trace(tb), prof)
    routed = drive_frontend_trace(_frontend(tb, 2, 2, prof),
                                  _trace(tb), prof)
    assert routed["goodput_under_slo"] > single["goodput_under_slo"]
    assert routed["goodput_under_slo"] > 0.9
    assert routed["deadline_misses"] < single["deadline_misses"]
    for res in (single, routed):
        for rs in res["router"]["replicas"].values():
            assert rs["recompiles_after_warmup"] == 0


def test_drain_scale_cycle_repins_sessions_zero_recompiles(tb):
    """scale_down(1) mid-trace: replica 1's in-flight work retires on its
    warm executables, sessions pinned to it re-pin to replica 0, and
    scale_up(1) rejoins the pool — all with zero recompiles anywhere."""
    prof = _profile()
    fe = _frontend(tb, 2, 2, prof)
    # the window stays open past the last arrival: every s1 request that
    # lands while replica 1 drains MUST re-pin rather than wait it out
    events = ((4.0, "scale_down", 1), (30.0, "scale_up", 1))
    out = drive_frontend_trace(fe, _trace(tb, n=10, deadline_s=60.0),
                               prof, events=events)
    r = out["router"]
    assert r["scale_downs"] == 1 and r["scale_ups"] == 1
    assert r["repins"] >= 1            # a pinned session crossed the drain
    assert out["completed"] == 10      # nothing lost across the cycle
    assert fe.router.replicas[1].state == ACTIVE
    for rs in r["replicas"].values():
        assert rs["recompiles_after_warmup"] == 0
    # replica 1 served work before the drain and finished it (drain never
    # drops in-flight requests)
    assert r["replicas"]["1"]["completed"] >= 1


def test_emulated_drive_is_byte_deterministic_with_frontend_in_loop(tb):
    """Two identical emulated drives THROUGH the asyncio front-end (event
    loop, executor lane, router, admission control all in the path) must
    produce byte-identical artifacts: same token digest, same summary."""
    prof = _profile()
    events = ((4.0, "drain", 1), (9.0, "scale_up", 1))
    a = drive_frontend_trace(_frontend(tb, 2, 2, prof),
                             _trace(tb), prof, events=events)
    b = drive_frontend_trace(_frontend(tb, 2, 2, prof),
                             _trace(tb), prof, events=events)
    assert a["results_digest"] == b["results_digest"]
    assert (json.dumps(a, sort_keys=True, default=float)
            == json.dumps(b, sort_keys=True, default=float))
