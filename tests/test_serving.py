"""BatchedServer behaviour: EOS truncation, pad-slot replication, prompt
truncation recording and empty-queue guards — engine stubbed out so these
run without a testbed."""
import numpy as np
import pytest

from repro.core.engine import GenStats
from repro.serving.server import BatchedServer, Request


class FakeEngine:
    """Echoes a fixed per-row sequence; records what it was asked to run."""

    def __init__(self, seq_fn):
        self.seq_fn = seq_fn
        self.calls = []

    def generate(self, toks, lens, max_new):
        toks, lens = np.asarray(toks), np.asarray(lens)
        self.calls.append((toks.copy(), lens.copy(), max_new))
        seq = self.seq_fn(toks, lens, max_new)
        stats = GenStats()
        stats.accept_lens.append(np.ones(toks.shape[0], np.int64))
        stats.iter_times.append(1e-4)
        return seq, stats

    def mesh_info(self):
        return {"devices": 1, "shape": None}


def arange_rows(toks, lens, max_new):
    B = toks.shape[0]
    return np.arange(1, max_new + 1)[None].repeat(B, 0) + 100 * np.arange(B)[:, None]


def test_eos_truncation():
    def with_eos(toks, lens, max_new):
        seq = arange_rows(toks, lens, max_new)
        seq[0, 3] = 7  # EOS mid-sequence for request 0
        return seq

    srv = BatchedServer(FakeEngine(with_eos), batch_size=2, prompt_pad=4,
                        eos_id=7)
    srv.submit(Request(uid=0, prompt=np.array([1, 2]), max_new=8))
    srv.submit(Request(uid=1, prompt=np.array([3]), max_new=8))
    done = srv.run()
    np.testing.assert_array_equal(done[0].result, [1, 2, 3, 7])  # cut AT eos
    assert len(done[1].result) == 8                              # no eos: full

def test_pad_slots_replicate_request0_and_are_dropped():
    eng = FakeEngine(arange_rows)
    srv = BatchedServer(eng, batch_size=3, prompt_pad=4)
    srv.submit(Request(uid=5, prompt=np.array([9, 8, 7]), max_new=4))
    done = srv.run()
    toks, lens, _ = eng.calls[0]
    assert toks.shape == (3, 4)
    np.testing.assert_array_equal(toks[1], toks[0])  # pad slots replay row 0
    np.testing.assert_array_equal(toks[2], toks[0])
    np.testing.assert_array_equal(lens, [3, 3, 3])
    assert list(done) == [5]                         # pad rows never surface


def test_prompt_truncation_recorded():
    eng = FakeEngine(arange_rows)
    srv = BatchedServer(eng, batch_size=1, prompt_pad=4)
    req = Request(uid=0, prompt=np.arange(10) + 1, max_new=4)
    srv.submit(req)
    done = srv.run()
    toks, lens, _ = eng.calls[0]
    np.testing.assert_array_equal(toks[0], [1, 2, 3, 4])  # truncated, not 0-padded
    assert lens[0] == 4
    assert req.truncated                              # recorded, not silent
    assert done[0].stats["prompt_truncated"] is True


def test_empty_queue_guards():
    srv = BatchedServer(FakeEngine(arange_rows), batch_size=2, prompt_pad=4)
    assert srv.step() == []                 # all-empty queue is a no-op
    with pytest.raises(ValueError):
        srv._make_batch([])                 # defensive: never build 0-request batches


def test_run_drains_multiple_batches():
    eng = FakeEngine(arange_rows)
    srv = BatchedServer(eng, batch_size=2, prompt_pad=4)
    for uid in range(5):
        srv.submit(Request(uid=uid, prompt=np.array([1 + uid]), max_new=3))
    done = srv.run()
    assert sorted(done) == [0, 1, 2, 3, 4]
    assert len(eng.calls) == 3              # 2 + 2 + 1
    assert all(r.t_finish >= r.t_submit > 0 for r in done.values())
