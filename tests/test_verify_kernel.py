"""The fused GQA-native, length-aware verify kernel (the megastep hot path):
differential sweeps against the pure-jnp oracle over group sizes, dtypes and
boundary lengths; token-exactness of the kernel path vs the XLA einsum path
through the model and the full engine; and the zero-recompile contract with
the kernel enabled across slot churn and bucket switches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.buckets import buckets_for_depths
from repro.core.egt import egt_spec
from repro.core.engine import EngineConfig, SpeculativeEngine
from repro.kernels import ops, ref
from repro.models import Model
from repro.models.cache import make_kv_cache
from repro.serving.testbed import Testbed, TestbedSpec, build_testbed

S_CACHE = 256
BLOCK_S = 128


@pytest.fixture(scope="module")
def tb() -> Testbed:
    return build_testbed(TestbedSpec(train_steps=160))


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _committed(lengths, B, S):
    """kv_pos/q_pos for a contiguously committed prefix per row."""
    pos = jnp.arange(S)[None]
    kv_pos = jnp.where(pos < lengths[:, None], pos, -1).astype(jnp.int32)
    return kv_pos


# ---------------------------------------------------------- differential ----
# boundary lengths: empty, mid-block, exactly block-aligned, full cache
@pytest.mark.parametrize("lengths", [(0, 0), (37, 200), (BLOCK_S, 2 * BLOCK_S),
                                     (S_CACHE, S_CACHE), (0, S_CACHE)])
@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("G", [1, 4])
def test_verify_attention_matches_ref(G, quantized, lengths):
    B, W, KV, dh, T = 2, 5, 2, 64, 5
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    q = _rand(ks[0], (B, W, KV * G, dh))
    k = _rand(ks[1], (B, S_CACHE, KV, dh))
    v = _rand(ks[2], (B, S_CACHE, KV, dh))
    k_new = _rand(ks[3], (B, T, KV, dh))
    v_new = _rand(ks[4], (B, T, KV, dh))
    lens = jnp.asarray(lengths, jnp.int32)
    kv_pos = _committed(lens, B, S_CACHE)
    depths = jnp.broadcast_to(jnp.arange(W)[None] % 3, (B, W))
    q_pos = lens[:, None] + depths
    tree_mask = jax.random.bernoulli(ks[5], 0.5, (B, W, T))
    tree_mask = tree_mask.at[:, :, 0].set(True)
    scales = {}
    if quantized:
        from repro.quant import quantize_kv
        k, k_s = quantize_kv(k)
        v, v_s = quantize_kv(v)
        scales = dict(k_scale=k_s, v_scale=v_s)
    out = ops.verify_attention(q, k, v, kv_pos, q_pos, lens, k_new, v_new,
                               tree_mask, block_s=BLOCK_S, **scales)
    want = ref.verify_attention_ref(q, k, v, kv_pos, q_pos, lens, k_new,
                                    v_new, tree_mask, **scales)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_verify_attention_ignores_dead_tail_kv():
    """Length-awareness is semantic, not just a perf claim: garbage K/V in
    slots past the committed length (with poisoned pos metadata) must not
    leak into the output — those blocks are skipped/masked."""
    B, W, KV, G, dh, T = 1, 4, 2, 2, 64, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    q = _rand(ks[0], (B, W, KV * G, dh))
    k = _rand(ks[1], (B, S_CACHE, KV, dh))
    v = _rand(ks[2], (B, S_CACHE, KV, dh))
    k_new = _rand(ks[3], (B, T, KV, dh))
    v_new = _rand(ks[4], (B, T, KV, dh))
    lens = jnp.asarray([96], jnp.int32)
    kv_pos = _committed(lens, B, S_CACHE)
    q_pos = lens[:, None] + jnp.arange(W)[None]
    tree_mask = jnp.tril(jnp.ones((W, W), bool))[None]
    base = ops.verify_attention(q, k, v, kv_pos, q_pos, lens, k_new, v_new,
                                tree_mask, block_s=BLOCK_S)
    # poison everything past the committed prefix
    tail = jnp.arange(S_CACHE)[None] >= lens[:, None]
    k_bad = jnp.where(tail[..., None, None], 1e4, k)
    v_bad = jnp.where(tail[..., None, None], -1e4, v)
    pos_bad = jnp.where(tail, 10_000, kv_pos)  # occupied-looking, > length
    out = ops.verify_attention(q, k_bad, v_bad, pos_bad, q_pos, lens,
                               k_new, v_new, tree_mask, block_s=BLOCK_S)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=1e-6, atol=1e-6)


def test_verify_attention_scale_args_must_pair():
    B, W, KV, dh = 1, 2, 1, 64
    q = _rand(jax.random.PRNGKey(0), (B, W, KV, dh))
    k = _rand(jax.random.PRNGKey(1), (B, 64, KV, dh))
    lens = jnp.asarray([8], jnp.int32)
    with pytest.raises(ValueError):
        ops.verify_attention(q, k, k, _committed(lens, B, 64),
                             lens[:, None] + jnp.zeros((1, W), jnp.int32),
                             lens, q[:, :, :KV], q[:, :, :KV],
                             jnp.eye(W, dtype=bool)[None],
                             k_scale=jnp.ones((B, 64, KV, 4)))


# ------------------------------------------------- model-level exactness ----
@pytest.mark.parametrize("arch", ["yi-6b", "granite-20b"])
def test_model_kernel_path_matches_xla(arch):
    """Reduced GQA archs (G > 1) through the real model: decode and tree-
    verify logits on the fused kernel path match the XLA einsum path."""
    cfg_x = get_reduced_config(arch).replace(verify_kernel="xla")
    cfg_k = cfg_x.replace(verify_kernel="fused")
    assert cfg_x.num_q_per_kv > 1, "arch must exercise GQA grouping"
    m_x, m_k = Model(cfg_x), Model(cfg_k)
    params = m_x.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg_x.vocab_size)
    lengths = jnp.full((B,), S, jnp.int32)
    c_x = make_kv_cache(cfg_x).init(B, 64)
    c_k = make_kv_cache(cfg_k).init(B, 64)
    l_x, c_x, _ = m_x.prefill(params, toks, lengths, c_x)
    l_k, c_k, _ = m_k.prefill(params, toks, lengths, c_k)
    np.testing.assert_allclose(np.asarray(l_x), np.asarray(l_k),
                               rtol=1e-5, atol=1e-5)
    nxt = jnp.argmax(l_x, -1)
    d_x, c_x, _ = m_x.decode(params, nxt, c_x)
    d_k, c_k, _ = m_k.decode(params, nxt, c_k)
    np.testing.assert_allclose(np.asarray(d_x), np.asarray(d_k),
                               rtol=2e-5, atol=2e-5)
    assert (jnp.argmax(d_x, -1) == jnp.argmax(d_k, -1)).all()
    # a 4-node tree: root + chain + a sibling fork
    W = 4
    tree = jax.random.randint(jax.random.PRNGKey(2), (B, W), 0,
                              cfg_x.vocab_size)
    depths = jnp.broadcast_to(jnp.asarray([0, 1, 1, 2])[None], (B, W))
    amask = jnp.broadcast_to(jnp.asarray(
        [[1, 0, 0, 0], [1, 1, 0, 0], [1, 0, 1, 0], [1, 1, 0, 1]],
        bool)[None], (B, W, W))
    t_x, _, _ = m_x.tree_verify(params, tree, depths, amask, c_x)
    t_k, _, _ = m_k.tree_verify(params, tree, depths, amask, c_k)
    np.testing.assert_allclose(np.asarray(t_x), np.asarray(t_k),
                               rtol=2e-5, atol=2e-5)
    assert (jnp.argmax(t_x, -1) == jnp.argmax(t_k, -1)).all()


# ------------------------------------------- engine greedy token-exactness --
def _engine(tb, vk, **cfg_kw) -> SpeculativeEngine:
    return SpeculativeEngine(tb.drafter, tb.d_params, tb.verifier,
                             tb.v_params,
                             buckets=buckets_for_depths((3,), width=2,
                                                        verify_frac=0.75),
                             depth_options=(3,),
                             config=EngineConfig(verify_kernel=vk, **cfg_kw))


def _prompts(tb, n, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(1, tb.spec.vocab, size=(n, 12)).astype(np.int32)
    return jnp.asarray(toks), jnp.full((n,), 12, jnp.int32)


@pytest.mark.parametrize("quant", ["none", "int8-kv"])
def test_engine_kernel_path_token_exact(tb, quant):
    """Greedy decode through decode_step on the kernel path emits exactly
    the XLA oracle path's tokens — fp32 and int8-KV caches."""
    from repro.quant import QuantConfig
    toks, lens = _prompts(tb, 2)
    seqs = {}
    for vk in ("xla", "fused"):
        eng = _engine(tb, vk, quant=QuantConfig.parse(quant))
        assert eng.verify_path() == vk
        seq, stats = eng.generate(toks, lens, 32, spec=egt_spec(3, 2),
                                  verify_v=5)
        assert stats.aal >= 1.0
        seqs[vk] = np.asarray(seq)[:, :32]
    np.testing.assert_array_equal(seqs["fused"], seqs["xla"])


def test_engine_kernel_zero_recompiles_across_churn_and_buckets(tb):
    """The kernel path preserves the executable-cache contract: slot churn
    (prefill_into_slot / reset_state_slot) and bucket switches replay the
    same compiled megasteps — executable_count() must not grow."""
    eng = _engine(tb, "fused")
    buckets = buckets_for_depths((2, 3), width=2, verify_frac=0.75)
    state = eng.init_decode_state(2)
    prompt = np.arange(1, 9, dtype=np.int32)
    state = eng.prefill_into_slot(state, 0, prompt, len(prompt))
    state = eng.prefill_into_slot(state, 1, prompt[::-1].copy(), len(prompt))
    state, _ = eng.warmup_buckets(state, buckets)
    state = eng.reset_state_slot(state, 0)  # warm the slot-reset executable
    state = eng.prefill_into_slot(state, 0, prompt, len(prompt))
    warm = eng.executable_count()
    # churn every slot and switch buckets every step
    for i in range(4):
        state = eng.reset_state_slot(state, i % 2)
        state = eng.prefill_into_slot(state, i % 2, prompt, len(prompt))
        b = buckets[i % len(buckets)]
        state, res = eng.decode_step(state, spec=egt_spec(b.depth, b.width),
                                     verify_v=b.verify)
        assert res.accept_len.min() >= 1
    assert eng.executable_count() == warm, (
        "kernel path recompiled under slot churn / bucket switches")


# ---------------------------------------------------- HBM traffic model ----
def test_traffic_scales_with_length_not_max_len():
    """The modeled kernel bytes (what the regression gate pins) must grow
    with the committed length at block granularity while the XLA paths sit
    flat at the max_len extent."""
    from repro.kernels.traffic import (bytes_summary, verify_kernel_bytes,
                                       verify_xla_bytes)
    shape = dict(w=8, kv_heads=2, num_q_per_kv=4, head_dim=64, s_cache=512)
    kb = [verify_kernel_bytes(lengths=[ln] * 4, block_s=128, **shape)
          for ln in (0, 128, 256, 512)]
    assert kb == sorted(kb) and kb[0] < kb[1] < kb[3]
    # block granularity: lengths inside one block cost the same
    assert (verify_kernel_bytes(lengths=[1] * 4, block_s=128, **shape)
            == verify_kernel_bytes(lengths=[128] * 4, block_s=128, **shape))
    flat = verify_xla_bytes(batch=4, grouped=True, **shape)
    assert all(flat == verify_xla_bytes(batch=4, grouped=True, **shape)
               for _ in (0, 512))
    # ~num_q_per_kv x drop vs the repeated-KV baseline at full length (the
    # mask elimination pushes it slightly under/over G depending on dh)
    s = bytes_summary(lengths=[512] * 4, block_s=128, **shape)
    G = shape["num_q_per_kv"]
    assert s["repeated_over_kernel"] >= 0.85 * G
    # int8 caches cut kernel bytes further (payload 1B + scale groups)
    s8 = bytes_summary(lengths=[512] * 4, block_s=128, kv_itemsize=1,
                       scale_groups=4, **shape)
    assert s8["kernel_bytes"] < 0.5 * s["kernel_bytes"]
