"""Property-based tests (hypothesis) on the tree/pruning/acceptance
invariants that losslessness rests on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dependency
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import pruning, verify  # noqa: E402
from repro.core.tree import (TreeArrays, ancestor_mask,  # noqa: E402
                             ancestor_paths, gather_subtree, node_depths)

# print_blob: on failure, emit the @reproduce_failure blob alongside the
# randomized seed so the CI property-test job's failures replay locally
settings.register_profile("ci", max_examples=25, deadline=None,
                          print_blob=True)
settings.load_profile("ci")


# -------------------------------------------------- random-tree strategy ----
@st.composite
def random_parents(draw, max_n=14):
    """Topologically-ordered random forest rooted at 0 (parent < child)."""
    n = draw(st.integers(2, max_n))
    parents = [-1]
    for i in range(1, n):
        parents.append(draw(st.integers(0, i - 1)))
    return np.array(parents, np.int32)


def make_tree(parents: np.ndarray, rng: np.random.Generator) -> TreeArrays:
    n = len(parents)
    # path log-probs must be monotone non-increasing along edges
    edge_lp = -rng.exponential(1.0, n)
    path_lp = np.zeros(n, np.float64)
    for i in range(1, n):
        path_lp[i] = path_lp[parents[i]] + edge_lp[i]
    depths = np.zeros(n, np.int32)
    for i in range(1, n):
        depths[i] = depths[parents[i]] + 1
    return TreeArrays(
        tokens=jnp.asarray(rng.integers(0, 50, (1, n)), jnp.int32),
        parents=jnp.asarray(parents)[None],
        depths=jnp.asarray(depths)[None],
        path_lp=jnp.asarray(path_lp, jnp.float32)[None],
        live=jnp.ones((1, n), bool),
    )


# ------------------------------------------------------------ structure ----
@given(random_parents())
def test_ancestor_mask_matches_reference(parents):
    n = len(parents)
    got = np.asarray(ancestor_mask(jnp.asarray(parents)[None], n))[0]
    want = np.zeros((n, n), bool)
    for i in range(n):
        j = i
        while j >= 0:
            want[i, j] = True
            j = parents[j]
    np.testing.assert_array_equal(got, want)


@given(random_parents())
def test_node_depths_and_paths_consistent(parents):
    n = len(parents)
    d = np.asarray(node_depths(jnp.asarray(parents)[None], n))[0]
    paths = np.asarray(ancestor_paths(jnp.asarray(parents)[None], n))[0]
    for i in range(n):
        chain = [x for x in paths[i] if x >= 0]
        assert chain[-1] == i
        assert chain[0] == 0                      # rooted
        assert len(chain) == d[i] + 1
        for a, b in zip(chain, chain[1:]):
            assert parents[b] == a                # consecutive edges


# -------------------------------------------------------------- pruning ----
@given(random_parents(), st.integers(1, 10), st.integers(0, 10 ** 6))
def test_topk_prune_is_parent_closed_and_optimal(parents, v, seed):
    n = len(parents)
    v = min(v, n)
    tree = make_tree(parents, np.random.default_rng(seed))
    sub, select_idx = pruning.topk_prune(tree, v, n)
    sel = np.asarray(select_idx)[0]
    assert sel[0] == 0                            # root kept
    assert len(np.unique(sel)) == v               # no duplicates
    sel_set = set(sel.tolist())
    for i in sel:
        if parents[i] >= 0:
            assert parents[i] in sel_set          # parent-closed
    # matches the paper's bottom-up DP on the same instance
    probs = np.exp(np.asarray(tree.path_lp)[0], dtype=np.float64)
    dp_sel, dp_val = pruning.dp_prune_reference(parents, probs, v)
    got_val = probs[sel].sum()
    assert got_val >= dp_val - 1e-9               # top-k is optimal here
    # re-indexed subtree preserves edges
    new_parents = np.asarray(sub.parents)[0]
    for j in range(v):
        if new_parents[j] >= 0:
            assert sel[new_parents[j]] == parents[sel[j]]


@given(random_parents(), st.integers(0, 10 ** 6))
def test_gather_subtree_identity(parents, seed):
    n = len(parents)
    tree = make_tree(parents, np.random.default_rng(seed))
    idx = jnp.arange(n)[None]
    sub, _ = gather_subtree(tree, idx, n, n)
    for a, b in zip(sub, tree):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------- acceptance ----
@given(random_parents(), st.integers(0, 10 ** 6))
def test_greedy_accept_matches_host_reference(parents, seed):
    rng = np.random.default_rng(seed)
    n = len(parents)
    tree = make_tree(parents, rng)
    vocab = 50
    logits = jnp.asarray(rng.normal(size=(1, n, vocab)), jnp.float32)
    acc = verify.greedy_accept(tree, logits, n)
    from repro.core.scheduler import greedy_accept_host
    node_idx, alen, bonus, last = greedy_accept_host(
        np.asarray(tree.tokens), np.asarray(tree.parents),
        np.asarray(tree.depths), np.asarray(tree.live),
        np.asarray(jnp.argmax(logits, -1)), n)
    assert int(acc.accept_len[0]) == int(alen[0])
    assert int(acc.bonus[0]) == int(bonus[0])
    k = int(alen[0])
    np.testing.assert_array_equal(np.asarray(acc.node_idx)[0, :k],
                                  node_idx[0, :k])


@given(random_parents(), st.integers(0, 10 ** 6))
def test_greedy_accept_chain_is_valid(parents, seed):
    """Every accepted chain is a root-to-node path whose tokens equal the
    verifier's greedy continuation."""
    rng = np.random.default_rng(seed)
    n = len(parents)
    tree = make_tree(parents, rng)
    vocab = 8                                     # small => collisions likely
    logits = jnp.asarray(rng.normal(size=(1, n, vocab)), jnp.float32)
    tree = tree._replace(tokens=jnp.asarray(
        rng.integers(0, vocab, (1, n)), jnp.int32))
    acc = verify.greedy_accept(tree, logits, n)
    tgt = np.asarray(jnp.argmax(logits, -1))[0]
    toks = np.asarray(tree.tokens)[0]
    chain = np.asarray(acc.node_idx)[0][: int(acc.accept_len[0])]
    assert chain[0] == 0
    for prev, cur in zip(chain, chain[1:]):
        assert parents[cur] == prev
        assert toks[cur] == tgt[prev]             # token matches target argmax
    assert int(acc.bonus[0]) == tgt[chain[-1]]


# ----------------------------------- stochastic acceptance distribution ----
def test_stochastic_accept_preserves_target_distribution():
    """Rejection-sampling identity on a 2-token chain with toy dists: the
    marginal of the first emitted token must equal the target distribution."""
    vocab = 4
    n = 2                                          # root + one draft node
    draws = 4000
    rng = np.random.default_rng(0)
    q = np.array([0.5, 0.3, 0.1, 0.1])             # drafter dist at root
    p = np.array([0.25, 0.25, 0.3, 0.2])           # target dist at root
    counts = np.zeros(vocab)
    draft_tok = rng.choice(vocab, size=draws, p=q)
    # batch all draws at once
    B = draws
    tree = TreeArrays(
        tokens=jnp.concatenate([jnp.zeros((B, 1), jnp.int32),
                                jnp.asarray(draft_tok)[:, None]], 1),
        parents=jnp.broadcast_to(jnp.array([-1, 0], jnp.int32), (B, n)),
        depths=jnp.broadcast_to(jnp.array([0, 1], jnp.int32), (B, n)),
        path_lp=jnp.zeros((B, n), jnp.float32),
        live=jnp.ones((B, n), bool),
    )
    dp = jnp.broadcast_to(jnp.asarray(q, jnp.float32), (B, n, vocab))
    tp = jnp.broadcast_to(jnp.asarray(p, jnp.float32), (B, n, vocab))
    acc = verify.stochastic_accept(tree, dp, tp, jax.random.PRNGKey(1),
                                   a_max=2, max_children=1)
    alen = np.asarray(acc.accept_len)
    toks = np.asarray(tree.tokens)
    bonus = np.asarray(acc.bonus)
    emitted = np.where(alen >= 2, toks[:, 1], bonus)
    for t in range(vocab):
        counts[t] = (emitted == t).sum()
    freq = counts / draws
    np.testing.assert_allclose(freq, p, atol=0.03)
