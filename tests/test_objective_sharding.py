"""Unit tests: latency objective (Eq. 3), bucket selection, sharding rules,
depth predictor, and the HLO collective analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.buckets import buckets_for_depths, select_bucket
from repro.core.objective import (LatencyProfile, choose_config,
                                  speedup_objective)
from repro.launch import hlo_analysis as H


# ------------------------------------------------------------- objective ----
def test_speedup_objective_penalizes_wide_verification():
    prof = LatencyProfile.synthetic(base_verify=1.0, slope=0.05,
                                    saturate_at=16)
    # same AAL, wider verification => lower speedup once saturated
    s_small = speedup_objective(prof, aal=3.0, depth=4, width=4, verify_w=16)
    s_big = speedup_objective(prof, aal=3.0, depth=4, width=4, verify_w=256)
    assert s_small > s_big


def test_speedup_objective_vs_aal_diverge():
    """The paper's Fig. 5 phenomenon: AAL keeps growing with verify width but
    actual speedup reverses — the two objectives pick different configs."""
    prof = LatencyProfile.synthetic(base_verify=1.0, slope=0.1, saturate_at=8)
    # AAL grows slowly (log-ish) with V; latency grows linearly after 8
    cands = [(4, 4, v) for v in (4, 8, 16, 64, 256)]
    aal = {(4, 4, v): 1.0 + np.log2(v) * 0.5 for _, _, v in
           [(4, 4, v) for v in (4, 8, 16, 64, 256)]}
    best_speed = choose_config(prof, cands, aal, objective="speedup")
    best_aal = choose_config(prof, cands, aal, objective="aal")
    assert best_aal[2] == 256                 # AAL always wants the max
    assert best_speed[2] < 256                # latency objective stops earlier


def test_select_bucket_respects_depth_prediction():
    buckets = buckets_for_depths((2, 4, 8), width=4)
    prof = LatencyProfile.synthetic()
    b = select_bucket(buckets, 4, prof)
    assert b.depth >= 4
    b2 = select_bucket(buckets, 100, prof)    # beyond all buckets -> any
    assert b2 in buckets


# ---------------------------------------------------------------- specs ----
class _FakeMesh:
    """Duck-typed mesh (axis_names + shape) so the divisibility rules can be
    tested without multiple real devices."""

    def __init__(self, **shape):
        self.axis_names = tuple(shape)
        self.shape = shape


def test_spec_for_divisibility_fallback():
    from repro.sharding import specs as sh
    mesh = _FakeMesh(data=4, model=2)
    # kv_heads=3 does not divide model=2 -> the rule drops; head_dim picks
    # up the sharding instead (the GQA head-dim fallback)
    spec = sh.spec_for(("batch", "kv_heads", "head_dim_shard"),
                       (8, 3, 64), mesh)
    assert spec == P("data", None, "model")
    # divisible kv_heads shard normally; head_dim is left alone ("model"
    # is already claimed by kv_heads)
    spec = sh.spec_for(("batch", "kv_heads", "head_dim_shard"),
                       (8, 4, 64), mesh)
    assert spec == P("data", "model")
    # the decode cache's seq axis outranks kv_heads for the model axis
    spec = sh.spec_for(("cache_seq", "kv_heads"), (64, 4), mesh)
    assert spec == P("model")
    # batch smaller than the data axis stays replicated
    spec = sh.spec_for(("batch", None), (2, 16), _FakeMesh(data=4, model=2))
    assert spec == P()


def test_spec_for_drops_trailing_nones_and_unit_axes():
    """Specs must match jit's normalized output specs structurally, or the
    executable cache misses on every placed-vs-computed array pair (a
    silent recompile under serving)."""
    from repro.sharding import specs as sh
    spec = sh.spec_for(("batch", None, "kv_heads", None),
                       (8, 4, 4, 64), _FakeMesh(data=4, model=2))
    assert spec == P("data", None, "model")          # trailing None dropped
    spec = sh.spec_for(("batch", "vocab"), (8, 64), _FakeMesh(data=8, model=1))
    assert spec == P("data")                          # extent-1 axis dropped


def test_param_and_fsdp_shardings_on_host_mesh():
    from repro.models import Model
    from repro.configs import get_reduced_config
    from repro.sharding import specs as sh
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_reduced_config("yi-6b")
    defs = Model(cfg).param_defs()
    ps = sh.param_shardings(defs, mesh)
    fs = sh.fsdp_shardings(defs, mesh)
    assert len(jax.tree.leaves(ps)) == len(jax.tree.leaves(fs))


# -------------------------------------------------------- depth predictor ----
def test_depth_predictor_learns_separable_labels():
    from repro.core.depth_predictor import (best_bucket_labels, predict_depth,
                                            train_predictor)
    rng = np.random.default_rng(0)
    n, d = 512, 32
    opts = (2, 4, 8)
    # embeddings whose first coordinate encodes the achievable accept length
    emb = rng.normal(size=(n, d)).astype(np.float32)
    alen = np.where(emb[:, 0] > 0.5, 8, np.where(emb[:, 0] > -0.5, 4, 2))
    params, _ = train_predictor(jax.random.PRNGKey(0), jnp.asarray(emb),
                                jnp.asarray(alen), opts, steps=200)
    pred = np.asarray(predict_depth(params, jnp.asarray(emb), opts))
    acc = (pred == alen).mean()
    assert acc > 0.8, acc


def test_best_bucket_labels():
    from repro.core.depth_predictor import best_bucket_labels
    labels = np.asarray(best_bucket_labels(jnp.array([1, 2, 3, 4, 7, 8, 20]),
                                           (2, 4, 8)))
    np.testing.assert_array_equal(labels, [0, 0, 1, 1, 2, 2, 2])


# ------------------------------------------------------------ HLO parser ----
SAMPLE_HLO = """
HloModule jit_step

%add.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,256]{1,0} get-tuple-element(%p), index=1
  %ar = f32[128,256]{1,0} all-reduce(%x), channel_id=1, replica_groups=[4,4]<=[16], use_global_device_ids=true, to_apply=%add.1
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,256]) tuple(%ip, %ar)
}

%cond (p: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(8)
  ROOT %c = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (arg: f32[128,256]) -> f32[128,256] {
  %arg = f32[128,256]{1,0} parameter(0)
  %ag = f32[128,1024]{1,0} all-gather(%arg), channel_id=2, replica_groups=[4,4]<=[16], dimensions={1}
  %z = s32[] constant(0)
  %t0 = (s32[], f32[128,256]) tuple(%z, %arg)
  %w = (s32[], f32[128,256]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[128,256]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_collective_accounting():
    rep = H.analyze(SAMPLE_HLO)
    kinds = {c.kind for c in rep.collectives}
    assert kinds == {"all-reduce", "all-gather"}
    ar = next(c for c in rep.collectives if c.kind == "all-reduce")
    ag = next(c for c in rep.collectives if c.kind == "all-gather")
    assert ar.out_bytes == 128 * 256 * 4
    assert ar.group_size == 4
    assert ar.multiplier == 8.0               # inside the 8-trip while body
    assert ag.out_bytes == 128 * 1024 * 4
    assert ag.operand_bytes == 128 * 1024 * 4 / 4
    assert ag.multiplier == 1.0
    total = rep.collective_bytes
    assert total == 8 * 128 * 256 * 4 + 128 * 1024
    assert rep.loop_multiplier == 8.0
    # wire bytes: ring all-reduce 2*(g-1)/g, all-gather (g-1)/g of output
    np.testing.assert_allclose(
        rep.collective_wire_bytes,
        8 * 2 * 128 * 256 * 4 * 3 / 4 + 128 * 1024 * 4 * 3 / 4)


def test_hlo_group_size_list_format():
    assert H._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert H._group_size("replica_groups=[16,32]<=[512]") == 32
    assert H._group_size("source_target_pairs={{0,1}}") == 1
