"""The stable ``repro.serving`` surface: explicit ``__all__``, no private
leaks, and the ServeConfig argv/JSON round-trip contract the CLI and the
benchmarks both depend on."""
import argparse
import dataclasses
import json

import pytest

import repro.serving as serving
from repro.serving import ServeConfig


def test_all_is_sorted_explicit_and_importable():
    assert serving.__all__ == sorted(serving.__all__)
    for name in serving.__all__:
        assert hasattr(serving, name), f"__all__ exports missing {name}"
    assert not any(n.startswith("_") for n in serving.__all__)


def test_star_import_matches_all():
    ns = {}
    exec("from repro.serving import *", ns)
    public = {k for k in ns if not k.startswith("_")}
    assert public == set(serving.__all__)


def test_expected_surface_is_pinned():
    # the redesigned API: additions here are deliberate, removals breaking
    assert set(serving.__all__) == {
        "AdmissionConfig", "BatchedServer", "BucketController",
        "ContinuousServer", "FaultEvent", "FaultPlan", "FrontendMetrics",
        "NoReplicaAvailable", "NumericalFault", "PoolExhausted",
        "RecoveryConfig", "Replica", "ReplicaError", "Request",
        "RequestHandle", "Router", "RouterMetrics", "ServeConfig",
        "ServingError", "ServingFrontend", "ServingMetrics", "StepTimeout",
        "drive_frontend_trace", "mask_padded_vocab", "sample",
    }


def test_cache_api_surface_is_pinned():
    # the KVCache redesign collapsed the free-function cache surface
    # (init_cache / slot_update / slot_slice / write_tokens / ...) behind
    # the strategy objects; only the curated names below are public now
    from repro.models import cache
    assert cache.__all__ == sorted(cache.__all__)
    for name in cache.__all__:
        assert hasattr(cache, name), f"__all__ exports missing {name}"
    assert set(cache.__all__) == {
        "Cache", "ContiguousCache", "KVCache", "PageState", "PagedCache",
        "PrefixStore", "cache_logical_axes", "cache_shardings",
        "make_kv_cache", "place_cache", "shard_cache", "visible_mask",
    }
    for gone in ("init_cache", "slot_update", "slot_slice", "write_tokens",
                 "commit_region", "cache_nbytes", "entry_kv",
                 "entry_kernel_kv"):
        assert not hasattr(cache, gone), f"legacy cache API leaked: {gone}"


# ----------------------------------------------------- ServeConfig ---------
def test_serveconfig_argv_roundtrip_defaults_and_overrides():
    assert ServeConfig().to_argv() == []          # defaults -> empty argv
    cfg = ServeConfig(server="frontend", replicas=3, batch=2, slo_s=30.0,
                      adaptive=True, affinity=False, temperature=0.5,
                      quantize="int8-kv", trace_dir="/tmp/t")
    argv = cfg.to_argv()
    assert "--no-affinity" in argv                # True-default bool flips
    assert "--adaptive" in argv
    assert ServeConfig.parse(argv) == cfg


def test_serveconfig_json_roundtrip_and_unknown_key_rejection():
    cfg = ServeConfig(server="continuous", adaptive=True, hysteresis=0.2)
    blob = json.loads(json.dumps(cfg.to_json()))
    assert ServeConfig.from_json(blob) == cfg
    with pytest.raises(ValueError, match="unknown"):
        ServeConfig.from_json({**blob, "typo_field": 1})


def test_serveconfig_validates_choices():
    with pytest.raises(ValueError, match="server="):
        ServeConfig(server="nope")
    with pytest.raises(ValueError, match="overload="):
        ServeConfig(overload="drop")


def test_serveconfig_cli_covers_every_field():
    ap = argparse.ArgumentParser()
    ServeConfig.add_args(ap)
    ns = ap.parse_args([])
    field_names = {f.name for f in dataclasses.fields(ServeConfig)}
    assert set(vars(ns)) == field_names           # one flag per field
    assert ServeConfig.from_args(ns) == ServeConfig()
