"""Quantized inference path: weight-only int8 params, int8 KV caches, and
the headline contract — int8-KV greedy decode is token-exact against fp32
for at least the first 64 generated tokens on the testbed, while one slot's
cache bytes shrink enough to fit >= 1.8x the slots into a fixed budget."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.buckets import buckets_for_depths
from repro.core.egt import egt_spec
from repro.core.engine import EngineConfig, SpeculativeEngine
from repro.data.pipeline import MarkovSource
from repro.models import cache as cache_lib
from repro.quant import (QTensor, QuantConfig, dequant_kv, dequant_params,
                         param_nbytes, quantize_kv, quantize_params)
from repro.serving.continuous import slots_at_budget
from repro.serving.testbed import Testbed, TestbedSpec, build_testbed

SPEC, VERIFY_V = egt_spec(4, 2), 6


@pytest.fixture(scope="module")
def tb() -> Testbed:
    # the llama-68m / llama-2-7b pair at laptop scale (shared disk cache)
    return build_testbed(TestbedSpec(train_steps=160))


def _engine(tb, mode: str) -> SpeculativeEngine:
    return SpeculativeEngine(
        tb.drafter, tb.d_params, tb.verifier, tb.v_params,
        buckets=buckets_for_depths((4,), width=2, verify_frac=0.75),
        depth_options=(4,),
        config=EngineConfig(quant=QuantConfig.parse(mode)))


def _prompts(tb, B=2, S=12, seed=0):
    src = MarkovSource(vocab=tb.spec.vocab,
                       concentration=tb.data_cfg.concentration, seed=0)
    rng = np.random.default_rng(seed)
    prompt = jnp.asarray(np.stack([src.sample(rng, S) for _ in range(B)]))
    return prompt, jnp.full((B,), S, jnp.int32)


# ------------------------------------------------------------ QuantConfig --
def test_quant_config_parse_roundtrip():
    assert QuantConfig.parse("none") == QuantConfig()
    assert QuantConfig.parse(None) == QuantConfig()
    qc = QuantConfig.parse("int8-kv")
    assert qc.kv_int8 and not qc.weights and qc.mode == "int8-kv"
    qc = QuantConfig.parse("int8-kv+w8")
    assert qc.kv_int8 and qc.weights and qc.mode == "int8-kv+w8"
    with pytest.raises(ValueError):
        QuantConfig.parse("fp4")
    hash(qc)  # must stay hashable: it sits inside EngineConfig / jit keys


# -------------------------------------------------------------- weights ----
def test_quantize_params_error_bound_and_selectivity():
    key = jax.random.PRNGKey(0)
    params = {
        "w": jax.random.normal(key, (64, 128)) * 0.1,     # quantized
        "norm": jnp.ones((128,)),                          # 1-D: untouched
        "tiny": jax.random.normal(key, (4, 4)),            # small: untouched
    }
    qp = quantize_params(params)
    assert isinstance(qp["w"], QTensor)
    assert qp["w"].q.dtype == jnp.int8
    assert qp["norm"] is params["norm"]
    assert qp["tiny"] is params["tiny"]
    dq = dequant_params(qp)
    # symmetric round-to-nearest: |err| <= scale/2 = absmax/254 per channel
    w = np.asarray(params["w"])
    bound = np.abs(w).max(-1, keepdims=True) / 254.0 + 1e-7
    assert (np.abs(np.asarray(dq["w"]) - w) <= bound).all()
    # idempotent, and dequant of unquantized tree is the identity
    assert isinstance(quantize_params(qp)["w"], QTensor)
    assert dequant_params(params)["w"] is params["w"]


def test_quantize_params_shrinks_bytes(tb):
    fp = param_nbytes(tb.v_params)
    q = param_nbytes(quantize_params(tb.v_params))
    assert q < 0.5 * fp, (q, fp)  # int8 payload + scales well under half


def test_qtensor_is_a_pytree():
    qt = quantize_params({"w": jnp.ones((64, 64))})["w"]
    leaves = jax.tree.leaves(qt)
    assert len(leaves) == 2  # payload + scales traverse as ordinary leaves
    rebuilt = jax.tree.unflatten(jax.tree.structure(qt), leaves)
    assert isinstance(rebuilt, QTensor) and rebuilt.dtype == qt.dtype


# ------------------------------------------------------------- KV cache ----
def test_quantize_kv_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 3, 64))
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == (2, 5, 3, 4)  # 64/16 groups
    err = jnp.abs(dequant_kv(q, s) - x)
    bound = jnp.max(jnp.abs(x.reshape(2, 5, 3, 4, 16)), -1) / 254.0 + 1e-7
    assert bool((err.reshape(2, 5, 3, 4, 16) <= bound[..., None]).all())


def test_quantized_cache_write_then_read_is_deterministic():
    cfg = ModelConfig(name="q", num_layers=2, d_model=64, num_heads=2,
                      num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=32)
    kv = cache_lib.make_kv_cache(cfg)
    c = kv.init(2, 64, kv_dtype=jnp.int8)
    entry = jax.tree.map(lambda a: a[0], c["blocks"])["layer0"]
    k = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(3), (2, 3, 2, 32))
    pos = jnp.broadcast_to(jnp.arange(3)[None], (2, 3)).astype(jnp.int32)
    written = kv.write_tokens(entry, k, v, pos)
    ek, ev = cache_lib.KVCache.entry_kv(written)
    # the single rounding happens at write time: reading back equals the
    # direct quantize->dequantize of the input, bit-exactly
    np.testing.assert_array_equal(np.asarray(ek[:, :3]),
                                  np.asarray(dequant_kv(*quantize_kv(k))))
    np.testing.assert_array_equal(np.asarray(ev[:, :3]),
                                  np.asarray(dequant_kv(*quantize_kv(v))))
    # unwritten slots dequantize to exact zeros (neutral 1.0 scales)
    assert bool((ek[:, 3:] == 0).all())


def test_cache_nbytes_quantized_ratio(tb):
    cfg = tb.verifier.cfg
    kv = cache_lib.make_kv_cache(cfg)
    fp = kv.nbytes(1, 512)
    q8 = kv.nbytes(1, 512, kv_dtype=jnp.int8)
    assert fp / q8 >= 2.0, (fp, q8)


# ------------------------------------------------- the headline contract --
def test_int8_kv_greedy_decode_token_exact_vs_fp32(tb):
    """int8-KV greedy decode must match fp32 token-for-token on (at least)
    the first 64 generated tokens — the KV quantization error stays below
    every argmax margin the verifier produces on this path."""
    prompt, lengths = _prompts(tb)
    seq32, st32 = _engine(tb, "none").generate(prompt, lengths, 72,
                                               spec=SPEC, verify_v=VERIFY_V)
    seq8, st8 = _engine(tb, "int8-kv").generate(prompt, lengths, 72,
                                                spec=SPEC, verify_v=VERIFY_V)
    for b in range(seq32.shape[0]):
        t32 = seq32[b][seq32[b] >= 0]  # compact the per-step -1 padding
        t8 = seq8[b][seq8[b] >= 0]
        assert len(t32) >= 64 and len(t8) >= 64, (len(t32), len(t8))
        np.testing.assert_array_equal(
            t32[:64], t8[:64],
            err_msg=f"int8-KV greedy decode diverged from fp32 within the "
                    f"first 64 tokens of row {b}")
    assert st8.aal >= 1.0


def test_w8_weight_only_decodes_and_speculates(tb):
    """int8-kv+w8 has no exactness contract (weight rounding shifts logits),
    but the engine must still draft/verify/commit sanely."""
    prompt, lengths = _prompts(tb, seed=3)
    seq, stats = _engine(tb, "int8-kv+w8").generate(prompt, lengths, 24,
                                                    spec=SPEC,
                                                    verify_v=VERIFY_V)
    # rows are front-aligned and -1 padded per iteration; every real token
    # must be in-vocab and every row must reach its token budget
    assert ((seq >= 0).sum(axis=1) >= 24).all()
    assert (seq[seq >= 0] < tb.spec.vocab).all()
    assert stats.aal >= 1.0  # speculation still accepts beyond the root


def test_int8_cache_shardings_place_scales_on_mesh():
    """cache_shardings must resolve the new scale leaves: on a data x model
    mesh the scales shard along cache_seq exactly like their int8 payload,
    so each tile and its scales land on the same device (runs under the
    tier1-multidevice CI job; skips on one device)."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (emulate with "
                    "--xla_force_host_platform_device_count)")
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:2]).reshape(1, 2), ("data", "model"))
    cfg = ModelConfig(name="qmesh", num_layers=2, d_model=128, num_heads=2,
                      num_kv_heads=2, head_dim=64, d_ff=256, vocab_size=32)
    abstract = cache_lib.make_kv_cache(cfg).init(2, 64, abstract=True,
                                                 kv_dtype=jnp.int8)
    sh = cache_lib.cache_shardings(abstract, mesh)
    blk = sh["blocks"]["layer0"]
    # seq axis (index 2 on stacked [layers, B, S, ...] leaves) -> model
    assert blk["k"].spec[2] == "model"
    assert blk["k_scale"].spec[2] == "model"
    assert blk["v_scale"].spec[2] == "model"
    # and a concrete quantized cache actually places without error
    concrete = cache_lib.make_kv_cache(cfg).init(2, 64, kv_dtype=jnp.int8)
    placed = cache_lib.place_cache(concrete, mesh)
    scale_leaf = placed["blocks"]["layer0"]["k_scale"]
    assert scale_leaf.sharding.spec[2] == "model"


def test_slots_at_budget_ratio(tb):
    """>= 1.8x concurrent slots at fixed cache bytes — the capacity headline
    the quant_sweep benchmark records."""
    fp32 = _engine(tb, "none")
    int8 = _engine(tb, "int8-kv")
    budget = 4 * fp32.cache_bytes_per_slot()["total"]
    assert slots_at_budget(fp32, budget) == 4
    assert slots_at_budget(int8, budget) >= int(1.8 * 4)
