"""Telemetry: metric/registry semantics, tracer invariants, Chrome-trace
export validity, and the serving contracts (telemetry on/off token-exactness,
recompile parity, emulated-clock determinism of exported snapshots)."""
import io
import json
import logging

import numpy as np
import pytest

from repro.core.buckets import buckets_for_depths
from repro.core.egt import egt_spec
from repro.core.engine import EngineConfig, SpeculativeEngine
from repro.core.objective import LatencyProfile
from repro.serving.continuous import ContinuousServer
from repro.serving.emulation import drive_trace
from repro.serving.server import Request
from repro.serving.testbed import Testbed, TestbedSpec, build_testbed
from repro.telemetry import (BoundedSeries, Counter, EmulatedClock, EventLog,
                             Gauge, Histogram, Registry, Telemetry, Tracer,
                             WallClock, linear_buckets,
                             validate_chrome_trace)
from repro.telemetry.events import JsonLineFormatter


# ------------------------------------------------------------- clocks ------
def test_emulated_clock_advances_monotonically():
    c = EmulatedClock(start=2.0)
    c.advance(0.5)
    assert c.now() == 2.5
    c.advance_to(1.0)                      # backward advance_to is a no-op
    assert c.now() == 2.5
    c.advance_to(3.0)
    assert c.now() == 3.0
    with pytest.raises(ValueError):
        c.advance(-0.1)


def test_wall_clock_moves_forward():
    c = WallClock()
    a, b = c.now(), c.now()
    assert b >= a


# ------------------------------------------------------ counters/gauges ----
def test_counter_accumulates_per_label():
    c = Counter("reqs_total", "requests")
    c.inc()
    c.inc(2.0, route="a")
    c.inc(route="a")
    assert c.value() == 1.0
    assert c.value(route="a") == 3.0


def test_gauge_set_and_callback():
    g = Gauge("depth", "current depth")
    g.set(4.0)
    g.set(8.0, bucket="8x2")
    snap = g.snapshot_values()
    assert snap[""] == 4.0
    assert snap['{bucket="8x2"}'] == 8.0
    lazy = Gauge("lazy", "callback gauge", fn=lambda: 7.0)
    assert lazy.snapshot_values()[""] == 7.0


# ---------------------------------------------------------- histograms -----
def test_histogram_quantiles_match_numpy_within_bucket_width():
    width = 0.1
    h = Histogram("lat", "latency", bounds=linear_buckets(width, width, 60))
    rng = np.random.default_rng(0)
    xs = rng.uniform(0.05, 5.5, size=5000)
    for x in xs:
        h.observe(float(x))
    for q in (0.50, 0.95, 0.99):
        est = h.quantile(q)
        exact = float(np.percentile(xs, 100 * q))
        assert abs(est - exact) <= width + 1e-9, (q, est, exact)
    assert abs(h.mean - xs.mean()) < 0.01


def test_histogram_quantile_clamped_to_observed_range():
    h = Histogram("x", "x", bounds=[1.0, 10.0, 100.0])
    h.observe(3.0)
    h.observe(4.0)
    # bucket upper bounds are coarse; estimates must stay inside [min, max]
    assert 3.0 <= h.quantile(0.5) <= 4.0
    assert h.quantile(0.99) <= 4.0
    empty = Histogram("y", "y", bounds=[1.0])
    assert empty.quantile(0.5) == 0.0


def test_histogram_rejects_unsorted_bounds():
    with pytest.raises(ValueError):
        Histogram("bad", "bad", bounds=[2.0, 1.0])


# ------------------------------------------------------------ registry -----
def test_registry_register_is_idempotent_by_name():
    reg = Registry()
    c1 = reg.counter("hits", "hits")
    c2 = reg.counter("hits", "hits")
    assert c1 is c2
    with pytest.raises(ValueError):
        reg.gauge("hits", "now a gauge?!")


def test_prometheus_exposition_format():
    reg = Registry()
    reg.counter("requests_total", "requests served").inc(3, route="a b")
    h = reg.histogram("iter_seconds", "iteration time",
                      bounds=[0.1, 1.0])
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = reg.to_prometheus()
    assert "# HELP requests_total requests served" in text
    assert "# TYPE requests_total counter" in text
    assert 'requests_total{route="a b"} 3' in text
    # cumulative buckets + implicit +Inf, sum and count
    assert 'iter_seconds_bucket{le="0.1"} 1' in text
    assert 'iter_seconds_bucket{le="1"} 2' in text
    assert 'iter_seconds_bucket{le="+Inf"} 3' in text
    assert "iter_seconds_count 3" in text


def test_registry_snapshot_has_quantiles():
    reg = Registry()
    h = reg.histogram("t", "t", bounds=linear_buckets(1.0, 1.0, 10))
    for v in range(1, 9):
        h.observe(float(v))
    snap = reg.snapshot()
    vals = snap["t"]["values"]
    assert {"count", "sum", "p50", "p95", "p99"} <= set(vals)
    assert vals["count"] == 8


# ------------------------------------------------------- bounded series ----
def test_bounded_series_window_and_exact_totals():
    s = BoundedSeries(maxlen=8, hist=Histogram("s", "s",
                                               bounds=linear_buckets(1, 1, 40)))
    for v in range(1, 21):
        s.append(float(v))
    assert len(s) == 8                       # window is bounded ...
    assert s.count == 20                     # ... aggregates are exact
    assert s.total == sum(range(1, 21))
    assert s.mean == pytest.approx(sum(range(1, 21)) / 20)
    assert s.last == 20.0
    # wrapped: quantile comes from the histogram, still well-defined
    assert 0 < s.quantile(0.5) <= 40


def test_bounded_series_exact_quantile_before_wrap():
    s = BoundedSeries(maxlen=64)
    xs = [3.0, 1.0, 4.0, 1.5, 9.0]
    for v in xs:
        s.append(v)
    assert s.quantile(0.5) == float(np.percentile(xs, 50))


def test_bounded_series_wrap_without_hist_refuses_quantile():
    s = BoundedSeries(maxlen=2)
    for v in (1.0, 2.0, 3.0):
        s.append(v)
    with pytest.raises(ValueError):
        s.quantile(0.5)


# --------------------------------------------------------------- tracer ----
def _traced_clock():
    clk = EmulatedClock()
    return clk, Tracer(clock=clk)


def test_span_nesting_and_ordering():
    clk, tr = _traced_clock()
    tr.begin("outer", track="engine")
    clk.advance(1.0)
    tr.begin("inner", track="engine", bucket="4x2")
    clk.advance(0.5)
    tr.instant("compile", track="engine")
    tr.end(track="engine")                   # inner
    clk.advance(0.25)
    tr.end(track="engine", accept=3)         # outer picks up closing args
    blob = tr.to_chrome_trace()
    assert validate_chrome_trace(blob) == []
    evs = [e for e in blob["traceEvents"] if e["ph"] == "X"]
    names = [e["name"] for e in evs]
    assert names == ["outer", "inner"]       # parent sorted before child
    outer, inner = evs
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1
    assert outer["args"]["accept"] == 3
    inst = [e for e in blob["traceEvents"] if e["ph"] == "i"]
    assert inst[0]["args"]["enclosing"] == "inner"


def test_end_without_begin_raises():
    _, tr = _traced_clock()
    with pytest.raises(RuntimeError):
        tr.end(track="engine")


def test_span_contextmanager_closes_on_exception():
    clk, tr = _traced_clock()
    with pytest.raises(RuntimeError, match="boom"):
        with tr.span("work", track="t"):
            clk.advance(1.0)
            raise RuntimeError("boom")
    assert tr.current("t") is None           # span closed despite the raise
    assert validate_chrome_trace(tr.to_chrome_trace()) == []


def test_validator_rejects_overflowing_child_span():
    doctored = {"traceEvents": [
        {"ph": "X", "name": "parent", "pid": 1, "tid": 2, "ts": 0, "dur": 10},
        {"ph": "X", "name": "child", "pid": 1, "tid": 2, "ts": 5, "dur": 50},
    ]}
    errs = validate_chrome_trace(doctored)
    assert any("overflows" in e or "nest" in e for e in errs)


def test_tracer_bounded_buffer_drops_and_counts():
    clk, _ = _traced_clock()
    tr = Tracer(clock=clk, maxlen=4)
    for i in range(10):
        tr.instant(f"e{i}", track="t")
    assert tr.dropped == 6
    assert len(tr.to_chrome_trace()["traceEvents"]) <= 4 + 1  # + M metadata


# ------------------------------------------------------------ event log ----
def test_event_log_json_lines_share_tracer_schema():
    clk = EmulatedClock(start=5.0)
    tr = Tracer(clock=clk)
    buf = io.StringIO()
    handler = logging.StreamHandler(buf)
    handler.setFormatter(JsonLineFormatter())
    logger = logging.getLogger("repro.test.events")
    logger.handlers = [handler]
    logger.setLevel(logging.INFO)
    logger.propagate = False
    ev = EventLog(logger=logger, clock=clk, tracer=tr)
    ev.emit("admission", uid=3, slot=1)
    rec = json.loads(buf.getvalue().strip())
    assert rec == {"event": "admission", "slot": 1, "ts": 5.0, "uid": 3}
    # mirrored onto the tracer's events track as an instant
    inst = [e for e in tr.to_chrome_trace()["traceEvents"] if e["ph"] == "i"]
    assert inst and inst[0]["name"] == "admission"
    assert inst[0]["args"]["uid"] == 3


# ---------------------------------------------- serving contracts (slow) ----
SPEC, VERIFY_V = egt_spec(3, 2), 5


@pytest.fixture(scope="module")
def tb() -> Testbed:
    return build_testbed(TestbedSpec(train_steps=160))


def _engine(tb):
    return SpeculativeEngine(tb.drafter, tb.d_params, tb.verifier,
                             tb.v_params,
                             buckets=buckets_for_depths((3,), width=2,
                                                        verify_frac=0.75),
                             depth_options=(3,), config=EngineConfig())


def _trace(tb, n, seed=11):
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    for uid in range(n):
        t += float(rng.exponential(1.0 / 0.8))
        plen = int(rng.integers(6, 14))
        prompt = rng.integers(1, tb.spec.vocab, size=plen).astype(np.int32)
        out.append((t, Request(uid=uid, prompt=prompt, max_new=10)))
    return out


def _drive(tb, telemetry):
    srv = ContinuousServer(_engine(tb), batch_size=2, prompt_pad=16,
                           spec=SPEC, verify_v=VERIFY_V, telemetry=telemetry)
    drive_trace(srv, _trace(tb, 4), LatencyProfile.synthetic())
    return srv


def _exports(tel):
    snap = json.dumps(tel.registry.snapshot(), sort_keys=True, default=float)
    trace = json.dumps(tel.tracer.to_chrome_trace(), sort_keys=True)
    return snap, trace


@pytest.fixture(scope="module")
def drives(tb):
    off = _drive(tb, None)
    on = _drive(tb, Telemetry(clock=EmulatedClock()))
    on2 = _drive(tb, Telemetry(clock=EmulatedClock()))
    return off, on, on2


def test_telemetry_is_token_invisible(drives):
    """Full telemetry (registry + tracer + event mirror) must not change a
    single emitted token, nor introduce a recompile."""
    off, on, _ = drives
    assert sorted(off.done) == sorted(on.done)
    for uid in off.done:
        np.testing.assert_array_equal(off.done[uid].result,
                                      on.done[uid].result)
    assert off.metrics.summary()["recompiles_after_warmup"] == 0
    assert on.metrics.summary()["recompiles_after_warmup"] == 0


def test_emulated_clock_exports_are_deterministic(drives):
    """Two identical emulated drives export byte-identical registry
    snapshots AND Chrome traces — no wall-clock leaks anywhere."""
    _, on, on2 = drives
    assert _exports(on.telemetry) == _exports(on2.telemetry)


def test_serving_trace_exports_valid_request_lifecycle(drives):
    _, on, _ = drives
    blob = on.telemetry.tracer.to_chrome_trace()
    assert validate_chrome_trace(blob) == []
    names = {}                                # tid -> thread_name
    for e in blob["traceEvents"]:
        if e["ph"] == "M":
            names[e["tid"]] = e["args"]["name"]
    by_track = {}
    for e in blob["traceEvents"]:
        if e["ph"] in ("X", "i"):
            by_track.setdefault(names[e["tid"]], set()).add(e["name"])
    req_tracks = [v for k, v in by_track.items() if k.startswith("req:")]
    assert req_tracks and any({"queued", "active", "retired"} <= v
                              for v in req_tracks)
    assert "megastep" in by_track.get("engine", set())


def test_serving_metrics_exposition_covers_engine_and_spec(drives):
    _, on, _ = drives
    text = on.telemetry.registry.to_prometheus()
    for name in ("serving_iter_seconds", "serving_request_latency_seconds",
                 "engine_executable_count", "engine_compiles_total",
                 "spec_accept_ratio", "spec_wasted_draft_tokens_total"):
        assert f"# TYPE {name}" in text, name
    snap = on.telemetry.registry.snapshot()
    assert snap["serving_accept_len"]["values"]["count"] > 0
