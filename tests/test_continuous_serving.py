"""Continuous-batching serving: slot-refill correctness, equivalence with
run-to-completion batching at temperature 0, and the compile-stability
contract (zero new engine compiles after warmup across slot churn)."""
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.buckets import buckets_for_depths
from repro.core.egt import egt_spec
from repro.core.engine import EngineConfig, SpeculativeEngine
from repro.models import cache as cache_lib
from repro.serving.continuous import ContinuousServer
from repro.serving.server import BatchedServer, Request
from repro.serving.testbed import Testbed, TestbedSpec, build_testbed

SPEC, VERIFY_V = egt_spec(3, 2), 5


@pytest.fixture(scope="module")
def tb() -> Testbed:
    return build_testbed(TestbedSpec(train_steps=160))


def _engine(tb, **cfg_kw) -> SpeculativeEngine:
    # one depth-3 bucket == (SPEC, VERIFY_V), so BatchedServer's dynamic
    # selection and the pinned continuous server share one megastep
    return SpeculativeEngine(tb.drafter, tb.d_params, tb.verifier,
                             tb.v_params,
                             buckets=buckets_for_depths((3,), width=2,
                                                        verify_frac=0.75),
                             depth_options=(3,),
                             config=EngineConfig(**cfg_kw))


def _requests(tb, n, seed=0, eos_free=True):
    rng = np.random.default_rng(seed)
    out = []
    for uid in range(n):
        plen = int(rng.integers(6, 14))
        prompt = rng.integers(1, tb.spec.vocab, size=plen).astype(np.int32)
        out.append(Request(uid=uid, prompt=prompt,
                           max_new=int(rng.integers(8, 18))))
    return out


# ------------------------------------------------------- the main contract --
def test_continuous_matches_batched_with_zero_recompiles(tb):
    """>= 3x batch_size concurrent requests, mid-flight slot refill, outputs
    identical to BatchedServer at temperature 0, zero compiles after warmup."""
    B, n = 2, 6  # 3x batch_size
    eng = _engine(tb)

    batched = BatchedServer(eng, batch_size=B, prompt_pad=16)
    for r in _requests(tb, n):
        batched.submit(r)
    ref = batched.run()

    streamed = {}

    def on_tokens(uid, toks):
        streamed.setdefault(uid, []).extend(int(t) for t in toks)

    cont = ContinuousServer(eng, batch_size=B, prompt_pad=16,
                            spec=SPEC, verify_v=VERIFY_V)
    cont.warmup()
    for r in _requests(tb, n):
        r.stream = on_tokens
        cont.submit(r)
    done = {uid: h.request for uid, h in cont.serve().items()}

    assert sorted(done) == sorted(ref)
    for uid in ref:
        np.testing.assert_array_equal(
            done[uid].result, ref[uid].result,
            err_msg=f"continuous output diverged from batched for uid {uid}")
        np.testing.assert_array_equal(streamed[uid], done[uid].result)

    m = cont.metrics.summary()
    # the static-shape contract: slot churn never compiles a new executable
    assert m["recompiles_after_warmup"] == 0, m
    assert m["completed"] == n
    assert m["refills"] >= n - B     # every slot was refilled mid-flight
    assert m["aal"] >= 1.0
    assert 0 < m["occupancy"] <= 1.0


def test_slot_lengths_and_long_run_parking(tb):
    """Queue far more work than the pool and let it drain: slot bookkeeping
    must track the device caches exactly and never overflow the cache."""
    B = 2
    eng = _engine(tb)
    cont = ContinuousServer(eng, batch_size=B, prompt_pad=16,
                            spec=SPEC, verify_v=VERIFY_V)
    cont.warmup()
    for r in _requests(tb, 8, seed=3):
        cont.submit(r)
    done = {uid: h.request for uid, h in cont.serve().items()}
    assert len(done) == 8
    np.testing.assert_array_equal(cont._slot_len,
                                  eng.slot_lengths(cont.state))
    L = eng.cfg.max_target_len
    assert (cont._slot_len <= L).all()
    assert cont.metrics.summary()["recompiles_after_warmup"] == 0


# ------------------------------------------------ scheduler logic (no jit) --
class _FakeState:
    def __init__(self, batch_size):
        self.root = np.zeros(batch_size, np.int64)


class _FakeStepEngine:
    """Just enough engine for ContinuousServer's host-side bookkeeping."""

    class cfg:
        max_target_len = 64

    _compile_count = 0

    def init_decode_state(self, batch_size):
        return _FakeState(batch_size)

    def prefill_into_slot(self, state, slot, tokens, length):
        return state

    def mesh_info(self):
        return {"devices": 1, "shape": None}


def _server(**kw):
    return ContinuousServer(_FakeStepEngine(), batch_size=2, prompt_pad=8,
                            spec=egt_spec(2, 2), **kw)


def _occupy(srv, slot, max_new=10):
    req = Request(uid=0, prompt=np.array([1, 2]), max_new=max_new)
    req.t_submit = req.t_start = 1.0
    srv.slots[slot] = req
    srv._buffers[slot] = []
    srv._budget[slot] = max_new
    return req


def test_credit_retires_on_eos():
    srv = _server(eos_id=7)
    _occupy(srv, 0)
    srv._credit(0, np.array([1, 2, 7, 9]))
    assert srv.slots[0] is None                      # retired, slot freed
    np.testing.assert_array_equal(srv.done[0].result, [1, 2, 7])
    assert srv.metrics.completed == 1
    assert srv.metrics.tokens_out == 3               # post-EOS token dropped


def test_eos_at_root_retires_at_admission_with_one_token():
    """Bug sweep: a request whose FIRST sampled token (the prefill root,
    credited at admission) is EOS must retire immediately with exactly one
    delivered token — streamed, counted, slot freed in the same call. The
    fake engine's roots are all zeros, so eos_id=0 makes every admission
    hit this path."""
    srv = _server(eos_id=0)
    streamed = []
    req = Request(uid=3, prompt=np.array([1, 2, 3]), max_new=10,
                  stream=lambda uid, toks: streamed.extend(toks.tolist()))
    srv.submit(req)
    srv._admit()
    assert srv.slots[0] is None                 # slot freed same call
    assert 3 in srv.done
    np.testing.assert_array_equal(srv.done[3].result, [0])  # exactly the EOS
    assert srv.done[3].stats["tokens"] == 1
    assert streamed == [0]                      # delivered to the stream too
    assert srv.metrics.completed == 1
    assert srv.metrics.tokens_out == 1


def test_credit_retires_on_budget():
    srv = _server()
    _occupy(srv, 0, max_new=4)
    srv._credit(0, np.array([5, 6, 8]))
    assert srv.slots[0] is not None                  # 3/4 — still running
    srv._credit(0, np.array([5, 6, 8]))              # would exceed: clamp
    np.testing.assert_array_equal(srv.done[0].result, [5, 6, 8, 5])
    assert srv.done[0].stats["tokens"] == 4


def test_credit_ignores_idle_slot():
    srv = _server()
    srv._credit(0, np.array([5, 6]))
    assert srv.metrics.tokens_out == 0 and not srv.done


def test_credit_negative_room_drops_all_tokens():
    """Regression: with the budget exhausted (room < 0), the old negative
    slice take[:room] KEPT tokens from the front; it must drop them all and
    retire with what was already buffered."""
    srv = _server()
    _occupy(srv, 0, max_new=2)
    srv._buffers[0] = [9, 9, 9]          # buffered past the budget somehow
    srv._credit(0, np.array([5, 6, 7]))  # room = 2 - 3 = -1
    assert srv.metrics.tokens_out == 0   # nothing new credited
    np.testing.assert_array_equal(srv.done[0].result, [9, 9, 9])


def test_zero_budget_admission_retires_immediately(monkeypatch):
    """Regression: a prompt so close to the cache cap that no generation
    budget remains must be clamped to 0 (not negative) and retire at
    admission with an empty result instead of slipping tokens through
    _credit's front-slice."""
    srv = _server()
    # prompt_pad=8 fills the slot to length 8; max_target_len=64 leaves
    # plenty, so shrink the cap via the headroom arithmetic instead
    # (monkeypatch: cfg is a class attribute shared by every fake engine)
    monkeypatch.setattr(srv.engine.cfg, "max_target_len",
                        srv.prompt_pad + srv._headroom - 2)
    req = Request(uid=5, prompt=np.arange(1, srv.prompt_pad + 1), max_new=10)
    srv.submit(req)
    srv._admit()
    assert srv._budget[0] == 0           # clamped, not negative
    assert srv.slots[0] is None          # retired at admission
    assert 5 in srv.done
    assert len(srv.done[5].result) == 0
    assert srv.done[5].stats["length_capped"]
    assert srv.metrics.tokens_out == 0


# --------------------------------------------------- per-slot cache ops ----
def _hybrid_cfg():
    # layer 0 attention + layer 1 SSM: exercises k/v/pos, state/conv and
    # length leaves of the slot ops in one cache
    return ModelConfig(name="slot-test", num_layers=2, d_model=32,
                       num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                       vocab_size=17, attn_layer_period=2,
                       ssm_state_size=8, ssm_head_dim=16)


def _filled_cache(cfg, batch, fill):
    import jax.numpy as jnp
    cache = cache_lib.make_kv_cache(cfg).init(batch, 32)
    return __import__("jax").tree.map(
        lambda a: jnp.full(a.shape, fill, a.dtype), cache)


def _assert_tree_equal(a, b, msg=""):
    import jax
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb), msg)


def test_slot_update_touches_only_the_slot():
    cfg = _hybrid_cfg()
    kv = cache_lib.make_kv_cache(cfg)
    big = _filled_cache(cfg, 3, 3)
    small = _filled_cache(cfg, 1, 5)
    upd = kv.merge_slot(big, 1, small)
    _assert_tree_equal(kv.slot_view(upd, 1), small, "slot not written")
    for other in (0, 2):
        _assert_tree_equal(kv.slot_view(upd, other),
                           kv.slot_view(big, other),
                           f"slot {other} disturbed")


def test_reset_slot_clears_positions_and_state():
    cfg = _hybrid_cfg()
    kv = cache_lib.make_kv_cache(cfg)
    big = _filled_cache(cfg, 3, 3)
    rst = kv.reset_slot(big, 1)
    s1 = kv.slot_view(rst, 1)
    assert int(np.asarray(s1["length"])[0]) == 0
    blk = s1["blocks"]["layer0"]
    assert (np.asarray(blk["pos"]) == -1).all()      # stale slots invisible
    ssm = s1["blocks"]["layer1"]
    assert (np.asarray(ssm["state"]) == 0).all()
    assert (np.asarray(ssm["conv"]) == 0).all()
    _assert_tree_equal(kv.slot_view(rst, 0),
                       kv.slot_view(big, 0), "slot 0 disturbed")


# ------------------------------------- quantized (int8+scales) slot ops ----
def _quantized_filled_cache(cfg, batch, seed=0):
    """int8 cache with every attention slot committed through the real
    quantizing write path, plus non-trivial SSM/length leaves."""
    import jax
    import jax.numpy as jnp
    cache = cache_lib.make_kv_cache(cfg).init(batch, 32, kv_dtype=jnp.int8)
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    k = jax.random.normal(keys[0], (batch, 8, cfg.num_kv_heads, cfg.head_dim))
    v = jax.random.normal(keys[1], (batch, 8, cfg.num_kv_heads, cfg.head_dim))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (batch, 8)).astype(jnp.int32)

    def upd(path, leaf):
        name = getattr(path[-1], "key", str(path[-1]))
        if name in ("state", "conv"):
            return jnp.full(leaf.shape, 2.0, leaf.dtype)
        return leaf

    cache = jax.tree_util.tree_map_with_path(upd, cache)
    blk = cache["blocks"]["layer0"]
    entry = jax.tree.map(lambda a: a[0], blk)
    written = cache_lib.make_kv_cache(cfg).write_tokens(entry, k, v, pos)
    cache["blocks"]["layer0"] = jax.tree.map(lambda a: a[None], written)
    cache["length"] = jnp.full((batch,), 8, jnp.int32)
    return cache


def test_quantized_slot_update_and_slice_roundtrip_exactly():
    """slot_update / slot_slice on an int8+scales cache: payload AND scales
    move together bit-exactly, other slots untouched."""
    cfg = _hybrid_cfg()
    kv = cache_lib.make_kv_cache(cfg)
    big = _quantized_filled_cache(cfg, 3, seed=0)
    small = _quantized_filled_cache(cfg, 1, seed=1)
    upd = kv.merge_slot(big, 1, small)
    _assert_tree_equal(kv.slot_view(upd, 1), small, "slot not written")
    for other in (0, 2):
        _assert_tree_equal(kv.slot_view(upd, other),
                           kv.slot_view(big, other),
                           f"slot {other} disturbed")
    blk = upd["blocks"]["layer0"]
    assert np.asarray(blk["k"]).dtype == np.int8
    assert np.asarray(blk["k_scale"]).dtype == np.float32


def test_quantized_reset_slot_per_leaf_fills():
    """reset_slot's per-leaf fill: int8 payloads -> 0, scales -> 1.0 (the
    empty-slot neutral pair, NOT a shared zero fill), pos -> -1; the other
    slots keep their exact quantized content."""
    cfg = _hybrid_cfg()
    kv = cache_lib.make_kv_cache(cfg)
    big = _quantized_filled_cache(cfg, 3)
    rst = kv.reset_slot(big, 1)
    s1 = kv.slot_view(rst, 1)
    entry = s1["blocks"]["layer0"]
    assert (np.asarray(entry["k"]) == 0).all()
    assert (np.asarray(entry["v"]) == 0).all()
    assert (np.asarray(entry["k_scale"]) == 1.0).all()
    assert (np.asarray(entry["v_scale"]) == 1.0).all()
    assert (np.asarray(entry["pos"]) == -1).all()
    assert int(np.asarray(s1["length"])[0]) == 0
    # and the neutral pair dequantizes to exact zeros
    ek, ev = cache_lib.KVCache.entry_kv(entry)
    assert (np.asarray(ek) == 0).all() and (np.asarray(ev) == 0).all()
    _assert_tree_equal(kv.slot_view(rst, 0),
                       kv.slot_view(big, 0), "slot 0 disturbed")


def test_quantized_continuous_serving_zero_recompiles(tb):
    """The compile-stability contract survives quantization: an int8-KV
    ContinuousServer sustains >= 3x batch_size requests with mid-flight slot
    refills and never compiles after warmup (dtype changes at trace time,
    never shape changes at step time)."""
    from repro.core.engine import EngineConfig
    from repro.quant import QuantConfig
    B, n = 2, 6
    eng = SpeculativeEngine(tb.drafter, tb.d_params, tb.verifier, tb.v_params,
                            buckets=buckets_for_depths((3,), width=2,
                                                       verify_frac=0.75),
                            depth_options=(3,),
                            config=EngineConfig(
                                quant=QuantConfig.parse("int8-kv")))
    cont = ContinuousServer(eng, batch_size=B, prompt_pad=16,
                            spec=SPEC, verify_v=VERIFY_V)
    cont.warmup()
    for r in _requests(tb, n, seed=5):
        cont.submit(r)
    done = {uid: h.request for uid, h in cont.serve().items()}
    m = cont.metrics.summary()
    assert m["completed"] == n
    assert m["refills"] >= n - B
    assert m["recompiles_after_warmup"] == 0, m
    assert m["quant_mode"] == "int8-kv"
    # quantized caches really are smaller per slot
    fp_eng = _engine(tb)
    assert (m["kv_bytes_per_slot"]
            < fp_eng.cache_bytes_per_slot()["total"] / 2)
    assert all(len(done[uid].result) > 0 for uid in done)
