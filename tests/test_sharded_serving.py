"""Mesh-sharded speculative serving.

The contracts under test (ISSUE 2 acceptance criteria):
  * sharded vs unsharded `decode_step` / `prefill_into_slot` emit identical
    tokens (tensor-parallel verify must be bit-honest at the argmax level);
  * params and both KV caches are actually placed on the mesh (not silently
    replicated);
  * `ContinuousServer` keeps its zero-recompile-after-warmup guarantee
    across slot churn when the engine runs on a mesh.

These tests need more than one device. CI runs them in the
`tier1-multidevice` job with 8 emulated CPU devices
(`XLA_FLAGS=--xla_force_host_platform_device_count=8`); on a single-device
host the whole module skips.
"""
import jax
import numpy as np
import pytest

from repro.core.buckets import buckets_for_depths
from repro.core.egt import egt_spec
from repro.core.engine import EngineConfig, SpeculativeEngine
from repro.serving.continuous import ContinuousServer
from repro.serving.server import Request
from repro.serving.testbed import Testbed, TestbedSpec, build_testbed

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices; run with "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")

SPEC, VERIFY_V = egt_spec(3, 2), 5
BATCH = 4


@pytest.fixture(scope="module")
def tb() -> Testbed:
    return build_testbed(TestbedSpec(train_steps=160))


@pytest.fixture(scope="module")
def mesh():
    n = len(jax.devices())
    return jax.make_mesh((n // 2, 2), ("data", "model"))


def _engine(tb, mesh=None, **cfg_kw) -> SpeculativeEngine:
    return SpeculativeEngine(tb.drafter, tb.d_params, tb.verifier,
                             tb.v_params,
                             buckets=buckets_for_depths((3,), width=2,
                                                        verify_frac=0.75),
                             depth_options=(3,),
                             config=EngineConfig(**cfg_kw), mesh=mesh)


def _prompts(tb, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, tb.spec.vocab,
                         size=int(rng.integers(6, 14))).astype(np.int32)
            for _ in range(n)]


def _requests(tb, n, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for uid, prompt in enumerate(_prompts(tb, n, seed)):
        out.append(Request(uid=uid, prompt=prompt,
                           max_new=int(rng.integers(8, 18))))
    return out


# ---------------------------------------------------------------- placement --
def test_params_and_caches_actually_sharded(tb, mesh):
    """The mesh engine must place tensors across devices, not replicate."""
    eng = _engine(tb, mesh)
    v_leaves = jax.tree.leaves(eng.v_params)
    assert any(not x.sharding.is_fully_replicated for x in v_leaves), \
        "verifier params fully replicated under a model-parallel mesh"
    state = eng.init_decode_state(BATCH)
    c_leaves = jax.tree.leaves(state.vcache)
    assert any(not x.sharding.is_fully_replicated for x in c_leaves), \
        "verifier KV cache fully replicated under the mesh"
    n_dev = mesh.devices.size
    assert all(len(x.sharding.device_set) == n_dev for x in v_leaves), \
        "params must span every mesh device (replicated-or-sharded)"


# ----------------------------------------------------- stepwise exactness --
def test_stepwise_sharded_matches_unsharded(tb, mesh):
    """prefill_into_slot + decode_step: identical emitted tokens with and
    without the mesh, slot by slot, step by step."""
    prompts = _prompts(tb, BATCH)
    engines = [_engine(tb), _engine(tb, mesh)]
    states = [e.init_decode_state(BATCH) for e in engines]
    for slot, p in enumerate(prompts):
        toks = np.zeros(16, np.int32)
        toks[: len(p)] = p
        states = [e.prefill_into_slot(s, slot, toks, len(p))
                  for e, s in zip(engines, states)]
    roots = [np.asarray(s.root) for s in states]
    np.testing.assert_array_equal(
        roots[0], roots[1], err_msg="slot-prefill root tokens diverged")

    for step in range(6):
        results = []
        for i, e in enumerate(engines):
            states[i], res = e.decode_step(states[i], spec=SPEC,
                                           verify_v=VERIFY_V)
            results.append(res)
        np.testing.assert_array_equal(
            results[0].tokens, results[1].tokens,
            err_msg=f"sharded decode_step diverged at step {step}")
        np.testing.assert_array_equal(
            results[0].accept_len, results[1].accept_len,
            err_msg=f"accept lengths diverged at step {step}")
    np.testing.assert_array_equal(
        engines[0].slot_lengths(states[0]), engines[1].slot_lengths(states[1]))


def test_generate_sharded_matches_unsharded(tb, mesh):
    """Batched prefill + generate parity (covers the eager prefill path)."""
    rng = np.random.default_rng(1)
    B, S = BATCH, 12
    prompt = rng.integers(1, tb.spec.vocab, size=(B, S)).astype(np.int32)
    lengths = np.full((B,), S, np.int32)
    seq0, _ = _engine(tb).generate(prompt, lengths, 16,
                                   spec=SPEC, verify_v=VERIFY_V)
    seq1, _ = _engine(tb, mesh).generate(prompt, lengths, 16,
                                         spec=SPEC, verify_v=VERIFY_V)
    np.testing.assert_array_equal(seq0, seq1)


def test_staged_plans_match_fused_under_mesh(tb, mesh):
    """The staged pipelines (device accept and host accept) must commit the
    same tokens as the fused megastep when everything is sharded."""
    rng = np.random.default_rng(2)
    B, S = BATCH, 10
    prompt = rng.integers(1, tb.spec.vocab, size=(B, S)).astype(np.int32)
    lengths = np.full((B,), S, np.int32)
    ref, _ = _engine(tb, mesh, plan="fused").generate(
        prompt, lengths, 12, spec=SPEC, verify_v=VERIFY_V)
    for plan in ("staged", "staged_device"):
        seq, _ = _engine(tb, mesh, plan=plan).generate(
            prompt, lengths, 12, spec=SPEC, verify_v=VERIFY_V)
        np.testing.assert_array_equal(ref, seq,
                                      err_msg=f"plan {plan} diverged")


# ------------------------------------------------- serving under the mesh --
def test_continuous_serving_sharded_exact_with_zero_recompiles(tb, mesh):
    """Slot churn on a mesh: outputs identical to the unsharded continuous
    server, and not a single executable is built after warmup."""
    def run(mesh_arg):
        eng = _engine(tb, mesh_arg)
        srv = ContinuousServer(eng, batch_size=BATCH, prompt_pad=16,
                               spec=SPEC, verify_v=VERIFY_V)
        srv.warmup()
        for r in _requests(tb, 3 * BATCH):
            srv.submit(r)
        srv.serve()
        return srv.done, srv.metrics.summary()

    ref, _ = run(None)
    done, m = run(mesh)
    assert sorted(done) == sorted(ref)
    for uid in ref:
        np.testing.assert_array_equal(
            done[uid].result, ref[uid].result,
            err_msg=f"sharded continuous output diverged for uid {uid}")
    assert m["recompiles_after_warmup"] == 0, m
    assert m["completed"] == 3 * BATCH
    assert m["refills"] >= 2 * BATCH      # genuine slot churn
    assert m["mesh_devices"] == mesh.devices.size


def test_mesh_shape_stability_smoke(tb):
    """Every feasible data×model factorization serves with zero recompiles
    (exercises batch-divisibility fallbacks: replicated batch on 8x1 when
    B=4, replicated model dims on 1xN, etc.)."""
    n = len(jax.devices())
    shapes = {(n, 1), (1, n), (n // 2, 2)} if n % 2 == 0 else {(1, n), (n, 1)}
    for shape in sorted(shapes):
        mesh = jax.make_mesh(shape, ("data", "model"))
        eng = _engine(tb, mesh)
        srv = ContinuousServer(eng, batch_size=2, prompt_pad=16,
                               spec=SPEC, verify_v=VERIFY_V)
        srv.warmup()
        for r in _requests(tb, 4, seed=3):
            srv.submit(r)
        srv.serve()
        m = srv.metrics.summary()
        assert m["completed"] == 4, (shape, m)
        assert m["recompiles_after_warmup"] == 0, (shape, m)
