"""Fault tolerance: deterministic fault injection, typed step errors,
replica failure recovery with token-exact replay, and graceful degradation.

Recovery-policy logic (health model, evacuation + replay, retry budgets,
no-replica timeout, shed-by-priority) runs on a position-deterministic fake
engine — the token at sequence position x is always the same, so any replay
bug (double delivery, budget drift, lost tokens) breaks the digest even
host-only. The acceptance criteria (token-exact replay through a crash on
the real chunked engine, NaN detection on real verifier logits, paged-pool
exhaustion parks) run on the real testbed at the bottom of the file.
"""
import numpy as np
import pytest

from repro.core.buckets import buckets_for_depths
from repro.core.egt import egt_spec
from repro.core.engine import EngineConfig, SpeculativeEngine
from repro.core.objective import LatencyProfile
from repro.models.cache import PageState
from repro.serving import (ContinuousServer, FaultEvent, FaultPlan,
                           NoReplicaAvailable, NumericalFault, PoolExhausted,
                           RecoveryConfig, ReplicaError, Request, Router,
                           ServingError, ServingFrontend, StepTimeout,
                           drive_frontend_trace)
from repro.serving.controller import BucketController
from repro.serving.router import ACTIVE, FAILED
from repro.serving.testbed import Testbed, TestbedSpec, build_testbed

PROF = LatencyProfile.synthetic(base_verify=1.0, slope=1.0, draft_frac=0.1,
                                saturate_at=16, overhead=0.2)


# ------------------------------------------------------- typed errors ------
def test_error_hierarchy_and_fatality():
    assert issubclass(ReplicaError, ServingError)
    assert issubclass(StepTimeout, ServingError)
    assert issubclass(NumericalFault, ServingError)
    assert issubclass(PoolExhausted, ServingError)
    assert issubclass(NoReplicaAvailable, ServingError)
    assert ReplicaError("boom").fatal
    assert not ReplicaError("blip", fatal=False).fatal
    assert StepTimeout("late", timeout_s=2.0).timeout_s == 2.0


def test_pool_exhausted_distinguishes_slots_from_hoarding():
    slots = PoolExhausted(n_pages=8, pages_in_use=7, prefix_pages=1,
                          peak_pages=7)
    assert "too many slots" in str(slots)
    hoard = PoolExhausted(n_pages=8, pages_in_use=7, prefix_pages=6,
                          peak_pages=7)
    assert "prefix store hoarding" in str(hoard)
    for e in (slots, hoard):            # stats ride on the exception
        assert e.n_pages == 8 and e.pages_in_use == 7
        assert e.peak_pages == 7


def test_no_replica_available_carries_wait():
    e = NoReplicaAvailable(waited_s=3.5)
    assert e.waited_s == 3.5


# --------------------------------------------------------- fault plans -----
def test_fault_plan_seeded_is_deterministic_and_validates_kinds():
    a = FaultPlan.seeded(7, horizon_s=30.0, replicas=3)
    b = FaultPlan.seeded(7, horizon_s=30.0, replicas=3)
    assert [(e.t, e.kind, e.replica) for e in a.events] == \
           [(e.t, e.kind, e.replica) for e in b.events]
    assert all(0.0 <= e.t < 30.0 and 0 <= e.replica < 3 for e in a.events)
    with pytest.raises(ValueError):
        FaultEvent(1.0, "meteor", 0)


def test_fault_plan_pop_due_fires_each_event_once_in_time_order():
    plan = FaultPlan([FaultEvent(5.0, "crash", 0),
                      FaultEvent(2.0, "error", 0),
                      FaultEvent(3.0, "hang", 1)])
    assert plan.pop_due(0, 1.0) is None          # nothing due yet
    ev = plan.pop_due(0, 10.0)
    assert ev.kind == "error"                    # earliest due event first
    assert plan.pop_due(1, 2.5) is None          # replica 1's event not due
    assert plan.pop_due(1, 3.0).kind == "hang"
    assert plan.pop_due(0, 10.0).kind == "crash"
    assert plan.pop_due(0, 99.0) is None         # each event fires once
    assert plan.faults_injected == 3
    plan.reset()                                 # re-armed for a second drive
    assert plan.faults_injected == 0
    assert plan.pop_due(0, 10.0).kind == "error"


# ------------------------------------------------------ router lifecycle ---
class _FakeState:
    def __init__(self, batch_size):
        self.root = np.zeros(batch_size, np.int64)
        self.pos = np.zeros(batch_size, np.int64)


class _FakeResult:
    def __init__(self, tokens, accept_len, bucket):
        self.tokens = tokens
        self.accept_len = accept_len
        self.bucket = bucket
        self.iter_time = 1e-5

    def mean_accept(self, slots=None):
        a = self.accept_len if slots is None else self.accept_len[slots]
        return float(np.mean(a)) if np.size(a) else 0.0


def _V(x):
    return 7000 + int(x)


class _ReplayEngine:
    """Position-deterministic fake: the committed token at sequence
    position x is always ``_V(x)`` regardless of replica, step count, or
    history — re-prefilling prompt+delivered MUST continue the identical
    sequence, mirroring the greedy-verifier determinism the real replay
    contract rests on."""

    class cfg:
        max_target_len = 4096

    _compile_count = 0
    profile = None

    def init_decode_state(self, batch_size):
        return _FakeState(batch_size)

    def prefill_into_slot(self, state, slot, tokens, length):
        state.pos[slot] = length
        state.root[slot] = _V(length)
        return state

    def reset_state_slot(self, state, slot):
        state.pos[slot] = 0
        state.root[slot] = 0
        return state

    def decode_step(self, state, spec=None, verify_v=None):
        B = len(state.root)
        toks = np.full((B, 2), -1, np.int64)
        for i in range(B):
            state.pos[i] += 1
            toks[i, 0] = _V(state.pos[i])
        return state, _FakeResult(toks, np.ones(B, np.int64),
                                  (spec.depth, spec.width, verify_v))

    def executable_count(self):
        return 0

    def mesh_info(self):
        return {"devices": 1, "shape": None}


def _fake_server(batch=2):
    return ContinuousServer(_ReplayEngine(), batch_size=batch, prompt_pad=4,
                            spec=egt_spec(2, 2))


def _req(uid, max_new=6):
    return Request(uid=uid, prompt=np.array([1, 2, 3]), max_new=max_new)


def test_router_fail_recover_lifecycle_and_typed_no_replica():
    router = Router([_fake_server(), _fake_server()])
    router.fail(0)
    assert router.replicas[0].state == FAILED
    assert router.replicas[0].failures == 1
    assert router.metrics.fails == 1
    assert [r.idx for r in router.live()] == [1]      # out of the pool
    assert not router.replicas[0].steppable()
    router.fail(1)
    with pytest.raises(NoReplicaAvailable):
        router._best()
    router.fail(0)                     # idempotent: already FAILED
    assert router.replicas[0].failures == 1
    router.recover(0)
    assert router.replicas[0].state == ACTIVE
    assert router.replicas[0].recoveries == 1
    assert router.metrics.recoveries == 1
    rep, _ = router.submit(_req(0))
    assert rep.idx == 0


def test_submit_with_no_active_replica_parks_instead_of_raising():
    fe = ServingFrontend([_fake_server()],
                         recovery=RecoveryConfig(no_replica_timeout_s=5.0))
    fe.router.fail(0)
    h = fe.submit(_req(0))             # queue-and-wait, not a crash
    assert not h.shed and len(fe._pending) == 1


# -------------------------------------------------- controller degradation --
def test_controller_degraded_floors_at_shallowest_bucket():
    ladder = buckets_for_depths((2, 4, 8), width=2, verify_frac=0.75)
    ctrl = BucketController(ladder, profile=PROF)
    deep = ctrl.choose(n_active=1)
    assert deep.depth > 2              # idle pool prefers a deeper tree
    ctrl.degraded = True
    floor = ctrl.choose(n_active=1)
    assert floor.depth == 2            # pinned to the cheapest compiled step
    assert ctrl.summary()["degraded"] is True
    assert ctrl.last_switch["reason"] == "degraded"
    ctrl.degraded = False
    assert ctrl.choose(n_active=1).key() in {b.key() for b in ladder}


# -------------------------------------------- fake-frontend fault recovery --
def _frontend(replicas=2, batch=2, **rec):
    servers = [_fake_server(batch) for _ in range(replicas)]
    return ServingFrontend(servers, profile=PROF,
                           recovery=RecoveryConfig(**rec))


def _trace(n=6, max_new=6, deadline_s=None, start=0.0):
    rows = []
    for uid in range(n):
        extra = {} if deadline_s is None else {"deadline_s": deadline_s}
        rows.append((start + float(uid), _req(uid, max_new=max_new), extra))
    return rows


def _expected_tokens(req, max_new=6):
    # pass the ORIGINAL budget: replay decrements req.max_new in place by
    # exactly the tokens already delivered
    plen = min(len(req.prompt), 4)     # prompt_pad=4 in _fake_server
    return [_V(plen + i) for i in range(max_new)]


def test_crash_evacuates_replays_token_exact_and_recovers():
    clean = drive_frontend_trace(_frontend(), _trace(), PROF)
    plan = FaultPlan([FaultEvent(2.0, "crash", 0)])
    fe = _frontend(backoff_s=2.0)
    out = drive_frontend_trace(fe, _trace(), PROF, faults=plan)
    assert out["faults"]["injected"]["crash"] == 1
    assert out["replica_failures"] == 1
    assert out["replays"] >= 1
    assert out["completed"] == 6 and out["sheds"] == 0
    # token-exact: every request's delivered stream is byte-identical to
    # the fault-free run, with zero duplicates and zero gaps
    assert out["results_digest"] == clean["results_digest"]
    for h in fe.handles().values():
        assert h.tokens == _expected_tokens(h.request)
    # the failed replica healed: backoff elapsed, MTTR accounted
    rep = fe.router.replicas[0]
    assert rep.state == ACTIVE and rep.recoveries == 1
    assert rep.mttr_total >= 2.0


def test_faulted_drive_is_byte_deterministic():
    plan_a = FaultPlan([FaultEvent(2.0, "crash", 0),
                        FaultEvent(6.0, "error", 1)])
    a = drive_frontend_trace(_frontend(), _trace(), PROF, faults=plan_a)
    plan_a.reset()
    b = drive_frontend_trace(_frontend(), _trace(), PROF, faults=plan_a)
    assert a["results_digest"] == b["results_digest"]
    assert a["makespan_s"] == b["makespan_s"]


def test_transient_errors_retry_in_place_until_watchdog_fails_replica():
    # two transient errors: absorbed in place, replica stays ACTIVE
    plan = FaultPlan([FaultEvent(1.0, "error", 0, duration_s=0.5),
                      FaultEvent(2.0, "error", 0, duration_s=0.5)])
    fe = _frontend(watchdog=3)
    out = drive_frontend_trace(fe, _trace(), PROF, faults=plan)
    assert out["faults"]["faults_injected"] == 2
    assert out["replica_failures"] == 0
    assert out["completed"] == 6
    assert fe.router.replicas[0].faults_seen == 2
    # three consecutive transients: the watchdog declares the replica dead
    plan = FaultPlan([FaultEvent(1.0, "error", 0, duration_s=0.5)
                      for _ in range(3)])
    fe = _frontend(watchdog=3)
    out = drive_frontend_trace(fe, _trace(), PROF, faults=plan)
    assert out["replica_failures"] == 1
    assert out["completed"] == 6 and out["sheds"] == 0


def test_hang_is_charged_and_fails_the_replica_with_backoff():
    plan = FaultPlan([FaultEvent(2.0, "hang", 0, duration_s=4.0)])
    fe = _frontend(step_timeout_s=3.0, backoff_s=2.0)
    out = drive_frontend_trace(fe, _trace(), PROF, faults=plan)
    assert out["replica_failures"] == 1
    assert out["completed"] == 6
    rep = fe.router.replicas[0]
    assert rep.failures == 1 and rep.recoveries == 1
    # the hang burned the watchdog budget on the emulated clock
    assert out["busy_s"]["0"] >= 3.0


def test_backoff_doubles_across_repeated_failures():
    plan = FaultPlan([FaultEvent(1.0, "crash", 0),
                      FaultEvent(8.0, "crash", 0)])
    fe = _frontend(backoff_s=2.0, backoff_max_s=60.0)
    drive_frontend_trace(fe, _trace(n=8, max_new=8), PROF, faults=plan)
    rep = fe.router.replicas[0]
    if rep.failures == 2:              # second crash needs replica 0 rearmed
        # failure #1 backs off 2s, failure #2 backs off 4s
        assert rep.mttr_total >= 2.0 + 4.0


def test_retry_budget_exhaustion_sheds_with_typed_error():
    plan = FaultPlan([FaultEvent(2.0, "crash", 0)])
    fe = _frontend(replicas=1, retry_budget=0, backoff_s=1.0)
    out = drive_frontend_trace(fe, _trace(n=3), PROF, faults=plan)
    assert out["shed_retry"] >= 1
    assert out["completed"] + out["sheds"] == out["submitted"]
    shed = [h for h in fe.handles().values()
            if h.shed and h.shed_reason == "retry-budget"]
    assert shed and all(isinstance(h.error, ReplicaError) for h in shed)


def test_no_replica_timeout_sheds_pending_with_typed_error():
    plan = FaultPlan([FaultEvent(1.0, "crash", 0)])
    fe = _frontend(replicas=1, retry_budget=3, backoff_s=500.0,
                   no_replica_timeout_s=5.0)
    out = drive_frontend_trace(fe, _trace(n=4), PROF, faults=plan)
    assert out["shed_no_replica"] >= 1
    assert out["completed"] + out["sheds"] == out["submitted"]
    shed = [h for h in fe.handles().values()
            if h.shed and h.shed_reason == "no-replica"]
    assert shed
    for h in shed:
        assert isinstance(h.error, NoReplicaAvailable)
        assert h.error.waited_s >= 5.0


def test_overload_sheds_by_priority_not_arrival():
    from repro.serving import AdmissionConfig
    fe = ServingFrontend([_fake_server(batch=1)],
                         admission=AdmissionConfig(max_pending=1,
                                                   on_overload="shed"))
    h0 = fe.submit(_req(0))                       # into the replica
    hlow = fe.submit(_req(1), priority=0)         # parked
    hhigh = fe.submit(_req(2), priority=5)        # outranks hlow: evicts it
    assert hlow.shed and hlow.shed_reason == "overload"
    assert not hhigh.shed
    hmid = fe.submit(_req(3), priority=1)         # outranked by hhigh: shed
    assert hmid.shed and not hhigh.shed and not h0.shed
    assert fe.metrics.shed_overload == 2


def test_degradation_flag_follows_failures_and_overload():
    fe = _frontend(replicas=2)
    fe._update_degraded()
    assert fe.router.replicas[1].server.controller is None  # pinned spec
    assert not fe.router.replicas[1].server._degraded
    fe.router.fail(0)
    fe._update_degraded()
    assert fe.router.replicas[1].server._degraded
    fe.router.recover(0)
    fe._update_degraded()
    assert not fe.router.replicas[1].server._degraded


# ---------------------------------------------------- host page-pool edge --
def test_page_state_exhaustion_raises_typed_with_stats():
    ps = PageState(batch=2, pages_per_slot=4, n_pages=3, page_len=4)
    ps.ensure(0, 8)                    # both usable pages
    with pytest.raises(PoolExhausted) as ei:
        ps.ensure(1, 4)
    e = ei.value
    assert e.n_pages == 3 and e.pages_in_use == 2 and e.prefix_pages == 0
    assert "too many slots" in str(e)
    ps.release(0)                      # pages return; the pool self-heals
    assert ps.ensure(1, 4)


def test_prefix_adoption_denied_when_pool_has_no_free_pages():
    ps = PageState(batch=2, pages_per_slot=4, n_pages=4, page_len=4)
    prompt = list(range(100, 108))     # two full pages
    ps.ensure(0, 8)
    ps.store.register(0, prompt)
    assert ps.store.lookup(prompt)[0] == 2
    ps.ensure(1, 4)                    # last free page gone
    assert not ps.free
    got = ps.store.adopt(1, prompt)
    assert got == 0                    # denied, not a crash
    assert ps.store.adopt_denied == 1


# ==================================================== real-testbed tests ===
SPEC, VERIFY_V = egt_spec(3, 2), 5


@pytest.fixture(scope="module")
def tb() -> Testbed:
    return build_testbed(TestbedSpec(train_steps=160))


def _engine(tb, **cfg_kw) -> SpeculativeEngine:
    return SpeculativeEngine(tb.drafter, tb.d_params, tb.verifier,
                             tb.v_params, profile=PROF,
                             buckets=buckets_for_depths((3,), width=2,
                                                        verify_frac=0.75),
                             depth_options=(3,),
                             config=EngineConfig(**cfg_kw))


def _real_frontend(tb, replicas=2, batch=2, **rec):
    servers = [ContinuousServer(_engine(tb), batch_size=batch, prompt_pad=12,
                                spec=SPEC, verify_v=VERIFY_V,
                                prefill_chunks=(4, 8))
               for _ in range(replicas)]
    return ServingFrontend(servers, profile=PROF,
                           recovery=RecoveryConfig(**rec))


def _real_trace(tb, n=6, max_new=12, deadline_s=120.0):
    rng = np.random.default_rng(11)
    rows = []
    for uid in range(n):
        prompt = rng.integers(1, tb.spec.vocab, size=8).astype(np.int32)
        rows.append((float(uid), Request(uid=uid, prompt=prompt,
                                         max_new=max_new),
                     {"deadline_s": deadline_s}))
    return rows


def test_real_crash_and_hang_replay_token_exact_zero_recompiles(tb):
    """The tentpole acceptance criterion on the real engine: crash one
    replica and hang the other mid-trace — every completed request's
    delivered tokens must be byte-identical to the fault-free run (the
    replayed prefix re-prefills through the warm chunk lane), nothing is
    lost, the drive is deterministic, and the fail->recover cycle costs
    zero recompiles."""
    clean = drive_frontend_trace(_real_frontend(tb), _real_trace(tb), PROF)

    def plan():
        return FaultPlan([FaultEvent(3.0, "crash", 0),
                          FaultEvent(9.0, "hang", 1, duration_s=2.0)])

    fe = _real_frontend(tb, retry_budget=3, step_timeout_s=2.0,
                        backoff_s=2.0)
    out = drive_frontend_trace(fe, _real_trace(tb), PROF, faults=plan())
    assert out["faults"]["faults_injected"] == 2
    assert out["replica_failures"] >= 1 and out["replays"] >= 1
    assert out["completed"] == out["submitted"] and out["sheds"] == 0
    assert out["results_digest"] == clean["results_digest"]
    for rs in out["router"]["replicas"].values():
        assert rs["recompiles_after_warmup"] == 0
    fe2 = _real_frontend(tb, retry_budget=3, step_timeout_s=2.0,
                         backoff_s=2.0)
    out2 = drive_frontend_trace(fe2, _real_trace(tb), PROF, faults=plan())
    assert out2["results_digest"] == out["results_digest"]


def test_real_poisoned_step_raises_numerical_fault_carrying_state(tb):
    srv = ContinuousServer(_engine(tb), batch_size=2, prompt_pad=12,
                           spec=SPEC, verify_v=VERIFY_V)
    srv.submit(Request(uid=0, prompt=_real_trace(tb, n=1)[0][1].prompt,
                       max_new=8))
    srv.step()                         # admission + first megastep
    srv.engine.poison_next_step()
    with pytest.raises(NumericalFault) as ei:
        srv.step()
    assert ei.value.state is not None  # donated buffers carried out
    assert srv.metrics.numerical_faults == 1
    assert srv.state is ei.value.state  # server adopted the live state


def test_real_nonfinite_verifier_logits_detected(tb):
    """Genuine NaNs (not the poison flag): NaN out the verifier params —
    same shapes/dtypes, so no recompile — and the finite guard on the real
    logits must raise with the offending slots."""
    import jax
    import jax.numpy as jnp
    eng = _engine(tb)
    state = eng.init_decode_state(2)
    prompt = _real_trace(tb, n=1)[0][1].prompt
    toks = np.zeros(12, np.int32)
    toks[:len(prompt)] = prompt
    state = eng.prefill_into_slot(state, 0, toks, len(prompt))
    eng.v_params = jax.tree_util.tree_map(
        lambda x: jnp.full_like(x, jnp.nan) if jnp.issubdtype(
            x.dtype, jnp.floating) else x, eng.v_params)
    with pytest.raises(NumericalFault) as ei:
        eng.decode_step(state, spec=SPEC, verify_v=VERIFY_V)
    assert ei.value.slots            # names the corrupted slots


def test_real_nan_fault_recovers_token_exact_through_frontend(tb):
    clean = drive_frontend_trace(_real_frontend(tb), _real_trace(tb), PROF)
    plan = FaultPlan([FaultEvent(4.0, "nan", 0)])
    fe = _real_frontend(tb, retry_budget=3, backoff_s=2.0)
    out = drive_frontend_trace(fe, _real_trace(tb), PROF, faults=plan)
    assert out["faults"]["injected"]["nan"] == 1
    assert out["replica_failures"] == 1
    assert out["completed"] == out["submitted"] and out["sheds"] == 0
    assert out["results_digest"] == clean["results_digest"]
    for rs in out["router"]["replicas"].values():
        assert rs["recompiles_after_warmup"] == 0


def test_real_pool_exhaustion_parks_admission_then_drains(tb):
    """A paged engine whose pool cannot hold two concurrent prompts must
    park the second admission (typed, counted) and finish it once the
    first retires and releases its pages — no crash, nothing lost."""
    # 6 usable pages: one slot fits (4 prompt pages + decode growth), two
    # concurrent admissions do not — the second must hit the typed
    # allocator error at admission, where the server parks it
    eng = _engine(tb, cache_layout="paged", page_len=8, cache_pages=7)
    srv = ContinuousServer(eng, batch_size=2, prompt_pad=32,
                           spec=SPEC, verify_v=VERIFY_V)
    rng = np.random.default_rng(3)
    for uid in range(2):
        prompt = rng.integers(1, tb.spec.vocab, size=29).astype(np.int32)
        srv.submit(Request(uid=uid, prompt=prompt, max_new=2))
    done = srv.serve()
    assert sorted(done) == [0, 1]
    assert srv.metrics.pool_parks >= 1
    assert srv.metrics.summary()["recompiles_after_warmup"] == 0
