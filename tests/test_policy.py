"""Unit tests for the scheduling policy layer: LatencyProfile persistence,
offline bucket choice (choose_config / select_bucket), the occupancy-aware
step-latency model, the online AAL estimator, ladder validation, and the
adaptive controller's hysteresis."""
import numpy as np
import pytest

from repro.core.buckets import (Bucket, buckets_for_depths, ladder_headroom,
                                parse_buckets, select_bucket, validate_ladder)
from repro.core.objective import (AALEstimator, LatencyProfile, choose_config,
                                  speedup_objective, step_latency)
from repro.serving.controller import BucketController


# ------------------------------------------------------------- profile ----
def test_latency_profile_save_load_roundtrip(tmp_path):
    prof = LatencyProfile.synthetic(base_verify=2.0, slope=0.07,
                                    saturate_at=16, overhead=0.11)
    path = str(tmp_path / "prof.json")
    prof.save(path)
    back = LatencyProfile.load(path)
    assert back == prof
    for w in (1, 3, 48, 200):
        assert back.t_verify(w) == prof.t_verify(w)
        assert back.t_draft(w) == prof.t_draft(w)


def test_step_latency_batch_term_is_monotone_and_backward_compatible():
    prof = LatencyProfile.synthetic(slope=0.5, saturate_at=8)
    base = step_latency(prof, 4, 2, 8)
    # batch=1 is exactly Eq. 3 — the pre-existing objective value
    assert speedup_objective(prof, 3.0, 4, 2, 8) == pytest.approx(
        3.0 * prof.t_verify(1) / base)
    # more active sequences can only cost more per step
    assert step_latency(prof, 4, 2, 8, batch=4) >= base
    assert (step_latency(prof, 4, 2, 8, batch=8)
            >= step_latency(prof, 4, 2, 8, batch=4))


def test_occupancy_flips_the_preferred_bucket():
    """The adaptive premise: past the knee, a full pool makes the shallow
    bucket win the objective that the deep bucket wins at occupancy 1."""
    prof = LatencyProfile.synthetic(base_verify=1.0, slope=1.0,
                                    draft_frac=0.1, saturate_at=16,
                                    overhead=0.2)
    shallow, deep = Bucket(2, 2, 4), Bucket(4, 2, 7)
    aal = {shallow.key(): 2.8, deep.key(): 4.2}

    def best(batch):
        return select_bucket([shallow, deep], 1, prof, aal_estimates=aal,
                             batch=batch)

    assert best(1) == deep
    assert best(4) == shallow


# ------------------------------------------------------ bucket selection ----
def test_select_bucket_empty_candidate_fallback():
    """predicted_depth above every bucket: fall back to the full set
    instead of crashing (the deepest affordable bucket wins)."""
    buckets = buckets_for_depths((2, 4), width=2)
    prof = LatencyProfile.synthetic()
    got = select_bucket(buckets, predicted_depth=64, profile=prof)
    assert got in buckets


def test_select_bucket_tie_breaks_to_first():
    prof = LatencyProfile.synthetic()
    twin_a, twin_b = Bucket(4, 2, 8), Bucket(4, 2, 8)
    aal = {twin_a.key(): 3.0}
    got = select_bucket([twin_a, twin_b], 2, prof, aal_estimates=aal)
    assert got is twin_a


def test_select_bucket_aal_estimates_override():
    """Measured AALs beat the default prior: the prior is capped at
    predicted_depth+1 (identical for both buckets here), so the cheap
    shallow bucket wins by default — a measured deep-bucket AAL near full
    acceptance must flip the choice."""
    shallow, deep = Bucket(2, 2, 4), Bucket(8, 2, 13)
    prof = LatencyProfile.synthetic()
    assert select_bucket([shallow, deep], 2, prof) == shallow
    measured = {deep.key(): 8.5, shallow.key(): 2.1}
    assert select_bucket([shallow, deep], 2, prof,
                         aal_estimates=measured) == deep


def test_choose_config_prefers_speedup_over_aal():
    prof = LatencyProfile.synthetic(slope=0.1, saturate_at=8)
    cands = [(4, 4, v) for v in (4, 16, 256)]
    aal = {c: 1.0 + 0.4 * np.log2(c[2]) for c in cands}
    assert choose_config(prof, cands, aal, objective="aal")[2] == 256
    assert choose_config(prof, cands, aal, objective="speedup")[2] < 256


# ------------------------------------------------------------ estimator ----
def test_aal_estimator_prior_then_ema():
    est = AALEstimator(alpha=0.5)
    key = (4, 2, 7)
    assert est.estimate(key) == 5.0          # optimistic prior: depth + 1
    assert not est.observed(key)
    est.update(key, 3.0)
    assert est.estimate(key) == 3.0          # first observation replaces prior
    est.update(key, 1.0)
    assert est.estimate(key) == pytest.approx(2.0)   # EMA, alpha=0.5
    assert est.estimates([key, (2, 2, 4)]) == {key: pytest.approx(2.0),
                                               (2, 2, 4): 3.0}


# -------------------------------------------------------------- ladders ----
def test_parse_buckets_forms():
    lad = parse_buckets("2x2,4x2x6")
    assert lad == (Bucket(2, 2, 3), Bucket(4, 2, 6))
    with pytest.raises(ValueError):
        parse_buckets("4")


def test_validate_ladder_headroom_tracks_deepest():
    lad = (Bucket(2, 2, 4), Bucket(8, 2, 13))
    assert ladder_headroom(lad) == 10
    assert validate_ladder(lad, 512, prompt_pad=24) == lad
    # max_target_len leaves no room under the DEEPEST bucket -> reject,
    # even though the shallow one alone would fit
    with pytest.raises(ValueError, match="headroom"):
        validate_ladder(lad, 32, prompt_pad=24)
    validate_ladder((Bucket(2, 2, 4),), 32, prompt_pad=24)


def test_validate_ladder_rejects_bad_entries():
    with pytest.raises(ValueError):
        validate_ladder((), 512)
    with pytest.raises(ValueError):
        validate_ladder((Bucket(0, 2, 2),), 512)
    with pytest.raises(ValueError):
        validate_ladder((Bucket(2, 2, 99),), 512)    # verify > num_nodes
    with pytest.raises(ValueError):
        validate_ladder((Bucket(2, 2, 4), Bucket(2, 2, 4)), 512)


# ------------------------------------------------------------ controller ----
def _noisy_controller(**kw):
    prof = LatencyProfile.synthetic(base_verify=1.0, slope=1.0,
                                    draft_frac=0.1, saturate_at=16,
                                    overhead=0.2)
    ladder = (Bucket(2, 2, 4), Bucket(4, 2, 7))
    return BucketController(ladder, profile=prof, **kw), ladder


def test_controller_hysteresis_no_flapping_on_noisy_aal():
    """AAL observations that jitter around score parity must not produce a
    switch per step: hysteresis + dwell bound the switch count."""
    ctl, (shallow, deep) = _noisy_controller(hysteresis=0.3, min_dwell=3,
                                             aal_alpha=0.6)
    rng = np.random.default_rng(0)
    # at occupancy 2 the buckets' step costs are 1.5 vs 1.7: these AAL
    # ranges put the EXPECTED scores at parity, so the noisy per-step
    # observations flip the raw argmax constantly
    raw_flips, prev_raw = 0, None
    for _ in range(200):
        ctl.choose(n_active=2)
        ctl.observe(shallow.key(), float(rng.uniform(2.4, 3.6)), 0.01)
        ctl.observe(deep.key(), float(rng.uniform(2.7, 4.1)), 0.01)
        raw = max((shallow, deep), key=lambda x: ctl.score(x, 2)).key()
        if prev_raw is not None and raw != prev_raw:
            raw_flips += 1
        prev_raw = raw
    assert raw_flips > 10          # the input genuinely flaps...
    assert ctl.switches <= 5       # ...the controller does not (200 steps)


def test_controller_switches_on_sustained_shift():
    """Hysteresis must not mean paralysis: a sustained occupancy change and
    consistent AAL flips the bucket exactly once."""
    ctl, (shallow, deep) = _noisy_controller(hysteresis=0.1, min_dwell=2)
    for _ in range(10):
        b = ctl.choose(n_active=1)
        ctl.observe(b.key(), 4.2 if b == deep else 2.8, 0.01)
    assert ctl.current == deep
    before = ctl.switches
    for _ in range(10):
        b = ctl.choose(n_active=4)       # pool fills and stays full
        ctl.observe(b.key(), 4.2 if b == deep else 2.8, 0.01)
    assert ctl.current == shallow
    assert ctl.switches == before + 1    # one decisive switch, no flapping


def test_controller_online_mode_uses_iter_time_ema():
    """No profile: scores come from observed iteration times. A bucket that
    measures 3x slower than its AAL advantage justifies loses."""
    ladder = (Bucket(2, 2, 4), Bucket(4, 2, 7))
    ctl = BucketController(ladder, profile=None, min_dwell=0, hysteresis=0.05)
    # unvisited buckets score inf -> both get explored via seed times
    ctl.seed_iter_times({ladder[0].key(): 0.010, ladder[1].key(): 0.045})
    ctl.observe(ladder[0].key(), 2.8, 0.010)
    ctl.observe(ladder[1].key(), 4.2, 0.045)
    assert ctl.choose(n_active=1) == ladder[0]     # 280 tok/s beats 93
