"""Cross-mode consistency: for every assigned architecture (reduced config),
prefill / decode / tree_verify / commit must agree with the full-sequence
train-mode forward pass. This is the correctness foundation for lossless
speculative decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_reduced_config
from repro.models import Model
from repro.models.cache import make_kv_cache


def chain_paths(W: int) -> np.ndarray:
    pp = np.full((W, W), -1, np.int32)
    for i in range(W):
        pp[i, W - 1 - i:] = np.arange(i + 1)
    return pp


@pytest.mark.parametrize("arch", ASSIGNED)
def test_modes_consistent(arch):
    cfg = get_reduced_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S, Sbuf = 2, 16, 24
    tokens = jnp.zeros((B, Sbuf), jnp.int32).at[:, :S].set(
        jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size))
    lengths = jnp.array([16, 12])
    enc = None
    if cfg.is_encoder_decoder:
        enc = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder_seq_len, cfg.d_model)) * 0.02

    def ref_logits_for(toks, lens):
        # moe_dropless: the reference must route every token exactly, like
        # the inference paths do — capacity drops are a train-only concession
        h, _ = m.hidden_train(
            params, toks, seq_valid=jnp.arange(Sbuf)[None] < lens[:, None],
            enc_feats=enc, moe_dropless=True)
        return m.logits(params, h)

    ref = ref_logits_for(tokens, lengths)
    cache = make_kv_cache(cfg).init(B, 64)
    pl_logits, cache, _ = m.prefill(params, tokens, lengths, cache, enc_feats=enc)
    assert not bool(jnp.any(jnp.isnan(pl_logits)))
    for b in range(B):
        np.testing.assert_allclose(np.array(pl_logits[b]),
                                   np.array(ref[b, lengths[b] - 1]),
                                   rtol=3e-4, atol=3e-4)

    nxt = jnp.argmax(pl_logits, -1)
    dec_logits, cache2, _ = m.decode(params, nxt, cache)
    toks2 = tokens.at[jnp.arange(B), lengths].set(nxt)
    ref2 = ref_logits_for(toks2, lengths + 1)
    for b in range(B):
        np.testing.assert_allclose(np.array(dec_logits[b]),
                                   np.array(ref2[b, lengths[b]]),
                                   rtol=5e-4, atol=5e-4)

    # a linear 3-node chain verified as a tree == 3 sequential decodes
    W = 3
    tree_tokens = jax.random.randint(jax.random.PRNGKey(3), (B, W), 0,
                                     cfg.vocab_size)
    depths = jnp.broadcast_to(jnp.arange(W)[None], (B, W))
    mask = jnp.tril(jnp.ones((W, W), bool))[None].repeat(B, 0)
    paths = jnp.broadcast_to(jnp.array(chain_paths(W))[None], (B, W, W))
    tv_logits, scratch, _ = m.tree_verify(params, tree_tokens, depths, mask,
                                          cache2, tree_paths=paths)
    c = cache2
    for i in range(W):
        li, c, _ = m.decode(params, tree_tokens[:, i], c)
        np.testing.assert_allclose(np.array(tv_logits[:, i]), np.array(li),
                                   rtol=1e-3, atol=1e-3)

    # committing the whole chain must leave the cache equivalent to the
    # sequential decodes
    node_idx = jnp.broadcast_to(jnp.arange(W)[None], (B, W))
    c_commit = m.commit(cache2, scratch, node_idx, jnp.full((B,), W, jnp.int32))
    after_tok = jnp.argmax(tv_logits[:, -1], -1)
    d1, _, _ = m.decode(params, after_tok, c_commit)
    d2, _, _ = m.decode(params, after_tok, c)
    np.testing.assert_allclose(np.array(d1), np.array(d2), rtol=1e-3, atol=1e-3)
