"""The bench-regression gate must fail loudly on doctored artifacts — a
gate that passes vacuously (missing keys, empty baseline, nonzero recompile
counters) is worse than no gate."""
import copy
import json
import subprocess
import sys
from pathlib import Path

import pytest

from benchmarks.check_regression import (GATE_RTOL, compare, extract_baseline,
                                         lookup, main)

GOOD_CURRENT = {
    "servers": {"rate_4hz": {"continuous": {"throughput_tok_s": 999.0,
                                            "recompiles_after_warmup": 0}}},
    "adaptive_sweep": {
        "adaptive": {"throughput_tok_s": 10.0, "aal": 3.5,
                     "recompiles_after_warmup": 0},
        "adaptive_over_best_pinned": 1.05,
    },
    "quant_sweep": {
        "none": {"aal": 3.5, "recompiles_after_warmup": 0},
        "int8-kv": {"aal": 3.5, "recompiles_after_warmup": 0},
        "slots_ratio": 3.4,
    },
    "kernel_traffic": {
        "gqa_bytes_ratio": 3.8,
        "len_scaling_ratio": 3.4,
        "kernel_path": {"verify_path": "fused",
                        "recompiles_after_warmup": 0},
    },
    "telemetry": {
        "token_exact": 1.0,
        "trace_valid": 1.0,
        "emulated_snapshot_deterministic": 1.0,
        "overhead_frac": 0.004,
        "on": {"recompiles_after_warmup": 0},
        "off": {"recompiles_after_warmup": 0},
    },
    "frontend_sweep": {
        "deterministic": 1.0,
        "router_over_single": 1.8,
        "single": {"goodput_under_slo": 0.55,
                   "recompiles_after_warmup": 0},
        "router": {
            "goodput_under_slo": 1.0,
            "router": {"replicas": {
                "0": {"recompiles_after_warmup": 0},
                "1": {"recompiles_after_warmup": 0},
            }},
        },
    },
    "chunked_prefill_sweep": {
        "token_exact": 1.0,
        "p95_speedup": 6.3,
        "p99_speedup": 6.2,
        "throughput_ratio": 5.2,
        "monolithic": {"throughput_tok_s": 0.3,
                       "recompiles_after_warmup": 0},
        "chunked": {"throughput_tok_s": 1.5,
                    "recompiles_after_warmup": 0},
        "exactness_check": {
            "monolithic": {"recompiles_after_warmup": 0},
            "chunked": {"recompiles_after_warmup": 0},
        },
    },
    "paged_sweep": {
        "token_exact": 1.0,
        "prefix_hit_rate": 0.66,
        "slots_at_fixed_hbm_ratio": 16.0,
        "contiguous": {"recompiles_after_warmup": 0},
        "paged": {"recompiles_after_warmup": 0},
    },
    "fault_sweep": {
        "replay_token_exact": 1.0,
        "deterministic": 1.0,
        "lost_requests": 0,
        "recompiles_after_recovery": 0,
        "goodput_under_faults": 0.8,
        "clean_goodput": 0.95,
        "faults_injected": 15,
        "replays": 6,
        "seeds": {"101": {"replicas": {
            "0": {"faults_seen": 3, "replays": 2,
                  "recompiles_after_warmup": 0},
            "1": {"faults_seen": 2, "replays": 1,
                  "recompiles_after_warmup": 0},
        }}},
    },
}


def _baseline():
    return extract_baseline(GOOD_CURRENT)


def test_gate_passes_on_identical_run():
    assert compare(_baseline(), GOOD_CURRENT) == []


def test_gate_passes_within_threshold():
    cur = copy.deepcopy(GOOD_CURRENT)
    cur["adaptive_sweep"]["adaptive"]["throughput_tok_s"] *= 0.95  # -5%
    assert compare(_baseline(), cur) == []


def test_gate_fails_on_throughput_regression():
    cur = copy.deepcopy(GOOD_CURRENT)
    cur["adaptive_sweep"]["adaptive"]["throughput_tok_s"] *= 0.8  # -20%
    fails = compare(_baseline(), cur)
    assert len(fails) == 1
    assert "throughput_tok_s" in fails[0]


def test_gate_fails_on_aal_regression():
    cur = copy.deepcopy(GOOD_CURRENT)
    cur["quant_sweep"]["int8-kv"]["aal"] = 2.0  # way below 3.5
    fails = compare(_baseline(), cur)
    assert any("quant_sweep.int8-kv.aal" in f for f in fails)


def test_gate_fails_on_slots_ratio_regression():
    cur = copy.deepcopy(GOOD_CURRENT)
    cur["quant_sweep"]["slots_ratio"] = 1.2
    assert any("slots_ratio" in f for f in compare(_baseline(), cur))


def test_gate_fails_on_kernel_traffic_regression():
    """Reintroducing repeat_kv (gqa ratio -> ~1) or dropping the kv-block
    early-out (length scaling -> 1) must trip the gate."""
    cur = copy.deepcopy(GOOD_CURRENT)
    cur["kernel_traffic"]["gqa_bytes_ratio"] = 1.0
    assert any("gqa_bytes_ratio" in f for f in compare(_baseline(), cur))
    cur = copy.deepcopy(GOOD_CURRENT)
    cur["kernel_traffic"]["len_scaling_ratio"] = 1.0
    assert any("len_scaling_ratio" in f for f in compare(_baseline(), cur))
    cur = copy.deepcopy(GOOD_CURRENT)
    cur["kernel_traffic"]["kernel_path"]["recompiles_after_warmup"] = 1
    assert any("kernel_path" in f and "recompiles" in f
               for f in compare(_baseline(), cur))


def test_gate_fails_on_telemetry_hard_bounds():
    """Hard bounds are absolute: token-exactness/validity/determinism must
    be exactly 1.0 and overhead must stay under 2% — regardless of what the
    baseline says."""
    for key, bad in (("token_exact", 0.0), ("trace_valid", 0.0),
                     ("emulated_snapshot_deterministic", 0.0),
                     ("overhead_frac", 0.05)):
        cur = copy.deepcopy(GOOD_CURRENT)
        cur["telemetry"][key] = bad
        fails = compare(_baseline(), cur)
        assert any(key in f and "hard bound" in f for f in fails), (key, fails)


def test_gate_fails_on_frontend_hard_bounds():
    """The router must strictly beat the single-engine baseline and the
    emulated drive must be byte-deterministic — both absolute bounds, and
    ``>`` means equality fails too."""
    for key, bad in (("router_over_single", 1.0),   # == 1 is NOT > 1
                     ("router_over_single", 0.8),
                     ("deterministic", 0.0)):
        cur = copy.deepcopy(GOOD_CURRENT)
        cur["frontend_sweep"][key] = bad
        fails = compare(_baseline(), cur)
        assert any(key in f and "hard bound" in f for f in fails), (key, fails)


def test_gate_fails_on_chunked_prefill_hard_bounds():
    """Chunked prefill's absolute contracts: greedy must stay token-exact,
    and p95/throughput must strictly beat monolithic — landing AT 1.0
    (or within float noise of it) is a loss, not a win."""
    for key, bad in (("token_exact", 0.0),
                     ("p95_speedup", 1.0),       # == 1 is NOT > 1
                     ("p95_speedup", 0.7),
                     ("throughput_ratio", 1.0)):
        cur = copy.deepcopy(GOOD_CURRENT)
        cur["chunked_prefill_sweep"][key] = bad
        fails = compare(_baseline(), cur)
        assert any(key in f and "hard bound" in f for f in fails), (key, fails)


def test_gate_fails_on_paged_cache_hard_bounds():
    """The paged cache's absolute contracts: greedy decode token-exact vs
    the contiguous layout, the prefix store must actually hit, and the
    high-water HBM ratio must clear 1.5x — landing AT a bound is a loss."""
    for key, bad in (("token_exact", 0.0),
                     ("prefix_hit_rate", 0.0),            # == 0 is NOT > 0
                     ("slots_at_fixed_hbm_ratio", 1.5),   # == 1.5 fails too
                     ("slots_at_fixed_hbm_ratio", 1.2)):
        cur = copy.deepcopy(GOOD_CURRENT)
        cur["paged_sweep"][key] = bad
        fails = compare(_baseline(), cur)
        assert any(key in f and "hard bound" in f for f in fails), (key, fails)


def test_gate_fails_on_fault_sweep_hard_bounds():
    """The chaos gate's absolute contracts: token-exact replay, byte
    determinism, zero lost requests, zero recompiles through recovery."""
    for key, bad in (("replay_token_exact", 0.0),
                     ("deterministic", 0.0),
                     ("lost_requests", 1),
                     ("recompiles_after_recovery", 2)):
        cur = copy.deepcopy(GOOD_CURRENT)
        cur["fault_sweep"][key] = bad
        fails = compare(_baseline(), cur)
        assert any(key in f and "hard bound" in f for f in fails), (key, fails)


def test_gate_fails_when_fault_counters_unmeasured():
    """fault_sweep present but no faults_seen/replays counters anywhere
    means replica fault accounting went unmeasured — fail, not vacuous."""
    cur = copy.deepcopy(GOOD_CURRENT)
    cur["fault_sweep"] = json.loads(
        json.dumps(cur["fault_sweep"])
        .replace("faults_seen", "faults_gone")
        .replace("replays", "replays_gone"))
    fails = compare(_baseline(), cur)
    assert any("faults_seen" in f and "unmeasured" in f for f in fails)
    assert any("'replays'" in f and "unmeasured" in f for f in fails)


def test_gate_fails_on_silently_swallowed_faults():
    """A schedule that injected faults while every replica counter stayed
    0 means the injection missed the serving path entirely."""
    cur = copy.deepcopy(GOOD_CURRENT)
    fs = cur["fault_sweep"]
    fs["replays"] = 0
    for rep in fs["seeds"]["101"]["replicas"].values():
        rep["faults_seen"] = 0
        rep["replays"] = 0
    fails = compare(_baseline(), cur)
    assert any("silently missed" in f for f in fails)
    # ...but a schedule that injected nothing is allowed quiet counters
    cur2 = copy.deepcopy(cur)
    cur2["fault_sweep"]["faults_injected"] = 0
    assert not any("silently missed" in f for f in compare(_baseline(), cur2))


def test_gate_fails_on_goodput_under_faults_regression():
    cur = copy.deepcopy(GOOD_CURRENT)
    cur["fault_sweep"]["goodput_under_faults"] = 0.5   # -37% vs baseline
    assert any("goodput_under_faults" in f for f in compare(_baseline(), cur))


def test_gate_fails_on_chunked_p95_regression():
    cur = copy.deepcopy(GOOD_CURRENT)
    cur["chunked_prefill_sweep"]["p95_speedup"] = 4.0   # -36% vs baseline
    assert any("p95_speedup" in f for f in compare(_baseline(), cur))


def test_strict_op_tolerance_semantics():
    """The defined float semantics of the hard-bound ops (GATE_RTOL band):

      * "==" passes within the band — a token_exact of 1.0 reached through
        float accumulation must not flap;
      * ">" / "<" fail AT the bound and anywhere inside the band around it
        (a margin of 1 + 1e-16 is rounding noise posing as a win), and pass
        only with a real margin beyond the band.
    """
    def with_margin(m):
        cur = copy.deepcopy(GOOD_CURRENT)
        cur["frontend_sweep"]["router_over_single"] = m
        return compare(_baseline(), cur)

    # inside the tolerance band: all fail deterministically
    for val in (1.0, 1.0 + 1e-16, 1.0 - 1e-16, 1.0 + GATE_RTOL / 2):
        assert any("router_over_single" in f and "hard bound" in f
                   for f in with_margin(val)), val
    # real margin: passes (this is also the baseline's -10% window)
    assert with_margin(1.7) == []

    # "==" tolerates accumulated float noise but not real deviations
    for val, ok in ((1.0, True), (1.0 - 1e-12, True), (0.98, False)):
        cur = copy.deepcopy(GOOD_CURRENT)
        cur["telemetry"]["token_exact"] = val
        fails = [f for f in compare(_baseline(), cur) if "token_exact" in f]
        assert (fails == []) is ok, (val, fails)

    # "<" fails at the bound, passes strictly below the band
    for val, ok in ((0.02, False), (0.02 - 1e-15, False), (0.004, True)):
        cur = copy.deepcopy(GOOD_CURRENT)
        cur["telemetry"]["overhead_frac"] = val
        fails = [f for f in compare(_baseline(), cur)
                 if "overhead_frac" in f]
        assert (fails == []) is ok, (val, fails)


def test_gate_fails_on_replica_recompiles():
    """The walked recompile check reaches the router's per-replica
    counters — a single recompiling replica trips the gate."""
    cur = copy.deepcopy(GOOD_CURRENT)
    cur["frontend_sweep"]["router"]["router"]["replicas"]["1"][
        "recompiles_after_warmup"] = 1
    assert any("recompiles" in f for f in compare(_baseline(), cur))


def test_gate_fails_when_telemetry_section_missing():
    """A doctored artifact with the whole telemetry sweep gone must fail
    loudly, not pass vacuously."""
    cur = copy.deepcopy(GOOD_CURRENT)
    del cur["telemetry"]
    fails = compare(_baseline(), cur)
    assert any("telemetry" in f and "unmeasured" in f for f in fails)


def test_gate_fails_on_missing_metric_not_vacuously():
    cur = copy.deepcopy(GOOD_CURRENT)
    del cur["quant_sweep"]  # doctored artifact: the sweep silently vanished
    fails = compare(_baseline(), cur)
    assert any("missing" in f for f in fails)


def test_gate_fails_on_empty_baseline():
    assert compare({}, GOOD_CURRENT) != []
    assert compare({"metrics": {}}, GOOD_CURRENT) != []


def test_gate_fails_on_nonzero_recompiles_anywhere():
    cur = copy.deepcopy(GOOD_CURRENT)
    cur["servers"]["rate_4hz"]["continuous"]["recompiles_after_warmup"] = 3
    fails = compare(_baseline(), cur)
    assert any("recompiles" in f for f in fails)


def test_gate_fails_when_recompiles_unmeasured():
    cur = {"adaptive_sweep": GOOD_CURRENT["adaptive_sweep"],
           "quant_sweep": GOOD_CURRENT["quant_sweep"]}
    cur = json.loads(json.dumps(cur).replace("recompiles_after_warmup",
                                             "recompiles_gone"))
    fails = compare(_baseline(), cur)
    assert any("unmeasured" in f for f in fails)


def test_lookup_raises_on_missing_path():
    with pytest.raises(KeyError):
        lookup(GOOD_CURRENT, "quant_sweep.nope.aal")


def test_main_exit_codes(tmp_path: Path):
    base_p = tmp_path / "baseline.json"
    cur_p = tmp_path / "current.json"
    cur_p.write_text(json.dumps(GOOD_CURRENT))
    # --write-baseline then check: passes
    assert main(["--write-baseline", "--current", str(cur_p),
                 "--baseline", str(base_p)]) == 0
    assert main(["--current", str(cur_p), "--baseline", str(base_p)]) == 0
    # doctored current: fails with exit 1
    doctored = copy.deepcopy(GOOD_CURRENT)
    doctored["adaptive_sweep"]["adaptive"]["aal"] = 0.1
    cur_p.write_text(json.dumps(doctored))
    assert main(["--current", str(cur_p), "--baseline", str(base_p)]) == 1


def test_cli_process_fails_loudly_on_doctored_json(tmp_path: Path):
    """The exact CI invocation, as a subprocess, against a doctored
    artifact: nonzero exit AND a human-readable reason on stderr."""
    repo = Path(__file__).resolve().parent.parent
    base_p = tmp_path / "baseline.json"
    cur_p = tmp_path / "current.json"
    base_p.write_text(json.dumps(extract_baseline(GOOD_CURRENT)))
    doctored = copy.deepcopy(GOOD_CURRENT)
    doctored["quant_sweep"]["slots_ratio"] = 0.9
    doctored["quant_sweep"]["int8-kv"]["recompiles_after_warmup"] = 2
    cur_p.write_text(json.dumps(doctored))
    proc = subprocess.run(
        [sys.executable, str(repo / "benchmarks" / "check_regression.py"),
         "--baseline", str(base_p), "--current", str(cur_p)],
        capture_output=True, text=True, cwd=repo)
    assert proc.returncode == 1
    assert "BENCH REGRESSION GATE FAILED" in proc.stderr
    assert "slots_ratio" in proc.stderr
    assert "recompiles" in proc.stderr
