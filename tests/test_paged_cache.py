"""Paged KV cache: token-exactness vs the contiguous layout (fused and XLA
verify, fp32 and int8-kv), copy-on-write prefix sharing with mid-page
divergence, serving-level exactness under slot churn and chunked prefill,
the zero-recompile contract across page churn and bucket switches, the
page-granular HBM repricing, and the host-side PageState/PrefixStore
bookkeeping invariants.
"""
import numpy as np
import pytest

from repro.core.buckets import buckets_for_depths, parse_buckets
from repro.core.egt import egt_spec
from repro.core.engine import EngineConfig, SpeculativeEngine
from repro.models.cache import PageState, TRASH_PAGE
from repro.quant import QuantConfig
from repro.serving.continuous import ContinuousServer, slots_at_budget
from repro.serving.controller import BucketController
from repro.serving.server import Request
from repro.serving.testbed import Testbed, TestbedSpec, build_testbed

SPEC, VERIFY_V = egt_spec(3, 2), 5
PAGE_LEN = 8


@pytest.fixture(scope="module")
def tb() -> Testbed:
    return build_testbed(TestbedSpec(train_steps=160))


def _engine(tb, depths=(3,), **cfg_kw) -> SpeculativeEngine:
    return SpeculativeEngine(tb.drafter, tb.d_params, tb.verifier,
                             tb.v_params,
                             buckets=buckets_for_depths(depths, width=2,
                                                        verify_frac=0.75),
                             depth_options=depths,
                             config=EngineConfig(**cfg_kw))


def _paged_kw(**extra):
    return dict(cache_layout="paged", page_len=PAGE_LEN, **extra)


def _prompt(tb, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, tb.spec.vocab, size=n).astype(np.int32)


def _pad(prompt, width=16):
    out = np.zeros(width, np.int32)
    out[:len(prompt)] = prompt
    return out


def _decode_tokens(eng, state, slots, steps=3):
    out = {s: [] for s in slots}
    for _ in range(steps):
        state, res = eng.decode_step(state, spec=SPEC, verify_v=VERIFY_V)
        for s in slots:
            t = res.tokens[s]
            out[s].extend(t[t >= 0].tolist())
    return state, out


# ------------------------------------------- layout-exactness (engine) ----
@pytest.mark.parametrize("kernel", ["fused", "xla"])
@pytest.mark.parametrize("quant", ["none", "int8-kv"])
def test_paged_greedy_token_exact(tb, kernel, quant):
    """Greedy decode on the paged layout must match the contiguous layout
    token-for-token — the pool + page-table indirection is pure storage,
    on both verify hot paths and both KV dtypes."""
    kw = dict(verify_kernel=kernel, quant=QuantConfig.parse(quant))
    prompt = _prompt(tb, 13, seed=0)

    eng_c = _engine(tb, **kw)
    st_c = eng_c.init_decode_state(2)
    st_c = eng_c.prefill_into_slot(st_c, 1, _pad(prompt), 13)
    _, ref = _decode_tokens(eng_c, st_c, [1])

    eng_p = _engine(tb, **_paged_kw(**kw))
    assert eng_p.paged
    st_p = eng_p.init_decode_state(2)
    st_p = eng_p.prefill_into_slot(st_p, 1, _pad(prompt), 13)
    _, got = _decode_tokens(eng_p, st_p, [1])
    assert got == ref, f"paged diverged under {kernel}/{quant}"


def test_paged_slot_churn_and_chunked_prefill_exact(tb):
    """Reset a slot, re-prefill it through fixed-width chunks with garbage
    megasteps interleaved (the serving regime): the recycled pages must be
    clean and the continuation identical to a contiguous engine doing the
    same dance."""
    p0, p1 = _prompt(tb, 13, seed=1), _prompt(tb, 11, seed=2)

    def dance(eng):
        st = eng.init_decode_state(2)
        st = eng.prefill_into_slot(st, 0, _pad(p0), 13)
        st, _ = _decode_tokens(eng, st, [0], steps=2)
        st = eng.reset_state_slot(st, 0)       # churn: pages recycle
        pos, C = 0, 4
        while pos < len(p1):                   # chunked re-prefill
            valid = min(C, len(p1) - pos)
            chunk = np.zeros(C, np.int32)
            chunk[:valid] = p1[pos:pos + valid]
            st = eng.prefill_chunk_into_slot(st, 0, chunk, pos, valid,
                                             pos + valid >= len(p1))
            pos += valid
            if pos < len(p1):                  # garbage megastep between
                st, _ = _decode_tokens(eng, st, [], steps=1)
        _, toks = _decode_tokens(eng, st, [0])
        return toks[0]

    assert dance(_engine(tb, **_paged_kw())) == dance(_engine(tb))


# ------------------------------------------------ copy-on-write sharing ---
def test_cow_shared_page_mid_page_divergence_exact(tb):
    """Two prompts share their first page (8 tokens) then diverge inside
    the second page. The second admission adopts the shared page (skipping
    its prefill); its writes past the divergence must land in private
    pages — both slots' decodes match their contiguous references."""
    shared = _prompt(tb, 10, seed=3)
    a = np.concatenate([shared, _prompt(tb, 3, seed=4)])   # 13 tokens
    b = np.concatenate([shared, _prompt(tb, 3, seed=5)])   # same first 10

    eng_c = _engine(tb)
    st_c = eng_c.init_decode_state(2)
    st_c = eng_c.prefill_into_slot(st_c, 0, _pad(a), 13)
    st_c = eng_c.prefill_into_slot(st_c, 1, _pad(b), 13)
    _, ref = _decode_tokens(eng_c, st_c, [0, 1])

    eng_p = _engine(tb, **_paged_kw())
    st_p = eng_p.init_decode_state(2)
    st_p = eng_p.prefill_into_slot(st_p, 0, _pad(a), 13)
    st_p = eng_p.prefill_into_slot(st_p, 1, _pad(b), 13)
    ps = st_p.pages
    # slot 1 adopted slot 0's first page; the divergent page stays private
    assert ps.store.hits == 1 and ps.store.hit_tokens == PAGE_LEN
    assert ps.table[0, 0] == ps.table[1, 0] != TRASH_PAGE
    assert ps.table[0, 1] != ps.table[1, 1]
    assert ps.refcount[ps.table[0, 0]] >= 2
    _, got = _decode_tokens(eng_p, st_p, [0, 1])
    assert got[0] == ref[0], "sharer's writes corrupted the shared page"
    assert got[1] == ref[1], "adopted prefix decoded differently"


# ----------------------------------------------- serving-level exactness --
def _shared_prefix_requests(tb, n, prefix_pages=2):
    rng = np.random.default_rng(6)
    prefix = rng.integers(1, tb.spec.vocab,
                          size=prefix_pages * PAGE_LEN).astype(np.int32)
    return [Request(uid=uid,
                    prompt=np.concatenate(
                        [prefix, rng.integers(1, tb.spec.vocab,
                                              size=4 + uid % 3)
                         .astype(np.int32)]),
                    max_new=12)
            for uid in range(n)]


def _serve(tb, chunks=None, n=6, **cfg_kw):
    eng = _engine(tb, **cfg_kw)
    srv = ContinuousServer(eng, batch_size=2, prompt_pad=24, spec=SPEC,
                           verify_v=VERIFY_V, prefill_chunks=chunks)
    srv.warmup()
    for r in _shared_prefix_requests(tb, n):
        srv.submit(r)
    srv.serve()
    return ({u: srv.done[u].result.tolist() for u in srv.done},
            srv.metrics.summary())


@pytest.mark.parametrize("chunks", [None, (4, 8)],
                         ids=["monolithic", "chunked"])
def test_paged_serving_shared_prefix_token_exact(tb, chunks):
    """Continuous serving over shared-prefix traffic (3x slot churn):
    outputs identical to the contiguous server, prefix pages actually hit,
    and not one executable is built after warmup despite page churn."""
    ref, _ = _serve(tb, chunks=chunks)
    got, m = _serve(tb, chunks=chunks, **_paged_kw())
    assert got == ref
    assert m["completed"] == 6 and m["refills"] >= 4
    assert m["prefix_hits"] > 0 and m["prefix_hit_tokens"] > 0
    assert 0.0 < m["prefix_hit_rate"] < 1.0
    assert m["peak_pages_in_use"] > 0
    assert m["recompiles_after_warmup"] == 0, m


def test_paged_adaptive_bucket_switches_zero_recompiles(tb):
    """Bucket switches on a paged engine replay warmup-compiled megasteps
    — page churn, chunked prefill and ladder switching together leave the
    compile counter untouched."""
    ladder = parse_buckets("2x2x4,4x2x7")
    eng = _engine(tb, depths=(2, 4), **_paged_kw())
    srv = ContinuousServer(eng, batch_size=2, prompt_pad=24, buckets=ladder,
                           controller=BucketController(ladder,
                                                       profile=eng.profile),
                           prefill_chunks=(4, 8))
    srv.warmup()
    for r in _shared_prefix_requests(tb, 6):
        srv.submit(r)
    srv.serve()
    m = srv.metrics.summary()
    assert m["completed"] == 6
    assert m["recompiles_after_warmup"] == 0, m


# --------------------------------------------------- capacity repricing ---
def test_paged_repricing_and_slots_at_budget(tb):
    """A paged slot is priced by OCCUPIED pages, not capacity: at low live
    length the paged layout fits strictly more slots into the same HBM
    budget than the contiguous layout (> 1.5x here), and the repricing is
    monotone in live_tokens up to the contiguous full-capacity price."""
    eng_c = _engine(tb)
    eng_p = _engine(tb, **_paged_kw())
    full_c = eng_c.cache_bytes_per_slot()["total"]
    lo_p = eng_p.cache_bytes_per_slot(live_tokens=PAGE_LEN)["total"]
    hi_p = eng_p.cache_bytes_per_slot(live_tokens=2 * PAGE_LEN)["total"]
    assert lo_p < hi_p <= eng_p.cache_bytes_per_slot()["total"]
    budget = 64 * full_c
    assert slots_at_budget(eng_c, budget) == 64
    ratio = slots_at_budget(eng_p, budget, live_tokens=PAGE_LEN) / 64
    assert ratio > 1.5, f"paged capacity win only {ratio:.2f}x"


# --------------------------------------------- host-side page accounting --
def test_page_state_and_prefix_store_invariants():
    """Pure-host unit test of the allocator + store: adoption is capped
    below the full prompt, the store's own references keep shared pages
    alive across slot release, and eviction frees only refcount-0 pages."""
    ps = PageState(batch=2, pages_per_slot=4, n_pages=10, page_len=4)
    toks = list(range(100, 116))                   # 16 tokens = 4 full pages
    assert ps.store.adopt(0, toks) == 0            # empty store: no hit
    ps.ensure(0, 16)
    assert ps.mapped[0] == 4 and ps.pages_in_use == 4
    ps.live[0] = True
    ps.host_len[0] = 16
    ps.store.register(0, toks)

    # full-prompt hit is capped: 3 of 4 pages adopt, the last re-prefills
    assert ps.store.adopt(1, toks) == 12
    assert ps.mapped[1] == 3
    assert (ps.table[0, :3] == ps.table[1, :3]).all()
    shared = int(ps.table[0, 0])
    assert ps.refcount[shared] == 3                # slot0 + store + slot1

    ps.release(0)                                  # store refs keep pages
    assert ps.refcount[shared] == 2
    assert not ps.pending_clear                    # nothing actually freed
    assert (ps.table[0] == TRASH_PAGE).all() and ps.mapped[0] == 0

    freed = ps.store.evict(10)                     # drop the whole store
    # slot 1 still maps 3 pages; only the 4th (unmapped) page frees now
    assert freed == 1 and len(ps.pending_clear) == 1
    assert ps.refcount[shared] == 1                # slot1's mapping remains
    ps.release(1)
    assert ps.pages_in_use == 0
    assert sorted(ps.pending_clear) == sorted(set(ps.pending_clear))

    # a fresh adopt after total eviction sees nothing
    assert ps.store.adopt(0, toks) == 0
    assert ps.store.hit_rate == pytest.approx(12 / 48)
