"""§Perf variants must preserve model semantics: grouped-GQA attention and
batch-local MoE dispatch are pure layout/locality changes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import Model
from repro.models import moe as moe_mod
from repro.models.cache import make_kv_cache
from repro.models.params import init_params


@pytest.mark.parametrize("arch", ["yi-6b", "nemotron-4-15b", "granite-20b"])
def test_gqa_grouped_matches_baseline(arch):
    cfg0 = get_reduced_config(arch)
    cfg1 = cfg0.replace(gqa_grouped=True)
    m0, m1 = Model(cfg0), Model(cfg1)
    params = m0.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg0.vocab_size)
    h0, _ = m0.hidden_train(params, toks)
    h1, _ = m1.hidden_train(params, toks)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1),
                               rtol=3e-4, atol=3e-4)
    lengths = jnp.full((B,), S, jnp.int32)
    c0 = make_kv_cache(cfg0).init(B, 64)
    c1 = make_kv_cache(cfg1).init(B, 64)
    l0, c0, _ = m0.prefill(params, toks, lengths, c0)
    l1, c1, _ = m1.prefill(params, toks, lengths, c1)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=5e-4, atol=5e-4)
    nxt = jnp.argmax(l0, -1)
    d0, _, _ = m0.decode(params, nxt, c0)
    d1, _, _ = m1.decode(params, nxt, c1)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                               rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "granite-moe-3b-a800m"])
def test_moe_batch_dispatch_matches_when_no_drops(arch):
    """With ample capacity the batch-local dispatch is exactly the flat
    dispatch (drops are the only semantic difference)."""
    cfg0 = get_reduced_config(arch).replace(capacity_factor=8.0)
    cfg1 = cfg0.replace(moe_batch_dispatch=True)
    p = init_params(moe_mod.moe_defs(cfg0), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg0.d_model)) * 0.5
    y0, a0 = moe_mod.apply_moe(p, x, cfg0)
    y1, a1 = moe_mod.apply_moe(p, x, cfg1)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(a0), float(a1), rtol=1e-5)


def test_moe_bf16_combine_close():
    cfg0 = get_reduced_config("mixtral-8x7b").replace(capacity_factor=8.0,
                                                      moe_batch_dispatch=True)
    cfg1 = cfg0.replace(moe_combine_dtype="bfloat16")
    p = init_params(moe_mod.moe_defs(cfg0), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg0.d_model)) * 0.5
    y0, _ = moe_mod.apply_moe(p, x, cfg0)
    y1, _ = moe_mod.apply_moe(p, x, cfg1)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-2, atol=2e-2)
