"""Chunked prefill: token-exactness vs monolithic prefill (engine- and
server-level, truncation and fused-kernel parity included), the
zero-recompile contract across chunk-count churn and bucket switches, the
head-of-line-stall win on an emulated clock, EOS-at-root retirement, the
controller's prefill-budget/lane-cost pricing, and the ServeConfig surface.
"""
import numpy as np
import pytest

from repro.core.buckets import buckets_for_depths
from repro.core.egt import egt_spec
from repro.core.engine import EngineConfig, SpeculativeEngine
from repro.core.objective import LatencyProfile
from repro.serving.config import ServeConfig
from repro.serving.continuous import ContinuousServer
from repro.serving.controller import BucketController
from repro.serving.emulation import drive_trace
from repro.serving.server import Request
from repro.serving.testbed import Testbed, TestbedSpec, build_testbed

SPEC, VERIFY_V = egt_spec(3, 2), 5
CHUNKS = (4, 8)


@pytest.fixture(scope="module")
def tb() -> Testbed:
    return build_testbed(TestbedSpec(train_steps=160))


def _engine(tb, depths=(3,), **cfg_kw) -> SpeculativeEngine:
    return SpeculativeEngine(tb.drafter, tb.d_params, tb.verifier,
                             tb.v_params,
                             buckets=buckets_for_depths(depths, width=2,
                                                        verify_frac=0.75),
                             depth_options=depths,
                             config=EngineConfig(**cfg_kw))


def _chunked_prefill(eng, state, slot, prompt, chunk_len):
    """Feed `prompt` into `slot` through the chunk executable, the way the
    serving lane does (fixed width, right-padded tail, final flag)."""
    plen, pos = len(prompt), 0
    while pos < plen:
        valid = min(chunk_len, plen - pos)
        chunk = np.zeros(chunk_len, np.int32)
        chunk[:valid] = prompt[pos:pos + valid]
        state = eng.prefill_chunk_into_slot(state, slot, chunk, pos, valid,
                                            pos + valid >= plen)
        pos += valid
    return state


def _prompt(tb, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, tb.spec.vocab, size=n).astype(np.int32)


def _decode_tokens(eng, state, slot, steps=4):
    out = []
    for _ in range(steps):
        state, res = eng.decode_step(state, spec=SPEC, verify_v=VERIFY_V)
        t = res.tokens[slot]
        out.extend(t[t >= 0].tolist())
    return state, out


# ------------------------------------------------- engine-level exactness --
@pytest.mark.parametrize("chunk_len", [4, 5, 8, 16])
def test_chunked_prefill_token_exact(tb, chunk_len):
    """Chunked prefill must reproduce monolithic prefill EXACTLY: same root
    token and same greedy decode continuation, for chunk widths that divide
    the prompt, straddle it, and swallow it whole (prompt length 13)."""
    prompt = _prompt(tb, 13, seed=0)
    pad = np.zeros(16, np.int32)
    pad[:13] = prompt

    eng_m = _engine(tb)
    st_m = eng_m.init_decode_state(2)
    st_m = eng_m.prefill_into_slot(st_m, 1, pad, 13)

    eng_c = _engine(tb)
    st_c = eng_c.init_decode_state(2)
    st_c = _chunked_prefill(eng_c, st_c, 1, prompt, chunk_len)

    assert int(np.asarray(st_c.root)[1]) == int(np.asarray(st_m.root)[1])
    assert st_c.produced[1] == st_m.produced[1] == 1
    # committed lengths agree with the prompt length on both paths
    assert int(eng_c.slot_lengths(st_c)[1]) == 13
    assert int(eng_m.slot_lengths(st_m)[1]) == 13
    _, toks_m = _decode_tokens(eng_m, st_m, 1)
    _, toks_c = _decode_tokens(eng_c, st_c, 1)
    assert toks_c == toks_m


def test_chunk_interleaved_with_decode_is_exact(tb):
    """The serving regime: decode megasteps RUN between chunks (mid-prefill
    slots produce garbage on the batched step). The garbage must be
    invisible — same root and continuation as an uninterrupted prefill."""
    prompt = _prompt(tb, 11, seed=4)
    other = _prompt(tb, 8, seed=5)

    eng_m = _engine(tb)
    st_m = eng_m.init_decode_state(2)
    pad_o = np.zeros(16, np.int32)
    pad_o[:8] = other
    st_m = eng_m.prefill_into_slot(st_m, 0, pad_o, 8)
    pad_p = np.zeros(16, np.int32)
    pad_p[:11] = prompt
    st_m = eng_m.prefill_into_slot(st_m, 1, pad_p, 11)
    _, ref = _decode_tokens(eng_m, st_m, 1, steps=3)

    eng_c = _engine(tb)
    st_c = eng_c.init_decode_state(2)
    st_c = eng_c.prefill_into_slot(st_c, 0, pad_o, 8)
    pos = 0
    C = 4
    while pos < 11:
        valid = min(C, 11 - pos)
        chunk = np.zeros(C, np.int32)
        chunk[:valid] = prompt[pos:pos + valid]
        st_c = eng_c.prefill_chunk_into_slot(st_c, 1, chunk, pos, valid,
                                             pos + valid >= 11)
        pos += valid
        if pos < 11:
            # a full-batch megastep runs between chunks: slot 1 is garbage,
            # but slot 0 keeps decoding real tokens — and slot 1's next
            # chunk must re-pin its length and overwrite the garbage
            st_c, _ = eng_c.decode_step(st_c, spec=SPEC,
                                        verify_v=VERIFY_V)
    assert int(np.asarray(st_c.root)[1]) == int(np.asarray(st_m.root)[1])
    # re-pinning erased the garbage drift for the freshly-prefilled slot
    assert int(eng_c.slot_lengths(st_c)[1]) == 11
    _, toks = _decode_tokens(eng_c, st_c, 1, steps=3)
    assert toks == ref


def test_chunk_executable_input_validation(tb):
    eng = _engine(tb)
    st = eng.init_decode_state(2)
    with pytest.raises(ValueError, match="outside the chunk width"):
        eng.prefill_chunk_into_slot(st, 0, np.zeros(4, np.int32), 0, 5, True)
    with pytest.raises(ValueError, match="overflows"):
        eng.prefill_chunk_into_slot(st, 0, np.zeros(4, np.int32), -1, 2,
                                    False)
    with pytest.raises(ValueError, match="overflows"):
        eng.prefill_chunk_into_slot(st, 0, np.zeros(4, np.int32),
                                    eng.cfg.max_target_len - 1, 4, True)


# -------------------------------------------- truncated-prompt agreement --
def test_monolithic_prefill_rejects_length_past_pad(tb):
    """Bug sweep: the scalar-prefetched `lengths` driving fused-kernel
    kv-block skipping derive from the prefill `length` — a length past the
    padded token extent must be rejected, not silently committed."""
    eng = _engine(tb)
    st = eng.init_decode_state(2)
    with pytest.raises(ValueError, match="disagrees"):
        eng.prefill_into_slot(st, 0, np.zeros(8, np.int32), 9)
    with pytest.raises(ValueError, match="disagrees"):
        eng.prefill_into_slot(st, 0, np.zeros(8, np.int32), -1)


@pytest.mark.parametrize("kernel", ["xla", "fused"])
def test_truncated_prompt_length_agreement(tb, kernel):
    """A prompt longer than prompt_pad is truncated at admission: the
    prefill length, the host mirror `_slot_len`, and the device `length`
    feeding the fused kernel's kv-block skipping must all agree — and the
    truncated request must decode token-identically to submitting the
    pre-truncated prompt, on the XLA and fused verify paths alike."""
    pad = 12
    long_prompt = _prompt(tb, 20, seed=7)

    def serve(prompt, chunks):
        eng = _engine(tb, verify_kernel=kernel)
        srv = ContinuousServer(eng, batch_size=2, prompt_pad=pad,
                               spec=SPEC, verify_v=VERIFY_V,
                               prefill_chunks=chunks)
        srv.warmup()
        srv.submit(Request(uid=0, prompt=prompt.copy(), max_new=8))
        srv.serve()
        return srv

    srv_t = serve(long_prompt, CHUNKS)            # truncated in pad_prompt
    srv_p = serve(long_prompt[:pad], CHUNKS)      # pre-truncated by hand
    srv_m = serve(long_prompt, None)              # monolithic reference
    assert srv_t.metrics.truncated_prompts == 1
    assert srv_t.done[0].truncated
    for other in (srv_p, srv_m):
        np.testing.assert_array_equal(srv_t.done[0].result,
                                      other.done[0].result)
    # three-way length agreement at drain: host mirror == device length,
    # and both track prompt_pad + generated, never the raw prompt length
    np.testing.assert_array_equal(
        srv_t._slot_len, np.asarray(srv_t.engine.slot_lengths(srv_t.state)))
    assert srv_t.metrics.recompiles_after_warmup == 0


# --------------------------------------------------- executable-cache keys --
def _flatten_key(k):
    if isinstance(k, tuple):
        for x in k:
            yield from _flatten_key(x)
    else:
        yield k


def test_step_cache_keys_are_float_free(tb):
    """Bug sweep: float-bearing executable-cache keys (a raw temperature)
    let near-equal floats mint duplicate executables and skew
    executable_count(), the honest recompile signal. Every key must reduce
    to ints/strings/bools/specs — and chunk keys are (kind, chunk_len)
    ONLY, so chunk-count churn can never widen the cache."""
    eng = _engine(tb, temperature=0.7)
    st = eng.init_decode_state(2)
    st = eng.prefill_into_slot(st, 0, np.zeros(8, np.int32), 4)
    st = _chunked_prefill(eng, st, 1, _prompt(tb, 6, seed=2), 4)
    st, _ = eng.decode_step(st, spec=SPEC, verify_v=VERIFY_V)
    assert eng._step_cache, "nothing compiled?"
    for key in eng._step_cache:
        for leaf in _flatten_key(key):
            assert not isinstance(leaf, float), (
                f"float {leaf!r} in executable-cache key {key!r}")
    chunk_keys = [k for k in eng._step_cache
                  if k[0] == "slot_prefill_chunk"]
    assert chunk_keys == [("slot_prefill_chunk", 4)]


def test_equal_temperatures_share_executables(tb):
    """0.7 vs 0.7 + 0.0 must map to the SAME cache key (config identity,
    not float identity)."""
    e1 = _engine(tb, temperature=0.7)
    e2 = _engine(tb, temperature=0.7 + 0.0)
    assert e1._cfg_key == e2._cfg_key
    e3 = _engine(tb, temperature=0.0)
    assert e3._cfg_key != e1._cfg_key
    assert "greedy" in e3._cfg_key


# ------------------------------------------------- zero-recompile contract --
def test_zero_recompiles_across_chunk_churn_and_bucket_switches(tb):
    """Chunk-count churn (prompt lengths from 3 to 16 → 1..4 chunks per
    admission), slot churn (6 requests through 2 slots) and bucket switches
    must all replay warmup-compiled executables."""
    depths = (2, 3)
    eng = _engine(tb, depths=depths)
    ladder = buckets_for_depths(depths, width=2, verify_frac=0.75)
    srv = ContinuousServer(eng, batch_size=2, prompt_pad=16, buckets=ladder,
                           prefill_chunks=CHUNKS)
    srv.warmup()
    exec_after_warmup = eng.executable_count()
    rng = np.random.default_rng(9)
    for uid in range(6):
        plen = int(rng.integers(3, 17))
        srv.submit(Request(uid=uid, prompt=_prompt(tb, plen, seed=20 + uid),
                           max_new=int(rng.integers(4, 10))))
    srv.serve()
    assert srv.metrics.completed == 6
    assert srv.metrics.prefill_chunks > 0
    assert srv.metrics.recompiles_after_warmup == 0
    # drive BOTH warmed buckets explicitly — a bucket switch replays a
    # cached executable, it never compiles
    st = srv.state
    for b in ladder:
        st, _ = eng.decode_step(st, spec=egt_spec(b.depth, b.width),
                                verify_v=b.verify)
    # and one more chunk after all that churn
    st = _chunked_prefill(eng, st, 0, _prompt(tb, 5, seed=99), 4)
    assert eng.executable_count() == exec_after_warmup


# ------------------------------------------------ server-level equivalence --
def test_server_chunked_matches_monolithic(tb):
    """One request set drained through a chunked and a monolithic server:
    identical token streams, zero recompiles, exact host/device length
    agreement at drain."""
    rng = np.random.default_rng(1)
    prompts = [_prompt(tb, int(n), seed=50 + i)
               for i, n in enumerate(rng.integers(4, 15, size=5))]

    def drain(chunks):
        eng = _engine(tb)
        srv = ContinuousServer(eng, batch_size=2, prompt_pad=16,
                               spec=SPEC, verify_v=VERIFY_V,
                               prefill_chunks=chunks)
        srv.warmup()
        for uid, p in enumerate(prompts):
            srv.submit(Request(uid=uid, prompt=p.copy(), max_new=10))
        srv.serve()
        return srv

    mono, chunk = drain(None), drain(CHUNKS)
    assert set(mono.done) == set(chunk.done)
    for uid in mono.done:
        np.testing.assert_array_equal(
            chunk.done[uid].result, mono.done[uid].result,
            err_msg=f"chunked diverged from monolithic for uid {uid}")
    assert chunk.metrics.recompiles_after_warmup == 0
    assert chunk.metrics.prefill_chunks > 0
    assert chunk.metrics.prefill_chunk_tokens >= sum(len(p) for p in prompts)
    np.testing.assert_array_equal(
        chunk._slot_len, np.asarray(chunk.engine.slot_lengths(chunk.state)))


def test_eos_at_root_retires_with_one_token_chunked_and_monolithic(tb):
    """Bug sweep (real engine): a request whose FIRST sampled token is EOS
    retires with exactly one delivered token on both prefill paths — in
    the chunked case the root is credited at final-chunk completion, the
    exact seam where the token could have been dropped."""
    prompt = _prompt(tb, 9, seed=3)
    eng = _engine(tb)
    st = eng.init_decode_state(1)
    pad = np.zeros(16, np.int32)
    pad[:9] = prompt
    st = eng.prefill_into_slot(st, 0, pad, 9)
    first_tok = int(np.asarray(st.root)[0])

    for chunks in (None, CHUNKS):
        srv = ContinuousServer(_engine(tb), batch_size=2, prompt_pad=16,
                               spec=SPEC, verify_v=VERIFY_V,
                               prefill_chunks=chunks, eos_id=first_tok)
        srv.warmup()
        streamed = []
        srv.submit(Request(uid=0, prompt=prompt.copy(), max_new=10,
                           stream=lambda u, t: streamed.extend(t.tolist())))
        srv.serve(max_steps=20)
        assert 0 in srv.done, f"chunks={chunks}: did not retire"
        np.testing.assert_array_equal(srv.done[0].result, [first_tok])
        assert srv.done[0].stats["tokens"] == 1
        assert streamed == [first_tok]
        assert srv.slots[0] is None


# --------------------------------------------- emulated-clock interleaving --
def _profile() -> LatencyProfile:
    return LatencyProfile.synthetic(base_verify=1.0, slope=1.0,
                                    draft_frac=0.1, saturate_at=16,
                                    overhead=0.2)


def test_interleaving_beats_stall_on_emulated_clock(tb):
    """The tentpole economics, deterministically: on a bimodal short/long
    prompt trace the monolithic path charges every admission one
    prompt-pad-width verifier call (the head-of-line stall), the chunked
    lane charges the chunk widths it actually ran — strictly better p95
    AND makespan at identical token output."""
    profile = _profile()
    pad = 32
    rng = np.random.default_rng(13)
    arrivals = np.cumsum(rng.exponential(2.0, size=8))
    prompts = [_prompt(tb, 6 if rng.random() < 0.7 else 28, seed=60 + i)
               for i in range(8)]

    def drive(chunks):
        eng = SpeculativeEngine(
            tb.drafter, tb.d_params, tb.verifier, tb.v_params,
            profile=profile,
            buckets=buckets_for_depths((3,), width=2, verify_frac=0.75),
            depth_options=(3,), config=EngineConfig())
        srv = ContinuousServer(eng, batch_size=2, prompt_pad=pad,
                               spec=SPEC, verify_v=VERIFY_V,
                               prefill_chunks=chunks)
        trace = [(float(arrivals[i]),
                  Request(uid=i, prompt=prompts[i].copy(), max_new=8))
                 for i in range(8)]
        emu = drive_trace(srv, trace, profile)
        lat = np.asarray(list(emu["latencies_s"].values()))
        return srv, float(np.percentile(lat, 95)), emu["makespan_s"]

    srv_m, p95_m, span_m = drive(None)
    srv_c, p95_c, span_c = drive(CHUNKS)
    assert srv_c.metrics.tokens_out == srv_m.metrics.tokens_out
    assert p95_c < p95_m, (p95_c, p95_m)
    assert span_c < span_m, (span_c, span_m)
    assert srv_c.metrics.recompiles_after_warmup == 0
    # the lane padded the tail, so chunk tokens >= real prompt tokens
    assert (srv_c.metrics.prefill_chunk_tokens
            >= sum(len(p) for p in prompts))


# ----------------------------------------------------- controller pricing --
def test_controller_prefill_budget_prices_occupancy():
    ladder = buckets_for_depths((2, 4), width=2, verify_frac=0.75)
    chunks = (8, 16, 32)
    # no profile: drain fast while slots idle, trickle at minimum width once
    # the pool is busy
    ctl = BucketController(ladder)
    assert ctl.prefill_budget(0, 4, chunks) == 32
    assert ctl.prefill_budget(4, 4, chunks) == 8
    # profile mode: budget is monotone non-increasing in occupancy and
    # always one of the configured widths
    ctl_p = BucketController(ladder, profile=_profile())
    budgets = [ctl_p.prefill_budget(n, 4, chunks) for n in range(5)]
    assert all(b in chunks for b in budgets)
    assert all(a >= b for a, b in zip(budgets, budgets[1:])), budgets
    assert ctl_p.prefill_budget(0, 4, chunks) >= ctl_p.prefill_budget(
        4, 4, chunks)
    assert BucketController(ladder).prefill_budget(0, 4, ()) == 0


def test_controller_lane_cost_leans_deep():
    """A shared per-step lane tax dilutes a cheap shallow step more than an
    expensive deep one: the shallow bucket's score must drop by a larger
    factor, and choose() must accept the lane_cost keyword."""
    ladder = buckets_for_depths((2, 8), width=2, verify_frac=0.75)
    ctl = BucketController(ladder, profile=_profile())
    shallow, deep = ladder
    lane = 5.0
    ratio_shallow = (ctl.score(shallow, 1, lane_cost=lane)
                     / ctl.score(shallow, 1))
    ratio_deep = ctl.score(deep, 1, lane_cost=lane) / ctl.score(deep, 1)
    assert ratio_shallow < ratio_deep < 1.0
    assert ctl.choose(n_active=1, lane_cost=lane) in ladder
    # online mode (no profile): lane cost still taxes the denominator
    ctl_o = BucketController(ladder)
    ctl_o.seed_iter_times({shallow.key(): 1.0, deep.key(): 4.0})
    assert (ctl_o.score(shallow, 1, lane_cost=2.0)
            < ctl_o.score(shallow, 1))


# ------------------------------------------------------------ ServeConfig --
def test_serveconfig_chunk_fields_roundtrip():
    cfg = ServeConfig(server="continuous", prefill_chunk="16,8",
                      prefill_budget=16)
    assert cfg.chunk_lens() == (8, 16)
    assert ServeConfig.parse(cfg.to_argv()) == cfg
    assert ServeConfig.from_json(cfg.to_json()) == cfg
    assert ServeConfig().chunk_lens() == ()    # default: chunking off
    with pytest.raises(ValueError, match="comma-separated ints"):
        ServeConfig(prefill_chunk="8,x")
    with pytest.raises(ValueError, match=">= 1"):
        ServeConfig(prefill_chunk="0,8")
    with pytest.raises(ValueError, match=">= 0"):
        ServeConfig(prefill_budget=-1)


def test_serveconfig_builds_chunked_server(tb):
    cfg = ServeConfig(server="continuous", batch=2, prompt_pad=16,
                      depth=3, prefill_chunk="4,8", train_steps=160)
    eng = _engine(tb)
    srv = cfg.build_server(eng)
    assert srv.chunked and srv.prefill_chunks == (4, 8)
    cfg_off = ServeConfig(server="continuous", batch=2, prompt_pad=16,
                          depth=3, train_steps=160)
    assert not cfg_off.build_server(_engine(tb)).chunked
