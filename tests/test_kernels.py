"""Per-kernel correctness sweeps: every Pallas kernel (interpret mode on CPU)
against its pure-jnp oracle in ref.py, across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- tree ----
# k/v are the cache's own un-repeated [B, S, KV, dh] layout; the kernel
# tiles a [G·W, dh] query block per kv-head (G = H // KV)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,W,S,KV,G,dh", [
    (1, 8, 64, 2, 1, 64),
    (2, 16, 128, 2, 2, 64),
    (2, 5, 96, 2, 4, 128),  # GQA, W not MXU-aligned, S not block-aligned
    (1, 64, 512, 1, 8, 64),  # MQA
])
def test_tree_attention_matches_ref(B, W, S, KV, G, dh, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = _rand(ks[0], (B, W, KV * G, dh), dtype)
    k = _rand(ks[1], (B, S, KV, dh), dtype)
    v = _rand(ks[2], (B, S, KV, dh), dtype)
    # random visibility mask with at least one visible slot per query
    mask = jax.random.bernoulli(ks[3], 0.4, (B, W, S))
    mask = mask.at[:, :, 0].set(True)
    out = ops.tree_attention(q, k, v, mask)
    want = ref.tree_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,W,S,KV,G,dh", [
    (1, 8, 64, 2, 1, 64),
    (2, 5, 96, 2, 2, 128),  # GQA, W not MXU-aligned, S not block-aligned
    (1, 16, 128, 2, 1, 32),  # dh below one full scale group size
])
def test_tree_attention_int8_matches_ref(B, W, S, KV, G, dh):
    """The dequantizing kernel against its oracle: identical int8 payload +
    scales through both, so the comparison is tight (same dequant math)."""
    from repro.quant import quantize_kv
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    q = _rand(ks[0], (B, W, KV * G, dh), jnp.float32)
    kq, k_scale = quantize_kv(_rand(ks[1], (B, S, KV, dh), jnp.float32))
    vq, v_scale = quantize_kv(_rand(ks[2], (B, S, KV, dh), jnp.float32))
    mask = jax.random.bernoulli(ks[3], 0.4, (B, W, S))
    mask = mask.at[:, :, 0].set(True)
    out = ops.tree_attention(q, kq, vq, mask, k_scale=k_scale,
                             v_scale=v_scale)
    want = ref.tree_attention_int8_ref(q, kq, vq, k_scale, v_scale, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_tree_attention_int8_close_to_fp32():
    """End-to-end quantization error: int8 path vs the fp32 kernel on the
    same K/V stays within the per-group absmax rounding budget."""
    from repro.quant import quantize_kv
    B, W, S, KV, dh = 2, 8, 64, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    q = _rand(ks[0], (B, W, KV, dh), jnp.float32)
    k = _rand(ks[1], (B, S, KV, dh), jnp.float32)
    v = _rand(ks[2], (B, S, KV, dh), jnp.float32)
    mask = jax.random.bernoulli(ks[3], 0.5, (B, W, S)).at[:, :, 0].set(True)
    kq, k_scale = quantize_kv(k)
    vq, v_scale = quantize_kv(v)
    out8 = ops.tree_attention(q, kq, vq, mask, k_scale=k_scale,
                              v_scale=v_scale)
    out32 = ops.tree_attention(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out8), np.asarray(out32),
                               rtol=5e-2, atol=5e-2)


def test_tree_attention_scale_args_must_pair():
    B, W, S, H, dh = 1, 4, 32, 1, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = _rand(ks[0], (B, W, H, dh), jnp.float32)
    k = _rand(ks[1], (B, S, H, dh), jnp.float32)
    mask = jnp.ones((B, W, S), bool)
    with pytest.raises(ValueError):
        ops.tree_attention(q, k, k, mask, k_scale=jnp.ones((B, S, H, 4)))


def test_tree_attention_fully_masked_rows_are_finite():
    B, W, S, H, dh = 1, 4, 32, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (B, W, H, dh), jnp.float32)
    k = _rand(ks[1], (B, S, H, dh), jnp.float32)
    v = _rand(ks[2], (B, S, H, dh), jnp.float32)
    mask = jnp.zeros((B, W, S), bool)
    out = ops.tree_attention(q, k, v, mask)
    assert bool(jnp.all(jnp.isfinite(out)))


# ------------------------------------------------------ block-size guard ----
def test_block_pad_never_degrades_to_scalar_blocks():
    """Regression: the old wrapper halved the block size until it divided S,
    collapsing to bs=1 (scalar blocks, thousands of grid steps) for odd or
    prime S. The fix pads S up to a block multiple instead."""
    bs, pad = ops.block_pad(257, 256)        # prime, > one block
    assert bs == 256 and (257 + pad) % 256 == 0
    bs, pad = ops.block_pad(97, 256)         # prime, < one block: exact fit
    assert bs == 97 and pad == 0
    bs, pad = ops.block_pad(300, 256)        # old loop fell to bs=4 here
    assert bs == 256 and (300 + pad) % bs == 0
    bs, pad = ops.block_pad(512, 256)        # multiples stay pad-free
    assert bs == 256 and pad == 0


def test_tree_attention_prime_s_matches_ref():
    """Prime S larger than one block exercises the pad-up path end to end
    (the masked pad slots must not perturb the softmax)."""
    B, W, S, KV, G, dh = 2, 4, 257, 2, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(8), 4)
    q = _rand(ks[0], (B, W, KV * G, dh), jnp.float32)
    k = _rand(ks[1], (B, S, KV, dh), jnp.float32)
    v = _rand(ks[2], (B, S, KV, dh), jnp.float32)
    mask = jax.random.bernoulli(ks[3], 0.4, (B, W, S)).at[:, :, 0].set(True)
    out = ops.tree_attention(q, k, v, mask)
    want = ref.tree_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_prefill_prime_s_matches_ref():
    B, S, H, dh = 1, 131, 2, 64   # prime S > block 64
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = _rand(ks[0], (B, S, H, dh), jnp.float32)
    k = _rand(ks[1], (B, S, H, dh), jnp.float32)
    v = _rand(ks[2], (B, S, H, dh), jnp.float32)
    out = ops.flash_prefill(q, k, v, block_q=64, block_k=64)
    want = ref.flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# -------------------------------------------------------------- prefill ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,dh", [
    (1, 128, 2, 64),
    (2, 256, 4, 64),
    (1, 192, 2, 128),       # S not a power of two
])
def test_flash_prefill_matches_ref(B, S, H, dh, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], (B, S, H, dh), dtype)
    k = _rand(ks[1], (B, S, H, dh), dtype)
    v = _rand(ks[2], (B, S, H, dh), dtype)
    out = ops.flash_prefill(q, k, v, block_q=64, block_k=64)
    want = ref.flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ------------------------------------------------------------------ ssd ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 2, 32, 16, 16),
    (2, 96, 4, 64, 32, 32),   # s not divisible by chunk
    (1, 128, 2, 32, 64, 64),
])
def test_ssd_scan_matches_ref(b, s, h, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = _rand(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = _rand(ks[3], (b, s, h, n), dtype)
    C = _rand(ks[4], (b, s, h, n), dtype)
    y, st = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    y_ref, st_ref = ref.ssd_ref(x, dt, A, B, C)
    tol = dict(rtol=4e-2, atol=4e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=1e-3, atol=1e-3)


def test_ssd_scan_carries_initial_state():
    b, s, h, p, n = 1, 32, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    x = _rand(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = _rand(ks[3], (b, s, h, n), jnp.float32)
    C = _rand(ks[4], (b, s, h, n), jnp.float32)
    st0 = jax.random.normal(ks[5], (b, h, p, n))
    # split scan == full scan (state handoff correctness)
    y1, st1 = ops.ssd_scan(x[:, :16], dt[:, :16], A, B[:, :16], C[:, :16],
                           chunk=8, initial_state=st0)
    y2, st2 = ops.ssd_scan(x[:, 16:], dt[:, 16:], A, B[:, 16:], C[:, 16:],
                           chunk=8, initial_state=st1)
    y_full, st_full = ops.ssd_scan(x, dt, A, B, C, chunk=8, initial_state=st0)
    np.testing.assert_allclose(np.concatenate([y1, y2], 1), np.asarray(y_full),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=1e-4, atol=1e-4)
