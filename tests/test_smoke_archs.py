"""Per-architecture smoke tests: a REDUCED same-family variant runs one
forward and one train step on CPU — shapes correct, loss finite, no NaNs.
(The full configs are exercised only via the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, get_reduced_config
from repro.models import Model
from repro.training import OptConfig, init_opt_state, make_train_step


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_train_step(arch):
    cfg = get_reduced_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)),
                                   jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["enc_feats"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq_len, cfg.encoder_feature_dim))
            * 0.02, jnp.float32)
    step = jax.jit(make_train_step(model, OptConfig(warmup_steps=1,
                                                    total_steps=10)))
    state = init_opt_state(params)
    p1, s1, m1 = step(params, state, batch)
    p2, s2, m2 = step(p1, s1, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["ce"]) <= float(m1["ce"]) * 1.5  # not exploding
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l2 = jax.tree.leaves(p2)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l2))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward_shapes(arch):
    cfg = get_reduced_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    toks = jnp.zeros((B, S), jnp.int32)
    enc = (jnp.zeros((B, cfg.encoder_seq_len, cfg.encoder_feature_dim))
           if cfg.is_encoder_decoder else None)
    h, aux = model.hidden_train(params, toks, enc_feats=enc)
    assert h.shape == (B, S, cfg.d_model)
    logits = model.logits(params, h)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert not bool(jnp.any(jnp.isnan(logits)))
    # padded vocab entries must never win argmax after init (embed column 0
    # padding check): logits over pad region are finite, that's all we need
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    expect = {
        "nemotron-4-15b": dict(num_layers=32, d_model=6144, num_heads=48,
                               num_kv_heads=8, d_ff=24576, vocab_size=256000,
                               mlp_act="sq_relu"),
        "jamba-v0.1-52b": dict(num_layers=32, d_model=4096, num_heads=32,
                               num_kv_heads=8, d_ff=14336, vocab_size=65536,
                               num_experts=16, num_experts_per_tok=2),
        "yi-6b": dict(num_layers=32, d_model=4096, num_heads=32,
                      num_kv_heads=4, d_ff=11008, vocab_size=64000),
        "internlm2-20b": dict(num_layers=48, d_model=6144, num_heads=48,
                              num_kv_heads=8, d_ff=16384, vocab_size=92544),
        "whisper-medium": dict(num_layers=24, d_model=1024, num_heads=16,
                               num_kv_heads=16, d_ff=4096, vocab_size=51865,
                               is_encoder_decoder=True),
        "granite-20b": dict(num_layers=52, d_model=6144, num_heads=48,
                            num_kv_heads=1, d_ff=24576, vocab_size=49152),
        "mamba2-130m": dict(num_layers=24, d_model=768, ssm_state_size=128),
        "granite-moe-3b-a800m": dict(num_layers=32, d_model=1536,
                                     num_heads=24, num_kv_heads=8,
                                     num_experts=40, num_experts_per_tok=8,
                                     moe_d_ff=512, vocab_size=49155),
        "chameleon-34b": dict(num_layers=48, d_model=8192, num_heads=64,
                              num_kv_heads=8, d_ff=22016, vocab_size=65536),
        "mixtral-8x7b": dict(num_layers=32, d_model=4096, num_heads=32,
                             num_kv_heads=8, d_ff=14336, vocab_size=32000,
                             num_experts=8, num_experts_per_tok=2),
    }
    for arch, want in expect.items():
        cfg = get_config(arch)
        for k, v in want.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_is_actually_reduced(arch):
    cfg = get_reduced_config(arch)
    assert cfg.num_layers <= 8
    assert cfg.d_model <= 512
    assert (cfg.num_experts or 0) <= 4
    assert cfg.family == get_config(arch).family
